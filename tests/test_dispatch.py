"""Unified kernel dispatch: backend detection, mode resolution, interpret
fallback, launch-parameter ConfigSpace round-trips, and CAMEO tuning the
launch space end-to-end on the kernel-launch environment."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cameo import Cameo
from repro.core.query import Query
from repro.envs.kernel_launch import KernelLaunchEnv, KernelWorkload
from repro.kernels import dispatch, ops
from repro.kernels.flash_attention import ref as aref
from repro.kernels.rmsnorm import ref as rref

RNG = np.random.default_rng(0)


def rand(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# --------------------------------------------------------------------------
# backend detection / mode resolution
# --------------------------------------------------------------------------

def test_detect_backend_and_default_mode():
    assert dispatch.detect_backend() == "cpu"  # this container has no TPU
    assert dispatch.default_mode() == dispatch.REF
    assert dispatch.default_mode(backend="gpu") == dispatch.REF
    assert ops.kernel_mode() == dispatch.REF


def test_mode_env_override(monkeypatch):
    monkeypatch.setenv(dispatch.KERNEL_MODE_ENV, "pallas_interpret")
    assert dispatch.default_mode() == dispatch.PALLAS_INTERPRET
    monkeypatch.setenv(dispatch.KERNEL_MODE_ENV, "bogus")
    with pytest.raises(ValueError):
        dispatch.default_mode()


def test_all_families_registered():
    assert dispatch.families() == ["flash_attention", "mamba_scan",
                                   "paged_attention", "rmsnorm", "ssd"]
    for name in dispatch.families():
        fam = dispatch.get_family(name)
        assert fam.launch_options, name
        assert callable(dispatch.ref_fn(name))
        assert callable(dispatch.pallas_fn(name))


# --------------------------------------------------------------------------
# interpret-mode fallback through the generic router
# --------------------------------------------------------------------------

def test_generic_dispatch_rmsnorm_interpret_matches_ref():
    x, w = rand(6, 64), rand(64)
    ref = dispatch.dispatch("rmsnorm", x, w, mode="ref", eps=1e-5)
    np.testing.assert_allclose(ref, rref.rmsnorm_ref(x, w, eps=1e-5),
                               atol=1e-6)
    out = dispatch.dispatch("rmsnorm", x, w, mode="pallas_interpret",
                            launch={"row_block": 8}, eps=1e-5)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_generic_dispatch_attention_and_decode_variant():
    q, k, v = rand(1, 32, 4, 16), rand(1, 32, 2, 16), rand(1, 32, 2, 16)
    ref = aref.attention_ref(q, k, v, causal=True)
    out = dispatch.dispatch("flash_attention", q, k, v,
                            mode="pallas_interpret",
                            launch={"q_block": 16, "kv_block": 16},
                            causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)

    qd = rand(2, 1, 8, 32)
    kc, vc = rand(2, 80, 2, 32), rand(2, 80, 2, 32)
    clen = jnp.asarray([13, 77], jnp.int32)
    refd = aref.decode_attention_ref(qd, kc, vc, clen)
    # ref mode drops the kv_block launch param (the oracle has no blocking)
    outd_ref = dispatch.dispatch("flash_attention", qd, kc, vc, clen,
                                 variant="decode", mode="ref",
                                 launch={"kv_block": 32})
    np.testing.assert_allclose(outd_ref, refd, atol=2e-5, rtol=1e-4)
    outd = dispatch.dispatch("flash_attention", qd, kc, vc, clen,
                             variant="decode", mode="pallas_interpret",
                             launch={"kv_block": 32})
    np.testing.assert_allclose(outd, refd, atol=2e-5, rtol=1e-4)


def test_ops_entry_points_in_interpret_mode(monkeypatch):
    monkeypatch.setenv(dispatch.KERNEL_MODE_ENV, "pallas_interpret")
    x, w = rand(4, 7, 32), rand(32)
    np.testing.assert_allclose(ops.rmsnorm(x, w),
                               rref.rmsnorm_ref(x, w), atol=2e-5, rtol=1e-4)
    q, k, v = rand(1, 24, 4, 16), rand(1, 24, 2, 16), rand(1, 24, 2, 16)
    np.testing.assert_allclose(
        ops.flash_attention(q, k, v, causal=True, q_block=8, kv_block=8),
        aref.attention_ref(q, k, v, causal=True), atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------
# launch parameters: precedence + ConfigSpace round-trip
# --------------------------------------------------------------------------

def test_launch_param_precedence_and_validation():
    assert dispatch.launch_params("rmsnorm")["row_block"] == 256
    assert dispatch.launch_params("rmsnorm", row_block=64)["row_block"] == 64
    # None means "unspecified", not an override
    assert dispatch.launch_params("rmsnorm", row_block=None)["row_block"] == 256
    with dispatch.use_launch_config({"rmsnorm.row_block": 128}):
        # an active tuned config outranks the call site
        assert dispatch.launch_params("rmsnorm", row_block=64)["row_block"] == 128
        with dispatch.use_launch_config({"flash_attention": {"q_block": 256}}):
            # nested contexts merge
            assert dispatch.launch_params("rmsnorm")["row_block"] == 128
            assert dispatch.launch_params("flash_attention")["q_block"] == 256
    assert dispatch.launch_params("rmsnorm")["row_block"] == 256

    with pytest.raises(KeyError):
        dispatch.split_launch_config({"bogus.q_block": 128})
    with pytest.raises(KeyError):
        dispatch.split_launch_config({"rmsnorm.bogus": 128})
    with pytest.raises(KeyError):
        dispatch.launch_params("rmsnorm", bogus=1)


def test_launch_space_roundtrips_through_configspace():
    space = dispatch.launch_space()
    assert set(space.names) == {
        "flash_attention.q_block", "flash_attention.kv_block",
        "mamba_scan.chunk", "mamba_scan.c_block", "ssd.chunk",
        "rmsnorm.row_block", "paged_attention.page_size",
        "paged_attention.pages_per_slot_max",
        "paged_attention.prefill_chunk"}
    rng = np.random.default_rng(3)
    for cfg in [space.default_config()] + space.sample(rng, 25):
        assert space.decode(space.encode(cfg)) == cfg
        nested = dispatch.split_launch_config(cfg)
        with dispatch.use_launch_config(cfg):
            for fam, params in nested.items():
                resolved = dispatch.launch_params(fam)
                for pname, v in params.items():
                    assert resolved[pname] == v


def test_tuned_config_drives_real_kernel():
    x, w = rand(10, 32), rand(32)
    with dispatch.use_launch_config({"rmsnorm.row_block": 2}):
        res = dispatch.resolve("rmsnorm", mode="pallas_interpret")
        assert res.launch["row_block"] == 2
        out = ops.rmsnorm(x, w)  # still ref mode outside env var — numeric
        np.testing.assert_allclose(out, rref.rmsnorm_ref(x, w),
                                   atol=2e-5, rtol=1e-4)
        out_i = dispatch.dispatch("rmsnorm", x, w, mode="pallas_interpret")
        np.testing.assert_allclose(out_i, rref.rmsnorm_ref(x, w),
                                   atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------
# CAMEO optimizes the launch space end-to-end
# --------------------------------------------------------------------------

def test_cameo_tunes_launch_space_end_to_end():
    # source: cheap training-shape environment with plentiful observations
    src = KernelLaunchEnv(KernelWorkload(name="train-2k", batch=16,
                                         seq_len=2048), seed=1)
    # target: serving shape with higher launch overhead — effects shift
    tgt = KernelLaunchEnv(KernelWorkload(name="serve-8k", batch=4,
                                         seq_len=8192,
                                         launch_overhead_us=3.0), seed=2)
    source_data = src.dataset(48, seed=3)
    cam = Cameo(tgt.space, Query(objective="step_time"), source_data,
                counter_names=tgt.counter_names, seed=0)
    cam.seed_target(tgt.dataset(6, seed=4))
    best_cfg, best_y = cam.run(tgt, budget=10)

    assert np.isfinite(best_y)
    assert set(best_cfg) <= set(tgt.space.names)
    # the optimum must be feasible under the VMEM constraint model
    counters, y_check = tgt.intervene(best_cfg)
    assert np.isfinite(y_check)
    assert counters["vmem_peak_bytes"] <= tgt.workload.vmem_limit

    # end of the loop IS deployment: the tuned optimum installs onto the
    # dispatch registry and every kernel resolves with the tuned params
    with tgt.apply(best_cfg):
        for fam, params in dispatch.split_launch_config(best_cfg).items():
            resolved = dispatch.launch_params(fam)
            for pname, v in params.items():
                assert resolved[pname] == v
