"""Batched ask/tell loop: k=1 parity, q-batch proposal, batched measurement.

The refactor's contract is two-sided: ``query_batch=1`` must reproduce the
historical sequential trajectories bit-for-bit (same RNG streams, same
datasets, same traces), and ``query_batch=k`` must measure the same system
(batched replay equivalence) while actually sharing expensive measurement
infrastructure (compile-key grouping, vectorized noise, memoized pools).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.baselines import RandomSearch, SMAC, make_baseline
from repro.core.cameo import Cameo, Dataset, Proposal
from repro.core.query import parse_query
from repro.envs.kernel_launch import KernelLaunchEnv, KernelWorkload
from repro.tuner.runner import transfer_tune

TINY_TRACE = ("poisson:rate=1200,horizon=0.003,mean_prompt=5,"
              "mean_output=3,max_len=12")


def _env(seed=0, backend="analytic"):
    return KernelLaunchEnv(KernelWorkload(), families=["flash_attention"],
                           seed=seed, backend=backend)


def _cameo_for(env, seed=0, budget=8):
    d_s = _env(seed=seed + 50).dataset(24, seed=seed + 1)
    q = parse_query(f"minimize latency within {budget} samples")
    cam = Cameo(env.space, q, d_s, counter_names=env.counter_names,
                seed=seed)
    cam.seed_target(_env(seed=seed + 60).dataset(3, seed=seed + 2))
    return cam


# ---------------------------------------------------------------------------
# k=1 parity: the batched loop IS the sequential loop at query_batch=1
# ---------------------------------------------------------------------------


def test_cameo_run_qb1_matches_step_loop():
    env_a, env_b = _env(seed=3), _env(seed=3)
    cam_a, cam_b = _cameo_for(env_a, seed=7), _cameo_for(env_b, seed=7)
    for _ in range(8):
        cam_a.step(env_a)
    cfg_b, y_b = cam_b.run(env_b, budget=8, query_batch=1)
    assert cam_a.d_t.configs == cam_b.d_t.configs
    assert cam_a.d_t.ys == cam_b.d_t.ys
    assert cam_a.trace.action == cam_b.trace.action
    assert cam_a.trace.best_y == cam_b.trace.best_y
    assert (cfg_b, y_b) == (cam_a.best[0] or env_a.space.default_config(),
                            cam_a.best[1])


def test_transfer_tune_qb1_matches_default():
    res_a = transfer_tune("cameo", _env(seed=1), _env(seed=2), budget=6,
                          n_source=24, n_target_init=3, seed=5,
                          query_text="minimize latency within "
                                     "{budget} samples")
    res_b = transfer_tune("cameo", _env(seed=1), _env(seed=2), budget=6,
                          n_source=24, n_target_init=3, seed=5,
                          query_batch=1,
                          query_text="minimize latency within "
                                     "{budget} samples")
    assert res_a.trace_best_y == res_b.trace_best_y
    assert res_a.best_config == res_b.best_config
    assert res_b.rounds and all(r["size"] == 1 for r in res_b.rounds)


@pytest.mark.parametrize("method", ["random", "smac", "cello"])
def test_baseline_run_qb1_matches_propose_loop(method):
    d_s = _env(seed=9).dataset(16, seed=1)
    t_a = make_baseline(method, _env().space, d_s, seed=4)
    t_b = make_baseline(method, _env().space, d_s, seed=4)
    env_a, env_b = _env(seed=6), _env(seed=6)
    # hand-rolled historical loop vs the round-structured run()
    spent = 0.0
    while spent < 6 and method != "cello":
        cfg = t_a.propose()
        cnt, y = env_a.intervene(cfg)
        t_a.update(cfg, cnt, y)
        spent += 1.0
    if method == "cello":
        t_a.run(env_a, 6)
    t_b.run(env_b, 6, query_batch=1)
    assert t_a.xs == t_b.xs
    assert t_a.ys == t_b.ys


def test_baseline_ask_topk_distinct_and_anchored():
    d_s = _env(seed=9).dataset(16, seed=1)
    t_a = make_baseline("smac", _env().space, d_s, seed=11)
    t_b = make_baseline("smac", _env().space, d_s, seed=11)
    env = _env(seed=12)
    for cfg in env.space.sample(np.random.default_rng(0), 6):
        cnt, y = env.intervene(cfg)
        t_a.update(cfg, cnt, y)
        t_b.update(cfg, cnt, y)
    single = t_a.ask(1)
    batch = t_b.ask(4)
    assert batch[0] == single[0]          # anchor is the sequential argmax
    keys = [t_b._config_key(c) for c in batch]
    assert len(set(keys)) == len(keys)    # distinct within the round


# ---------------------------------------------------------------------------
# batched measurement backends
# ---------------------------------------------------------------------------


def test_analytic_measure_batch_bit_parity():
    env_a, env_b = _env(seed=21), _env(seed=21)
    cfgs = env_a.space.sample(np.random.default_rng(3), 6)
    # force one infeasible member so the feasible-only noise draw is covered
    big = dict(cfgs[2])
    big["flash_attention.q_block"] = max(
        env_a.space.by_name["flash_attention.q_block"].values)
    big["flash_attention.kv_block"] = max(
        env_a.space.by_name["flash_attention.kv_block"].values)
    cfgs[2] = big
    seq = [env_a.intervene(c) for c in cfgs]
    bat = env_b.intervene_batch(cfgs)
    for (c_s, y_s), (c_b, y_b) in zip(seq, bat):
        assert c_s == c_b
        assert y_s == y_b or (np.isinf(y_s) and np.isinf(y_b))


def test_shifted_measure_batch_bit_parity():
    from repro.envs.measure import ShiftedAnalyticBackend

    def env(seed):
        be = ShiftedAnalyticBackend(KernelWorkload(), ["flash_attention"],
                                    seed=seed, shifts="hardware")
        return KernelLaunchEnv(KernelWorkload(), backend=be, seed=seed)

    env_a, env_b = env(5), env(5)
    cfgs = env_a.space.sample(np.random.default_rng(8), 5)
    seq = [env_a.intervene(c) for c in cfgs]
    bat = env_b.intervene_batch(cfgs)
    assert [y for _, y in seq] == [y for _, y in bat]


def test_dataset_qb1_unchanged_and_grouped_batching():
    d_a = _env(seed=31).dataset(8, seed=2)
    d_b = _env(seed=31).dataset(8, seed=2, query_batch=1)
    assert d_a.configs == d_b.configs and d_a.ys == d_b.ys

    env = _env(seed=31)
    env.batch_share_dims = ("flash_attention.q_block",)
    d_g = env.dataset(8, seed=2, query_batch=4)
    for g0 in range(0, 8, 4):
        grp = d_g.configs[g0:g0 + 4]
        assert len({c["flash_attention.q_block"] for c in grp}) == 1


# ---------------------------------------------------------------------------
# cameo q-batch proposal structure
# ---------------------------------------------------------------------------


def test_cameo_ask_batch_pins_non_reduced_dims():
    env = _env(seed=41)
    cam = _cameo_for(env, seed=13)
    cam.ask(1)  # surrogates warm
    props = cam.ask(4, allow_observe=False)
    assert all(p.kind == "intervene" for p in props)
    cfgs = [p.config for p in props]
    keys = {cam._key(c) for c in cfgs}
    assert len(keys) == len(cfgs)         # diverse: no duplicate slots
    other = [n for n in cam.space.names if n not in cam.reduced_names]
    for nm in other:
        assert len({c[nm] for c in cfgs}) == 1  # pinned to the anchor


def test_cameo_ask_k1_is_argmax_anchor():
    env = _env(seed=42)
    cam_a, cam_b = _cameo_for(env, seed=17), _cameo_for(env, seed=17)
    p1 = cam_a.ask(1, allow_observe=False)
    p4 = cam_b.ask(4, allow_observe=False)
    assert p1[0].config == p4[0].config   # slot 0 is the sequential pick


def test_proposal_roundtrip_tell():
    env = _env(seed=43)
    cam = _cameo_for(env, seed=19)
    props = cam.ask(3, allow_observe=False)
    cfgs = [p.config for p in props]
    results = env.intervene_batch(cfgs)
    n0 = len(cam.d_t)
    cam.tell(cfgs, [c for c, _ in results], [y for _, y in results])
    assert len(cam.d_t) == n0 + 3
    assert len(cam.trace.best_y) == 3


# ---------------------------------------------------------------------------
# replay env: batched replay equivalence + memoized pool/dataset unification
# ---------------------------------------------------------------------------


def _replay_env(**kw):
    from repro.envs.replay_env import ReplayServingEnv

    kw.setdefault("repeats", 1)
    kw.setdefault("warmup", 1)
    return ReplayServingEnv(TINY_TRACE, seed=0, trace_seed=0, **kw)


def _plan_cfg(env, **over):
    cfg = env.space.default_config()
    cfg.update(over)
    return cfg


#: counters whose values are deterministic functions of the schedule (token
#: counts / tick counts), independent of wall-clock jitter
_DET = ("occupancy_mean", "rejected_rate", "slo_violation_rate")


def test_intervene_batch_matches_sequential_replay():
    env_b = _replay_env()
    cfgs = [_plan_cfg(env_b, **{"serving.num_slots": 4}),
            _plan_cfg(env_b, **{"serving.num_slots": 8,
                                "serving.admit_chunk": 2}),
            _plan_cfg(env_b, **{"serving.num_slots": 4,
                                "serving.interleave": "drain"})]
    got = env_b.intervene_batch(cfgs)
    for cfg, (cnt_b, y_b) in zip(cfgs, got):
        env_s = _replay_env()
        cnt_s, y_s = env_s.intervene(cfg)
        assert np.isfinite(y_b) and np.isfinite(y_s)
        for name in _DET:
            assert cnt_b[name] == pytest.approx(cnt_s[name]), name


def test_intervene_batch_one_member_drainstall():
    # max_ticks small enough that a 1-slot drain policy stalls while the
    # default plan drains — the stalled member must come back infeasible
    # without poisoning its batch-mates
    env = _replay_env(max_ticks=4)
    good = _plan_cfg(env)
    stall = _plan_cfg(env, **{"serving.num_slots": 1,
                              "serving.interleave": "drain"})
    good2 = _plan_cfg(env, **{"serving.admit_chunk": 2})
    results = env.intervene_batch([good, stall, good2])
    assert np.isfinite(results[0][1])
    assert np.isinf(results[1][1])
    assert results[1][0]["rejected_rate"] == 1.0
    assert np.isfinite(results[2][1])


def test_intervene_batch_infeasible_gate_and_order():
    from repro.envs.replay_env import ReplayServingEnv

    # a trace whose context cannot fit the smallest cache: the analytic
    # gate must reject those members before any batcher is built (this
    # batch is all-infeasible, so the call compiles nothing)
    env = ReplayServingEnv("poisson:rate=400,horizon=0.002,mean_prompt=150,"
                           "mean_output=5,max_len=200",
                           seed=0, trace_seed=0, repeats=1)
    assert env.trace.max_context > 128
    bad_a = _plan_cfg(env, **{"serving.cache_len": 128})
    bad_b = _plan_cfg(env, **{"serving.cache_len": 128,
                              "serving.num_slots": 2})
    assert env.infeasible_reason(bad_a)
    results = env.intervene_batch([bad_a, bad_b])
    assert np.isinf(results[0][1]) and np.isinf(results[1][1])
    assert results[0][0]["rejected_rate"] == 1.0


def test_replay_env_memoizes_dataset_and_pool():
    env = _replay_env()
    assert env.memoize_measurements
    d1 = env.dataset(3, seed=4)
    n_measured = len(env._measured)
    # same seed: every config is a memo hit — no new measurements
    d2 = env.dataset(3, seed=4)
    assert len(env._measured) == n_measured
    assert d1.ys == d2.ys
    # the observational pool was fed by dataset collection
    assert len(env._pool) >= 3
    cfg, cnt, y = env.observe(np.random.default_rng(0))
    assert isinstance(y, float)


def test_replay_env_batch_share_dims_cover_compile_key():
    env = _replay_env()
    assert "serving.cache_len" in env.batch_share_dims
    assert "serving.num_slots" not in env.batch_share_dims
    launch = [n for n in env.space.names
              if "." in n and not n.startswith("serving.")]
    assert set(launch) <= set(env.batch_share_dims)


def test_small_lru_bounds_and_evicts():
    from repro.envs.replay_env import _SmallLru

    lru = _SmallLru(maxsize=2)
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1              # refreshes 'a'
    lru.put("c", 3)                       # evicts 'b' (oldest)
    assert "b" not in lru and "a" in lru and "c" in lru
    assert len(lru) == 2


def test_built_model_lru_shared_identity():
    from repro.envs.replay_env import _MODEL_LRU, _built_model

    env_a, env_b = _replay_env(), _replay_env()
    assert env_a.model is env_b.model     # one deployment identity
    assert len(_MODEL_LRU) <= _MODEL_LRU.maxsize
    m, _, _ = _built_model(env_a.model_cfg, 0)
    assert m is env_a.model


# ---------------------------------------------------------------------------
# end-to-end: batched transfer_tune on the analytic env
# ---------------------------------------------------------------------------


def test_transfer_tune_batched_runs_and_rounds_accounting():
    res = transfer_tune("cameo", _env(seed=1), _env(seed=2), budget=7,
                        n_source=24, n_target_init=3, seed=5, query_batch=3,
                        query_text="minimize latency within "
                                   "{budget} samples")
    assert sum(r["size"] for r in res.rounds) == 7
    assert all(r["size"] <= 3 for r in res.rounds)
    assert len(res.trace_best_y) >= 5     # cold rounds don't append trace
    assert res.extras["query_batch"] == 3
    assert np.isfinite(res.best_y)


def test_transfer_tune_batched_baseline():
    res = transfer_tune("smac", _env(seed=1), _env(seed=2), budget=6,
                        n_source=24, n_target_init=3, seed=5, query_batch=2,
                        query_text="minimize latency within "
                                   "{budget} samples")
    assert sum(r["size"] for r in res.rounds) == 6
    assert len(res.trace_best_y) == 6
    assert np.isfinite(res.best_y)
