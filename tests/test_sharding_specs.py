"""Sharding rules verified against an abstract production mesh (no devices
needed: PartitionSpec construction is pure)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import tiny_model_config
from repro.compat import make_abstract_mesh
from repro.models.model import build_model
from repro.sharding.specs import (batch_specs, cache_specs, param_specs,
                                  train_state_specs)
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_train_state
from repro.utils.config import (MeshConfig, ParallelConfig, RunConfig,
                                ShapeConfig, TrainConfig)

MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH_MP = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
PAR = ParallelConfig(fsdp=2, tp=16)


def _flat(tree):
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", "")))) for p in path)
        out[key] = leaf
    return out


def _params_shapes(cfg):
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def test_dense_param_specs_shard_tp_and_fsdp():
    cfg = tiny_model_config(d_model=256, num_heads=16, num_kv_heads=16,
                            d_ff=512, vocab_size=512)
    shapes = _params_shapes(cfg)
    specs = _flat(param_specs(shapes, cfg, PAR, MESH))
    wq = specs["blocks/sub0/attn/wq"]
    # scanned leading dim unsharded; in=FSDP(data), out=model
    assert wq[0] is None
    assert wq[1] == ("data",) or wq[1] == "data"
    assert wq[2] == "model"
    emb = specs["embed/embedding"]
    assert "model" in str(emb)


def test_specs_never_exceed_rank_or_reuse_axes():
    cfg = tiny_model_config(d_model=256, num_heads=16, num_kv_heads=16,
                            d_ff=512, vocab_size=512, family="moe",
                            moe_num_experts=16, moe_top_k=2, moe_d_ff=256)
    shapes = _params_shapes(cfg)
    for key, spec in _flat(param_specs(shapes, cfg, PAR, MESH)).items():
        leaf = _flat(shapes)[key]
        assert len(spec) <= len(leaf.shape), key
        axes = []
        for s in spec:
            if s is None:
                continue
            axes.extend(s if isinstance(s, tuple) else (s,))
        assert len(axes) == len(set(axes)), f"axis reuse in {key}: {spec}"


def test_divisibility_guard():
    # d_model=100 is not divisible by 16 -> must not shard over model
    cfg = tiny_model_config(d_model=100, num_heads=4, num_kv_heads=4, d_ff=96)
    shapes = _params_shapes(cfg)
    specs = _flat(param_specs(shapes, cfg, PAR, MESH))
    wq = specs["blocks/sub0/attn/wq"]
    assert wq[1] is None or wq[1] == ("data",)  # 100 % 16 != 0 on in-dim? 100%... data=16: no
    # out dim 4*25=100 -> not divisible by model=16 either
    assert wq[2] is None


def test_multipod_fsdp_uses_pod_and_data():
    cfg = tiny_model_config(d_model=256, num_heads=16, num_kv_heads=16,
                            d_ff=1024, vocab_size=512)
    shapes = _params_shapes(cfg)
    specs = _flat(param_specs(shapes, cfg, PAR, MESH_MP))
    wq = specs["blocks/sub0/attn/wq"]
    assert wq[1] == ("pod", "data")


def test_train_state_specs_cover_optimizer_slots():
    cfg = tiny_model_config(d_model=256, num_heads=16, num_kv_heads=16,
                            d_ff=512, vocab_size=512)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                    mesh=MeshConfig((16, 16), ("data", "model")),
                    parallel=PAR, train=TrainConfig(optimizer="adamw"))
    model = build_model(cfg, PAR)
    opt = make_optimizer(run.train)
    state = jax.eval_shape(
        lambda: init_train_state(model, run, opt, jax.random.PRNGKey(0)))
    specs = train_state_specs(state, cfg, PAR, MESH)
    pf, mf = _flat(specs.params), _flat(specs.opt_state)
    # adamw m/v mirror the param specs exactly
    for k, spec in pf.items():
        assert mf[f"m/{k}"] == spec
        assert mf[f"v/{k}"] == spec
    assert specs.step == P()


def test_train_state_specs_adafactor_factored():
    cfg = tiny_model_config(d_model=256, num_heads=16, num_kv_heads=16,
                            d_ff=512, vocab_size=512)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                    mesh=MeshConfig((16, 16), ("data", "model")),
                    parallel=PAR, train=TrainConfig(optimizer="adafactor"))
    model = build_model(cfg, PAR)
    opt = make_optimizer(run.train)
    state = jax.eval_shape(
        lambda: init_train_state(model, run, opt, jax.random.PRNGKey(0)))
    specs = train_state_specs(state, cfg, PAR, MESH)
    pf, sf = _flat(specs.params), _flat(specs.opt_state)
    wq_spec = tuple(pf["blocks/sub0/attn/wq"])
    assert tuple(sf["slots/blocks/sub0/attn/wq/vr"]) == wq_spec[:-1]
    assert tuple(sf["slots/blocks/sub0/attn/wq/vc"]) == wq_spec[:-2] + wq_spec[-1:]


def test_cache_specs_batch_and_heads():
    cfg = tiny_model_config(d_model=256, num_heads=16, num_kv_heads=16,
                            d_ff=512)
    model = build_model(cfg)
    caches = jax.eval_shape(lambda: model.init_decode_state(256, 64))
    specs = _flat(cache_specs(caches, cfg, PAR, MESH))
    k_spec = next(v for kk, v in specs.items() if kk.endswith("/k"))
    # (layers, batch, seq, heads, dim): batch over data, heads/dim over model
    assert k_spec[1] in (("data",), "data")
    assert "model" in str(k_spec)


def test_batch_specs():
    tree = {"inputs": jax.ShapeDtypeStruct((256, 64), jnp.int32),
            "odd": jax.ShapeDtypeStruct((3, 5), jnp.float32)}
    specs = batch_specs(tree, MESH)
    assert specs["inputs"] == P(("data",), None)
    assert specs["odd"] == P(None, None)
