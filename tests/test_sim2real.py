"""Sim-to-real serving loop: ReplayServingEnv measures the real batcher
over the same configuration surface as the simulator env, make_sim2real_pair
shares one trace realization, transfer_tune runs simulator-source ->
replay-target end-to-end, the sim2real benchmark document + gate, and the
serve launcher's --sim2real-eval report."""

import dataclasses

import numpy as np
import pytest

from repro.envs.measure import KernelWorkload
from repro.envs.replay_env import (REPLAY_COUNTER_NAMES, ReplayServingEnv,
                                   default_replay_model, make_sim2real_pair)
from repro.envs.serving_env import ServingEnv
from repro.tuner.bench import (Sim2RealCell, make_sim2real_bench_pair,
                               run_sim2real_bench, sim2real_cell_by_name)
from repro.tuner.runner import transfer_tune
from repro.workloads import (RequestSpec, ServingPlan, Trace,
                             SIM_COUNTER_NAMES, make_workload)

SPEC = ("poisson:rate=1200,horizon=0.003,mean_prompt=5,mean_output=3,"
        "max_len=12")


def _pair(**kw):
    return make_sim2real_pair(SPEC, seed=0, trace_seed=0, **kw)


# --------------------------------------------------------------------------
# environment basics
# --------------------------------------------------------------------------

def test_pair_shares_space_and_trace():
    src, tgt = _pair()
    assert isinstance(src, ServingEnv) and isinstance(tgt, ReplayServingEnv)
    assert src.space.names == tgt.space.names
    assert src.trace == tgt.trace          # the IDENTICAL realization
    assert {"serving.num_slots", "serving.cache_len",
            "flash_attention.q_block"} <= set(tgt.space.names)
    # counter names transfer: everything the simulator-trained causal model
    # conditions on exists in the replay measurement too
    assert set(SIM_COUNTER_NAMES) <= set(tgt.counter_names)
    assert tgt.query_text == "minimize latency within {budget} samples"


def test_replay_measurement_finite_and_deterministic_scheduling():
    _, tgt = _pair(repeats=1)
    cfg = tgt.space.default_config()
    c1, y1 = tgt.intervene(cfg)
    c2, y2 = tgt.intervene(cfg)
    assert np.isfinite(y1) and y1 > 0 and np.isfinite(y2)
    assert set(REPLAY_COUNTER_NAMES) <= set(c1)
    assert {"latency", "throughput"} <= set(c1)
    # wall-clock y varies, but each intervention deploys onto a FRESH
    # batcher: the scheduling trajectory (and so every deterministic
    # counter) is identical across measurements of one configuration
    for name in ("queue_depth_mean", "queue_depth_max", "occupancy_mean",
                 "rejected_rate"):
        assert c1[name] == c2[name], name


def test_interleave_policy_reaches_the_replay_batcher():
    # the tuned serving.interleave knob must change the REAL deployment's
    # scheduling, not just the simulator's price: under 2 slots the trace
    # queues, and drain admission yields a different trajectory than eager
    _, tgt = _pair(repeats=1)
    base = dict(tgt.space.default_config(), **{"serving.num_slots": 2})
    eager = tgt.replay(dict(base, **{"serving.interleave": "eager"}))
    drain = tgt.replay(dict(base, **{"serving.interleave": "drain"}))
    assert eager.completed == drain.completed == len(tgt.trace)
    assert (eager.ticks, eager.mean_occupancy, eager.queue_depth_mean) != \
        (drain.ticks, drain.mean_occupancy, drain.queue_depth_mean)


def test_ticks_per_s_pinned_across_configurations():
    # the arrival schedule is part of the environment — it must not drift
    # with the candidate's num_slots
    _, tgt = _pair()
    assert tgt.ticks_per_s > 0
    from repro.serving.replay import default_ticks_per_s

    assert tgt.ticks_per_s == default_ticks_per_s(tgt.trace,
                                                  ServingPlan().num_slots)


def test_infeasible_gates_are_analytic_and_direction_aware():
    long_trace = Trace("k", "k", 0, (RequestSpec(0, 0.0, 120, 20),))
    tgt = ReplayServingEnv(long_trace, seed=0)
    small = dict(tgt.space.default_config(), **{"serving.cache_len": 128})
    assert tgt.infeasible_reason(small) == "cache_len"
    _, y = tgt.intervene(small)            # gated BEFORE any batcher runs
    assert y == float("inf")
    tgt_max = ReplayServingEnv(long_trace, seed=0, objective="throughput")
    _, y_max = tgt_max.intervene(small)
    assert y_max == float("-inf")
    assert "maximize throughput" in tgt_max.query_text
    # modeled VMEM overflow is infeasible without deploying, like the sim
    tiny_vmem = ReplayServingEnv(
        long_trace, seed=0,
        cell=dataclasses.replace(KernelWorkload(), vmem_limit=1))
    big = dict(tgt.space.default_config(), **{"serving.cache_len": 2048})
    assert tiny_vmem.infeasible_reason(big) == "vmem"
    with pytest.raises(ValueError, match="unknown serving objective"):
        ReplayServingEnv(long_trace, objective="energy")


def test_deployment_is_fixed_across_env_seeds():
    a = ReplayServingEnv(SPEC, seed=3, trace_seed=0)
    b = ReplayServingEnv(SPEC, seed=4, trace_seed=0)
    # model identity is shared (cached build): the deployment does not vary
    # with the tuning seed, and neither does the compile cache
    assert a.model is b.model and a.params is b.params
    assert a.trace == b.trace


# --------------------------------------------------------------------------
# transfer end-to-end: simulator source -> replay target
# --------------------------------------------------------------------------

def test_transfer_tune_sim_source_replay_target():
    src, tgt = _pair(repeats=1)
    res = transfer_tune("cameo", src, tgt, budget=2, n_source=24,
                        n_target_init=2, query_text=tgt.query_text, seed=0)
    assert res.best_config is not None
    assert np.isfinite(res.best_y) and res.best_y > 0
    assert len(res.trace_best_y) == 2
    # the winner deploys: plan + launch halves split cleanly
    plan = ReplayServingEnv.plan_of(res.best_config)
    assert plan.num_slots >= 1
    assert all(not k.startswith("serving.") for k in res.launch_config)
    rep = tgt.replay(res.best_config)
    assert rep.completed > 0


# --------------------------------------------------------------------------
# benchmark sweep document
# --------------------------------------------------------------------------

def test_sim2real_bench_document_shape_and_gate():
    import json

    cell = Sim2RealCell("tiny", SPEC)
    doc = run_sim2real_bench(cells=(cell,), methods=("cameo", "random"),
                             budget=2, n_source=16, n_target_init=2,
                             seeds=(0,), pool=3, repeats=1)
    json.dumps(doc)  # JSON-clean
    assert doc["meta"]["workloads"] == [SPEC]
    (out,) = doc["cells"]
    assert out["cell"] == "tiny" and out["workload"] == SPEC
    assert out["y_opt"] > 0
    assert out["y_default"] is None or out["y_default"] > 0
    for stats in out["methods"].values():
        (run,) = stats["runs"]
        assert len(run["regret"]) == len(run["best_y_trace"]) == 2
        tail = [r for r in run["regret"] if r is not None]
        assert all(r >= 0 for r in tail)
        assert all(a >= b - 1e-12 for a, b in zip(tail, tail[1:]))
    assert doc["gate"]["checked"]
    assert {"champion_mean_final_regret",
            "reference_mean_final_regret"} <= set(doc["gate"])


def test_sim2real_cell_lookup_and_bench_pair():
    assert sim2real_cell_by_name("tiny-poisson").workload.startswith(
        "poisson:")
    with pytest.raises(ValueError, match="unknown sim2real cell"):
        sim2real_cell_by_name("nope")
    src, tgt = make_sim2real_bench_pair(Sim2RealCell("tiny", SPEC), seed=0)
    assert src.space.names == tgt.space.names
    assert src.trace == tgt.trace


# --------------------------------------------------------------------------
# launcher: --sim2real-eval
# --------------------------------------------------------------------------

def test_serve_sim2real_eval_reports_both_sides(capsys):
    import jax
    from conftest import tiny_model_config
    from repro.launch.serve import serve_workload
    from repro.models.model import build_model
    from repro.utils.config import RunConfig, ShapeConfig

    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 64, 4, "decode"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spec = ("poisson:rate=2000,horizon=0.005,mean_prompt=5,"
            "mean_output=3,max_len=12")
    plan, launch, report = serve_workload(model, run, params, spec,
                                          tune_budget=0, seed=0,
                                          sim2real_eval=True)
    out = capsys.readouterr().out
    assert "sim2real" in out and "sim-predicted" in out
    assert "replayed-actual" in out
    assert report.completed > 0


def test_predicted_serving_report_matches_simulator():
    from repro.launch.tune import predicted_serving_report

    cfg = default_replay_model()
    trace = make_workload(SPEC).generate(0)
    rep = predicted_serving_report(cfg, trace, None)
    assert rep.feasible and rep.completed == len(trace)
    assert rep.p99_latency_us > 0
