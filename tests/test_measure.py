"""Measurement-backend subsystem: timing-harness invariants (median-of-k,
warmup exclusion, fake-clock determinism — as hypothesis properties when the
dev dep is installed, with deterministic counterparts that always run),
bit-identity of AnalyticBackend against the pre-refactor KernelLaunchEnv
measurement, backend selection precedence, and wall-clock measurement of the
real kernels."""

import itertools

import numpy as np
import pytest

from repro.envs.kernel_launch import KernelLaunchEnv, KernelWorkload
from repro.envs.measure import (
    ANALYTIC, BF16, F32, HBM_BYTES_PER_US, LANE, MEASURE_BACKEND_ENV,
    MXU_FLOPS_PER_US, VPU_FLOPS_PER_US, WALLCLOCK, AnalyticBackend, FakeClock,
    TimingResult, WallClockBackend, make_backend, resolve_backend_name, timeit)
from repro.kernels import dispatch

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # dev-only dep: pip install -r requirements-dev.txt
    HAVE_HYPOTHESIS = False

TINY = KernelWorkload(name="tiny", batch=1, seq_len=64, heads=2, kv_heads=1,
                      head_dim=16, d_model=32, channels=64, scan_state=4,
                      ssm_heads=2, ssm_head_dim=16, ssm_state=8, noise=0.0)

# the families the pre-refactor measurement modeled — paged_attention joined
# the registry later and has no config-independent representative inputs
# (the KV pool shape IS the launch config), so the wall-clock backend cannot
# time it standalone either; it is measured through ReplayServingEnv instead
LEGACY_FAMS = ["flash_attention", "mamba_scan", "rmsnorm", "ssd"]


# --------------------------------------------------------------------------
# timing harness — deterministic
# --------------------------------------------------------------------------

def test_fake_clock_scripted_sequence():
    clk = FakeClock([1.0, 2.0], start=10.0)
    assert [clk() for _ in range(4)] == [10.0, 11.0, 13.0, 14.0]
    assert clk.calls == 4
    with pytest.raises(ValueError):
        FakeClock([])


def _script(deltas):
    """Clock deltas such that timed run i measures exactly ``deltas[i]`` —
    each run brackets with two clock calls, so interleave zero-length gaps."""
    return [x for d in deltas for x in (d, 0.0)]


def test_timeit_counts_and_warmup_exclusion():
    # warmup runs see huge deltas; measured runs see 1ms — the median must
    # only reflect the measured samples
    clk = FakeClock(_script([5.0, 5.0] + [1e-3] * 3))
    res = timeit(lambda: 0, warmup=2, repeats=3, clock=clk, block=False)
    assert len(res.warmup_us) == 2 and len(res.samples_us) == 3
    assert res.warmup_us == (5e6, 5e6)
    assert res.median_us == pytest.approx(1e3)
    assert clk.calls == 10  # 2 calls per run, warmup included
    with pytest.raises(ValueError):
        timeit(lambda: 0, repeats=0, clock=clk, block=False)


def test_timeit_median_permutation_invariant_deterministic():
    deltas = [1e-3, 5e-3, 2e-3, 9e-3, 4e-3]
    medians = []
    for perm in itertools.permutations(deltas):
        res = timeit(lambda: 0, warmup=0, repeats=5,
                     clock=FakeClock(_script(perm)), block=False)
        medians.append(res.median_us)
    # invariant up to clock-accumulation ulps (~1e-9 us here)
    assert max(medians) - min(medians) < 1e-6
    assert medians[0] == pytest.approx(4e3)


def test_timeit_fake_clock_deterministic():
    runs = [timeit(lambda: 0, warmup=1, repeats=4,
                   clock=FakeClock(_script([3e-3, 1e-3, 2e-3])), block=False)
            for _ in range(2)]
    assert runs[0] == runs[1]


def test_timing_result_stats():
    r = TimingResult((4.0, 1.0, 3.0))
    assert r.median_us == 3.0 and r.best_us == 1.0
    assert r.mean_us == pytest.approx(8.0 / 3.0)


# --------------------------------------------------------------------------
# timing harness — hypothesis properties (dev environments / CI)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    deltas_s = st.lists(
        st.floats(min_value=1e-6, max_value=10.0, allow_nan=False,
                  allow_infinity=False),
        min_size=1, max_size=8)

    @given(deltas_s, st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_prop_median_invariant_under_permutation(deltas, seed):
        rng = np.random.default_rng(seed)
        perm = list(rng.permutation(deltas))
        base = timeit(lambda: 0, warmup=0, repeats=len(deltas),
                      clock=FakeClock(_script(deltas)), block=False)
        shuf = timeit(lambda: 0, warmup=0, repeats=len(perm),
                      clock=FakeClock(_script(perm)), block=False)
        # clock-accumulation ulps scale with total elapsed time: rel 1e-6
        # leaves ~100x margin over the worst case for these domains
        assert base.median_us == pytest.approx(shuf.median_us, rel=1e-6)

    @given(deltas_s, deltas_s)
    @settings(max_examples=25, deadline=None)
    def test_prop_warmup_samples_excluded(warm_deltas, meas_deltas):
        with_warm = timeit(
            lambda: 0, warmup=len(warm_deltas), repeats=len(meas_deltas),
            clock=FakeClock(_script(warm_deltas + meas_deltas)), block=False)
        without = timeit(lambda: 0, warmup=0, repeats=len(meas_deltas),
                         clock=FakeClock(_script(meas_deltas)), block=False)
        assert len(with_warm.warmup_us) == len(warm_deltas)
        assert with_warm.samples_us == pytest.approx(without.samples_us)

    @given(deltas_s, st.integers(1, 4), st.integers(1, 6))
    @settings(max_examples=25, deadline=None)
    def test_prop_fake_clock_determinism(deltas, warmup, repeats):
        a = timeit(lambda: 0, warmup=warmup, repeats=repeats,
                   clock=FakeClock(_script(deltas)), block=False)
        b = timeit(lambda: 0, warmup=warmup, repeats=repeats,
                   clock=FakeClock(_script(deltas)), block=False)
        assert a == b


# --------------------------------------------------------------------------
# AnalyticBackend — bit-identical to the pre-refactor measurement
# --------------------------------------------------------------------------

def _frozen_pre_refactor_measure(w, families, config, noise_rng):
    """Verbatim copy of KernelLaunchEnv._measure (and its geometry methods)
    as shipped before the backend refactor — the bit-identity oracle."""
    ceil_div = lambda a, b: -(-a // b)  # noqa: E731
    padded = lambda a, b: ceil_div(a, b) * b  # noqa: E731

    def mxu_util(*dims):
        u = 1.0
        for d in dims:
            u *= min(d, LANE) / LANE
        return max(u, 1e-3)

    def params_of(family):
        fam = dispatch.get_family(family)
        out = {o.name: o.default for o in fam.launch_options}
        for o in fam.launch_options:
            key = f"{family}.{o.name}"
            if key in config:
                out[o.name] = config[key]
        return out

    def flash_attention(p):
        qb, kb = int(p["q_block"]), int(p["kv_block"])
        sq, sk = padded(w.seq_len, qb), padded(w.seq_len, kb)
        grid = w.batch * w.heads * (sq // qb) * (sk // kb)
        flops = 0.5 * w.batch * w.heads * sq * sk * 4 * w.head_dim
        vmem = (BF16 * 2 * (qb + 2 * kb) * w.head_dim
                + BF16 * 2 * qb * w.head_dim
                + F32 * qb * (w.head_dim + 2 * LANE))
        hbm = F32 * grid * (qb + 2 * kb) * w.head_dim / 2 + F32 * sq * w.head_dim
        t = (grid * w.launch_overhead_us
             + flops / (MXU_FLOPS_PER_US * mxu_util(qb, kb))
             + hbm / HBM_BYTES_PER_US)
        return t, grid, vmem, flops, hbm

    def mamba_scan(p):
        chunk, cb = int(p["chunk"]), int(p["c_block"])
        l = padded(w.seq_len, chunk)
        grid = w.batch * ceil_div(w.channels, cb) * (l // chunk)
        flops = 8.0 * w.batch * l * w.channels * w.scan_state
        vmem = (BF16 * 2 * chunk * (3 * cb + 2 * w.scan_state)
                + BF16 * 2 * chunk * cb
                + F32 * cb * w.scan_state)
        hbm = F32 * w.batch * l * (3 * w.channels + 2 * w.scan_state)
        serial = grid * chunk * (cb * w.scan_state / VPU_FLOPS_PER_US) * 1e-3
        t = grid * w.launch_overhead_us + serial + hbm / HBM_BYTES_PER_US
        return t, grid, vmem, flops, hbm

    def ssd(p):
        chunk = int(p["chunk"])
        l = padded(w.seq_len, chunk)
        grid = w.batch * w.ssm_heads * (l // chunk)
        n, hd = w.ssm_state, w.ssm_head_dim
        flops = grid * (2 * chunk * chunk * (n + hd) + 4 * chunk * n * hd)
        vmem = (BF16 * 2 * chunk * (hd + 2 * n) + BF16 * 2 * chunk * hd
                + F32 * (chunk * chunk + n * hd))
        hbm = F32 * w.batch * l * w.ssm_heads * (hd + 2 * n // max(w.ssm_heads // 8, 1))
        t = (grid * w.launch_overhead_us
             + flops / (MXU_FLOPS_PER_US * mxu_util(chunk))
             + hbm / HBM_BYTES_PER_US)
        return t, grid, vmem, flops, hbm

    def rmsnorm(p):
        rb = int(p["row_block"])
        rows = padded(w.batch * w.seq_len, rb)
        grid = rows // rb
        flops = 4.0 * rows * w.d_model
        vmem = BF16 * (2 * 2 * rb * w.d_model + w.d_model)
        hbm = F32 * rows * w.d_model * 2
        t = grid * w.launch_overhead_us + hbm / HBM_BYTES_PER_US
        return t, grid, vmem, flops, hbm

    models = {"flash_attention": flash_attention, "mamba_scan": mamba_scan,
              "ssd": ssd, "rmsnorm": rmsnorm}
    total_us, grid_pts, vmem_peak, flops, hbm = 0.0, 0.0, 0.0, 0.0, 0.0
    feasible = True
    for family in families:
        t, grid, vmem, fl, hb = models[family](params_of(family))
        total_us += t
        grid_pts += grid
        vmem_peak = max(vmem_peak, vmem)
        flops += fl
        hbm += hb
        if vmem > w.vmem_limit:
            feasible = False
    counters = {"grid_points": grid_pts, "vmem_peak_bytes": vmem_peak,
                "hbm_bytes": hbm, "flops": flops}
    if not feasible:
        return counters, float("inf")
    y = total_us * (1.0 + w.noise * float(noise_rng.standard_normal()))
    return counters, y


def _pinned_grid(seed=7, n=40):
    space = dispatch.launch_space()
    rng = np.random.default_rng(seed)
    mins = {o.name: o.values[0] for o in space.options}
    maxs = {o.name: o.values[-1] for o in space.options}
    return [space.default_config(), mins, maxs] + space.sample(rng, n)


@pytest.mark.parametrize("workload", [
    KernelWorkload(),                                      # default serve-8b
    KernelWorkload(name="train-2k", batch=16, seq_len=2048),
    # tight VMEM budget: part of the grid goes infeasible, exercising the
    # no-noise-draw path of the RNG stream
    KernelWorkload(name="tight", vmem_limit=2 * 2 ** 20),
], ids=lambda w: w.name)
def test_analytic_backend_bit_identical_to_pre_refactor(workload):
    families = LEGACY_FAMS
    backend = AnalyticBackend(workload, families, seed=0)
    oracle_rng = np.random.default_rng(0 + 13)
    saw_infeasible = False
    for config in _pinned_grid():
        counters, y = backend.measure(config)
        exp_counters, exp_y = _frozen_pre_refactor_measure(
            workload, families, config, oracle_rng)
        assert counters == exp_counters, config
        if np.isinf(exp_y):
            saw_infeasible = True
            assert np.isinf(y)
        else:
            assert y == exp_y, config  # bit-identical, not approx
    if workload.name == "tight":
        assert saw_infeasible


def test_kernel_launch_env_delegates_to_analytic_backend():
    env = KernelLaunchEnv(seed=3)
    backend = AnalyticBackend(KernelWorkload(), sorted(dispatch.families()),
                              seed=3)
    for config in _pinned_grid(seed=11, n=8):
        assert env.intervene(config) == backend.measure(config)


# --------------------------------------------------------------------------
# backend selection
# --------------------------------------------------------------------------

def test_backend_selection_precedence(monkeypatch):
    fams = sorted(dispatch.families())
    assert resolve_backend_name(None) == ANALYTIC
    monkeypatch.setenv(MEASURE_BACKEND_ENV, WALLCLOCK)
    assert resolve_backend_name(None) == WALLCLOCK
    assert resolve_backend_name(ANALYTIC) == ANALYTIC  # explicit beats env
    assert isinstance(make_backend(None, TINY, fams), WallClockBackend)
    assert isinstance(KernelLaunchEnv(TINY).backend, WallClockBackend)
    monkeypatch.setenv(MEASURE_BACKEND_ENV, "bogus")
    with pytest.raises(ValueError):
        resolve_backend_name(None)
    monkeypatch.delenv(MEASURE_BACKEND_ENV)
    assert isinstance(make_backend(None, TINY, fams), AnalyticBackend)
    with pytest.raises(ValueError):
        make_backend("bogus", TINY, fams)


def test_env_accepts_backend_instance():
    fams = sorted(dispatch.families())
    inst = AnalyticBackend(TINY, fams, seed=0)
    env = KernelLaunchEnv(TINY, backend=inst)
    assert env.backend is inst
    with pytest.raises(ValueError):
        KernelLaunchEnv(TINY, backend=inst, backend_opts={"repeats": 2})


def test_env_space_follows_backend_instance_families():
    # the instance is authoritative: a backend measuring only rmsnorm must
    # not expose flash_attention/ssm knobs the measurement ignores
    inst = AnalyticBackend(TINY, ["rmsnorm"], seed=0)
    env = KernelLaunchEnv(TINY, backend=inst)
    assert env.families == ["rmsnorm"]
    assert env.space.names == ["rmsnorm.row_block"]
    assert env.counter_names == tuple(inst.counter_names)
    with pytest.raises(ValueError, match="conflict"):
        KernelLaunchEnv(TINY, families=["rmsnorm", "ssd"], backend=inst)


def test_unmodeled_family_rejected():
    with pytest.raises(ValueError, match="launch-geometry"):
        KernelLaunchEnv(TINY, families=["flash_attention", "nope"])


# --------------------------------------------------------------------------
# wall-clock backend
# --------------------------------------------------------------------------

def test_wallclock_fake_clock_deterministic_and_counters_match():
    fams = LEGACY_FAMS
    config = dispatch.launch_space().default_config()
    ys = []
    for _ in range(2):
        b = WallClockBackend(TINY, fams, seed=0, warmup=0, repeats=3,
                             clock=FakeClock([1e-3, 3e-3, 2e-3]))
        counters, y = b.measure(config)
        ys.append(y)
        # counters are the geometry model's — identical to analytic
        a_counters, _ = AnalyticBackend(TINY, fams, seed=0).measure(config)
        assert counters == a_counters
    assert ys[0] == ys[1]
    # 4 families x 3 repeats x 2 clock calls, no warmup
    assert ys[0] == pytest.approx(4 * 2e3)


def test_wallclock_infeasible_short_circuits_without_timing():
    clk = FakeClock([1e-3])
    tight = KernelWorkload(name="tight", batch=1, seq_len=64, heads=2,
                           kv_heads=1, head_dim=16, d_model=32, channels=64,
                           scan_state=4, ssm_heads=2, ssm_head_dim=16,
                           ssm_state=8, vmem_limit=1)
    b = WallClockBackend(tight, ["rmsnorm"], clock=clk)
    counters, y = b.measure({"rmsnorm.row_block": 512})
    assert np.isinf(y)
    assert clk.calls == 0  # never ran nor timed the kernel


def test_wallclock_paged_attention_has_no_representative_inputs():
    # paged_attention's working set is the launch config (pool/page shapes),
    # so there is no standalone input set to time — the backend says so and
    # points at the serving-level measurement path
    b = WallClockBackend(TINY, ["paged_attention"], seed=0, warmup=0,
                         repeats=1, clock=FakeClock([1e-3]))
    with pytest.raises(KeyError, match="ReplayServingEnv"):
        b.measure(dispatch.launch_space().default_config())


def test_wallclock_candidate_outranks_active_config():
    # measuring while a tuned config is installed (e.g. re-tuning inside
    # result.install()) must still time the CANDIDATE's launch params
    b = WallClockBackend(TINY, ["rmsnorm"], seed=0, warmup=0, repeats=1,
                         clock=FakeClock([1e-3]))
    with dispatch.use_launch_config({"rmsnorm.row_block": 64}):
        with dispatch.record_resolutions() as rec:
            b.measure({"rmsnorm.row_block": 512})
    resolved = [r.launch["row_block"] for r in rec if r.family == "rmsnorm"]
    assert resolved and all(v == 512 for v in resolved)


def test_wallclock_real_measurement_on_ref_kernels():
    # ref mode on CPU: small but real jitted executions, real perf_counter
    env = KernelLaunchEnv(TINY, families=LEGACY_FAMS, backend="wallclock",
                          backend_opts={"warmup": 1, "repeats": 3})
    c1, y1 = env.intervene(env.space.default_config())
    assert np.isfinite(y1) and y1 > 0
    c2, y2 = env.intervene({"flash_attention.q_block": 128,
                            "mamba_scan.chunk": 64})
    assert np.isfinite(y2) and y2 > 0
    assert c1 != c2  # geometry counters move with the config


@pytest.mark.wallclock
def test_wallclock_backend_across_config_grid():
    """Second-tier CI job: REPRO_KERNEL_MODE=pallas_interpret exercises the
    Pallas kernels themselves (interpreted on CPU) under timed dispatch."""
    env = KernelLaunchEnv(TINY, families=LEGACY_FAMS, backend="wallclock",
                          backend_opts={"warmup": 1, "repeats": 2})
    rng = np.random.default_rng(0)
    for config in [env.space.default_config()] + env.space.sample(rng, 3):
        counters, y = env.intervene(config)
        assert np.isfinite(y) and y > 0, config
        assert counters["grid_points"] > 0


@pytest.mark.wallclock
def test_wallclock_dataset_feeds_tuner():
    env = KernelLaunchEnv(TINY, families=LEGACY_FAMS, backend="wallclock",
                          backend_opts={"warmup": 0, "repeats": 1})
    d = env.dataset(3, seed=0)
    assert len(d) == 3 and all(np.isfinite(v) for v in d.ys)
