"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device by
design (the 512-device flag belongs exclusively to repro.launch.dryrun)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def tiny_model_config(**kw):
    from repro.utils.config import ModelConfig

    base = dict(vocab_size=64, d_model=32, num_heads=4, num_kv_heads=2,
                d_ff=64, num_layers=2, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)
