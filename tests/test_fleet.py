"""Sharded serving fleet: router policies over per-replica batchers, the
fleet-runtime fixes they depend on (per-host straggler seeding, mesh-shape
divisor degradation), fleet disruption shifts (straggler/resize), the fleet
environments (simulator + replay) end to end, and the counter audit keeping
objective clones out of the causal-discovery variables."""

import dataclasses

import numpy as np
import pytest

from conftest import tiny_model_config
from repro.envs.measure import (KernelWorkload, backend_names, make_backend,
                                shift_kinds, shifts_for)
from repro.envs.replay_env import (REPLAY_FLEET_COUNTER_NAMES,
                                   ReplayServingEnv, make_sim2real_pair)
from repro.envs.serving_env import ServingEnv, fleet_spec_for, make_fleet_pair
from repro.runtime.elastic import adjust_run_for_devices, viable_mesh_shape
from repro.runtime.straggler import StragglerMonitor
from repro.tuner.space import launch_config_of
from repro.utils.config import (MeshConfig, ParallelConfig, RunConfig,
                                ShapeConfig)
from repro.workloads import (FLEET_COUNTER_NAMES, FleetPlan, FleetReport,
                             FleetSimulator, FleetSpec, ServingPlan,
                             ServingSimulator, make_workload, serving_space,
                             tp_speedup)

TINY_CELL = KernelWorkload(name="tiny", batch=1, seq_len=128, heads=2,
                           kv_heads=1, head_dim=16, d_model=64, channels=64,
                           scan_state=4, ssm_heads=2, ssm_head_dim=16,
                           ssm_state=8)
FAMS = ("flash_attention", "rmsnorm")
SPEC = ("bursty:rate=2500,burst=4,horizon=0.02,mean_prompt=32,"
        "mean_output=16,max_len=96")


def _trace(seed=0):
    return make_workload(SPEC).generate(seed)


def _fleet_sim(**kw):
    kw.setdefault("fleet", FleetSpec(num_devices=8))
    return FleetSimulator(TINY_CELL, FAMS, **kw)


# --------------------------------------------------------------------------
# straggler monitor: partial reports (the bugfix)
# --------------------------------------------------------------------------

def test_straggler_partial_reports_seed_per_host():
    """A late joiner's first report seeds its OWN EWMA — the old global
    `_seen` flag blended every later host up from 0.0."""
    mon = StragglerMonitor(3)
    mon.report({0: 1.0, 1: 1.0})           # host 2 idle this step
    mon.report({0: 1.0, 1: 1.0, 2: 1.0})   # late joiner
    assert mon._ewma[2] == 1.0             # seeded, not 0.8 * 0 + 0.2 * 1
    assert mon.flagged() == []


def test_straggler_median_ignores_silent_hosts():
    """Hosts that never report stay out of the fleet median — under the old
    all-hosts median, 2 silent hosts out of 4 pinned the median at 0.5x and
    flagged every healthy host."""
    mon = StragglerMonitor(4)
    for _ in range(5):
        mon.report({0: 1.0, 1: 1.0})       # hosts 2, 3 never report
    assert mon.flagged() == []
    assert mon._median() == 1.0


def test_straggler_silent_host_never_flagged():
    mon = StragglerMonitor(3)
    for _ in range(10):
        mon.report({0: 1.0, 1: 5.0})
    assert 1 in mon.flagged()
    assert 2 not in mon.flagged()          # no report -> no flag


def test_straggler_exclusion_after_patience():
    mon = StragglerMonitor(4, patience=3)
    for i in range(3):
        mon.report({0: 1.0, 1: 1.0, 2: 1.0, 3: 9.0})
        assert mon.should_exclude(3) == (i >= 2)
    assert mon.excluded() == [3]
    # recovery clears the streak
    for _ in range(30):
        mon.report({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0})
    assert mon.excluded() == []


def test_straggler_empty_report_is_noop():
    mon = StragglerMonitor(2)
    mon.report({})
    assert mon.flagged() == [] and mon._median() == 0.0


# --------------------------------------------------------------------------
# mesh-shape divisor degradation + microbatch divisibility (the bugfix)
# --------------------------------------------------------------------------

def test_viable_mesh_shape_largest_divisor():
    # exact divisors keep the requested TP
    assert viable_mesh_shape(8, 4) == (2, 4)
    assert viable_mesh_shape(8, 8) == (1, 8)
    # degradation lands on the largest divisor <= the request — the old
    # halving walked 6 -> 3 -> 1 past the viable TP 4
    assert viable_mesh_shape(8, 6) == (2, 4)
    assert viable_mesh_shape(100, 16) == (10, 10)
    assert viable_mesh_shape(12, 9) == (2, 6)
    # clamping: request above device count, prime counts, degenerate TP
    assert viable_mesh_shape(4, 100) == (1, 4)
    assert viable_mesh_shape(7, 3) == (7, 1)
    assert viable_mesh_shape(5, 1) == (5, 1)
    with pytest.raises(ValueError):
        viable_mesh_shape(0, 4)


def test_adjust_run_for_devices_raises_when_batch_unsplittable():
    """data=3 x any power-of-two microbatch never divides global_batch=32:
    the old loop exited silently and handed back an invalid RunConfig."""
    run = RunConfig(model=tiny_model_config(),
                    shape=ShapeConfig("t", 32, 32, "train"),
                    mesh=MeshConfig((4, 1), ("data", "model")),
                    parallel=ParallelConfig(tp=1, microbatch=1))
    with pytest.raises(ValueError, match="global_batch"):
        adjust_run_for_devices(run, 3)
    # the same run on a dividing device count still adjusts cleanly
    new = adjust_run_for_devices(run, 8)
    assert new.mesh.num_devices == 8


# --------------------------------------------------------------------------
# fleet plan / spec / space plumbing
# --------------------------------------------------------------------------

def test_fleet_plan_from_config_and_validation():
    assert FleetPlan.from_config({}) == FleetPlan()
    plan = FleetPlan.from_config(
        {"fleet.num_replicas": 4, "fleet.routing": "power_of_two",
         "fleet.model_parallel": 2, "serving.num_slots": 8})
    assert plan == FleetPlan(num_replicas=4, routing="power_of_two",
                             model_parallel=2)
    with pytest.raises(ValueError, match="routing"):
        FleetPlan(routing="least_loaded")
    with pytest.raises(ValueError):
        FleetPlan(num_replicas=0)


def test_fleet_spec_validation():
    with pytest.raises(ValueError):
        FleetSpec(num_devices=0)
    with pytest.raises(ValueError):
        FleetSpec(num_devices=4, slow_devices=(4,))
    with pytest.raises(ValueError):
        FleetSpec(slowdown=0.5)


def test_serving_space_fleet_flag():
    flat = serving_space(FAMS)
    fleet = serving_space(FAMS, fleet=True)
    fleet_names = {"fleet.num_replicas", "fleet.routing",
                   "fleet.model_parallel"}
    assert not fleet_names & set(flat.names)
    assert fleet_names <= set(fleet.names)
    # the fleet space extends, not replaces, the serving space
    assert set(flat.names) <= set(fleet.names)


def test_launch_config_of_excludes_fleet_knobs():
    cfg = {"fleet.num_replicas": 4, "fleet.routing": "round_robin",
           "serving.num_slots": 8, "flash_attention.q_block": 64}
    assert launch_config_of(cfg) == {"flash_attention.q_block": 64}


def test_tp_speedup_sublinear():
    assert tp_speedup(1) == 1.0
    assert 1.0 < tp_speedup(2) < 2.0
    assert tp_speedup(2) < tp_speedup(4) < 4.0


def test_mesh_split_and_replica_hardware():
    sim = _fleet_sim(fleet=FleetSpec(num_devices=8, slow_devices=(5,),
                                     slowdown=2.0))
    plan = FleetPlan(num_replicas=4, model_parallel=2)
    assert sim.mesh_split(plan) == (1, 2)      # 2 devices per replica
    hw = sim.replica_hardware(plan)
    assert len(hw) == 4
    # replica 2 owns devices [4, 6) -> contains slow device 5
    base = sim.hardware.mxu_flops_per_us * tp_speedup(2)
    assert hw[0].mxu_flops_per_us == pytest.approx(base)
    assert hw[2].mxu_flops_per_us == pytest.approx(base / 2.0)
    assert hw[3].mxu_flops_per_us == pytest.approx(base)


# --------------------------------------------------------------------------
# router policies
# --------------------------------------------------------------------------

class _Stub:
    def __init__(self, backlog):
        self.backlog = backlog


def test_route_round_robin_exact():
    reps = [_Stub(9), _Stub(0), _Stub(0)]
    got = [FleetSimulator._route(k, reps, "round_robin", None)
           for k in range(7)]
    assert got == [0, 1, 2, 0, 1, 2, 0]    # ignores backlog by design


def test_route_jsq_deterministic_tie_break():
    reps = [_Stub(2), _Stub(1), _Stub(1)]
    assert FleetSimulator._route(0, reps, "join_shortest_queue", None) == 1
    reps = [_Stub(0), _Stub(0), _Stub(0)]
    assert FleetSimulator._route(5, reps, "join_shortest_queue", None) == 0


def test_route_power_of_two_seeded_and_tie_breaks_low():
    reps = [_Stub(3), _Stub(3), _Stub(3), _Stub(3)]
    # the probe sequence is a pure function of the rng state
    picks_a = [FleetSimulator._route(k, reps, "power_of_two",
                                     np.random.default_rng(7))
               for k in range(10)]
    picks_b = [FleetSimulator._route(k, reps, "power_of_two",
                                     np.random.default_rng(7))
               for k in range(10)]
    assert picks_a == picks_b
    # all tied: whichever pair is probed, the LOWER index wins
    rng = np.random.default_rng(3)
    pair = rng.choice(4, size=2, replace=False)
    assert FleetSimulator._route(0, reps, "power_of_two",
                                 np.random.default_rng(3)) == int(min(pair))
    # strictly smaller backlog in the probed pair wins
    reps = [_Stub(0), _Stub(9)]
    assert FleetSimulator._route(0, reps, "power_of_two",
                                 np.random.default_rng(0)) == 0


def test_route_unknown_policy_raises():
    # two replicas: a 1-replica fleet short-circuits before the policy check
    with pytest.raises(ValueError, match="routing policy"):
        FleetSimulator._route(0, [_Stub(0), _Stub(0)], "least_loaded", None)
    with pytest.raises(ValueError, match="routing policy"):
        FleetPlan(routing="least_loaded")


def test_round_robin_assignment_partition():
    sim = _fleet_sim()
    report = sim.run(_trace(), ServingPlan(),
                     FleetPlan(num_replicas=4, routing="round_robin"))
    assert report.feasible
    n = report.completed
    for r, idxs in enumerate(report.assignments):
        assert idxs == tuple(range(r, n, 4))


def test_power_of_two_deterministic_across_runs():
    sim = _fleet_sim()
    plan = FleetPlan(num_replicas=4, routing="power_of_two")
    a = sim.run(_trace(), ServingPlan(), plan)
    b = sim.run(_trace(), ServingPlan(), plan)
    assert a == b                          # frozen dataclass: bit-identical
    # a different trace seed draws a different probe sequence
    c = sim.run(_trace(seed=1), ServingPlan(), plan)
    assert c.assignments != a.assignments


def test_jsq_balances_heterogeneous_fleet():
    """JSQ routes away from the straggling replica; round-robin cannot.
    Needs a saturating arrival rate — when every replica drains between
    arrivals, all backlogs tie at zero and JSQ degenerates to the
    lowest-index tie-break."""
    dense = make_workload("poisson:rate=400000,horizon=0.002,mean_prompt=16,"
                          "mean_output=16,max_len=96").generate(0)
    spec = FleetSpec(num_devices=8, slow_devices=(0,), slowdown=50.0)
    sim = _fleet_sim(fleet=spec)
    rr = sim.run(dense, ServingPlan(),
                 FleetPlan(num_replicas=4, routing="round_robin"))
    jsq = sim.run(dense, ServingPlan(),
                  FleetPlan(num_replicas=4, routing="join_shortest_queue"))
    assert rr.feasible and jsq.feasible
    # replica 0 owns the slow device: JSQ sends it less than its even share
    assert len(jsq.assignments[0]) < len(rr.assignments[0])
    assert jsq.p99_latency_us < rr.p99_latency_us


# --------------------------------------------------------------------------
# fleet event loop vs the single simulator
# --------------------------------------------------------------------------

def test_single_replica_fleet_bit_identical_to_serving_sim():
    """fleet(R=1, mp=1, round_robin) must reproduce ServingSimulator.run
    field-for-field — the regression the fleet loop is held to."""
    trace = _trace()
    plan = ServingPlan()
    single = ServingSimulator(TINY_CELL, FAMS).run(trace, plan)
    fleet = _fleet_sim().run(trace, plan, FleetPlan(num_replicas=1,
                                                    model_parallel=1))
    for f in dataclasses.fields(single):
        assert getattr(fleet, f.name) == getattr(single, f.name), f.name
    assert fleet.num_replicas == 1
    assert fleet.assignments == (tuple(range(len(trace.requests))),)


def test_fleet_run_deterministic():
    sim = _fleet_sim(fleet=FleetSpec(num_devices=8, slow_devices=(2,),
                                     slowdown=3.0))
    plan = FleetPlan(num_replicas=4, routing="join_shortest_queue",
                     model_parallel=2)
    assert sim.run(_trace(), ServingPlan(), plan) == \
        sim.run(_trace(), ServingPlan(), plan)


def test_fleet_infeasible_reasons():
    sim = _fleet_sim(fleet=FleetSpec(num_devices=2))
    r = sim.run(_trace(), ServingPlan(), FleetPlan(num_replicas=4))
    assert not r.feasible and r.reason == "devices"
    r = sim.run(_trace(), ServingPlan(cache_len=16), FleetPlan())
    assert not r.feasible and r.reason == "cache_len"
    assert isinstance(r, FleetReport)
    # infeasible reports still carry every fleet counter
    assert set(FLEET_COUNTER_NAMES) <= set(r.counters())


def test_fleet_counters_and_straggler_mediator():
    spec = FleetSpec(num_devices=8, slow_devices=(0,), slowdown=50.0)
    report = _fleet_sim(fleet=spec).run(
        _trace(), ServingPlan(), FleetPlan(num_replicas=8))
    c = report.counters()
    assert set(FLEET_COUNTER_NAMES) <= set(c)
    assert c["routing_imbalance"] >= 1.0
    # an isolated heavy straggler among 8 replicas is flagged and, after
    # `patience` monitor rounds, marked for exclusion
    assert c["straggler_flagged"] >= 1.0
    assert 0 in report.straggler_excluded


# --------------------------------------------------------------------------
# fleet disruption shifts
# --------------------------------------------------------------------------

def test_disruption_shift_kinds_registered():
    assert {"straggler", "resize"} <= set(shift_kinds())
    assert {"shifted:straggler", "shifted:resize"} <= set(backend_names())
    (s,) = shifts_for("straggler")
    assert s.straggler_frac > 0 and s.straggler_slowdown > 1.0
    (s,) = shifts_for("resize")
    assert s.device_scale < 1.0


def test_disruption_shifts_usable_as_measurement_backends():
    """shifted:straggler / shifted:resize drop into the same kernel-grid
    backend plumbing as every other registered kind."""
    from repro.kernels import dispatch

    cfg = dispatch.launch_space(FAMS).default_config()
    for kind in ("shifted:straggler", "shifted:resize"):
        backend = make_backend(kind, TINY_CELL, FAMS, seed=0)
        counters, y = backend.measure(cfg)
        assert np.isfinite(y) and y > 0
        assert counters


def test_fleet_spec_for_composition_and_determinism():
    spec = fleet_spec_for(shifts_for("straggler"), num_devices=8)
    assert spec.num_devices == 8
    assert len(spec.slow_devices) == 2     # frac 0.25 of 8
    assert spec.slowdown == 3.0
    assert spec == fleet_spec_for(shifts_for("straggler"), num_devices=8)
    resized = fleet_spec_for(shifts_for("resize"), num_devices=8)
    assert resized == FleetSpec(num_devices=6)   # 0.75 * 8
    healthy = fleet_spec_for((), num_devices=8)
    assert healthy == FleetSpec(num_devices=8)
    # composition: resize shrinks the substrate the straggler draw sees
    both = fleet_spec_for(shifts_for("resize") + shifts_for("straggler"),
                          num_devices=8)
    assert both.num_devices == 6 and len(both.slow_devices) == 2


# --------------------------------------------------------------------------
# fleet environments end to end
# --------------------------------------------------------------------------

def test_serving_env_fleet_end_to_end():
    env = ServingEnv(SPEC, TINY_CELL, FAMS, seed=0, fleet=True)
    assert tuple(env.counter_names) == FLEET_COUNTER_NAMES
    assert {"fleet.num_replicas", "fleet.routing"} <= set(env.space.names)
    counters, y = env.intervene(env.space.default_config())
    assert np.isfinite(y) and y > 0
    assert set(env.counter_names) <= set(counters)
    # the counter audit: objective clones visible in metrics, OUT of the
    # causal-discovery variables
    assert {"latency", "throughput"} <= set(counters)
    assert not {"latency", "throughput"} & set(env.counter_names)


def test_make_fleet_pair_shares_trace_and_differs_in_disruption():
    src, tgt = make_fleet_pair(SPEC, "straggler", TINY_CELL, FAMS, seed=0)
    assert src.trace == tgt.trace          # identical realization
    assert src.space.names == tgt.space.names
    assert src.fleet_spec == FleetSpec(num_devices=8)
    assert tgt.fleet_spec.slow_devices     # target limps
    # resize shrinks the target's device budget instead
    _, tgt_rs = make_fleet_pair(SPEC, "resize", TINY_CELL, FAMS, seed=0)
    assert tgt_rs.fleet_spec == FleetSpec(num_devices=6)
    # the disruption moves the objective at the default config
    cfg = src.space.default_config()
    assert tgt.simulate(cfg).p99_latency_us > src.simulate(cfg).p99_latency_us


def test_fleet_pair_straggler_set_independent_of_seed():
    """y_opt sweeps (seed 99) and method runs (seeds 0..2) must price the
    SAME limping devices."""
    _, a = make_fleet_pair(SPEC, "straggler", TINY_CELL, FAMS, seed=0)
    _, b = make_fleet_pair(SPEC, "straggler", TINY_CELL, FAMS, seed=99,
                           trace_seed=0)
    assert a.fleet_spec == b.fleet_spec
    assert a.trace == b.trace


# --------------------------------------------------------------------------
# replay fleet (real batcher behind the router plan)
# --------------------------------------------------------------------------

REPLAY_SPEC = ("poisson:rate=1200,horizon=0.003,mean_prompt=5,"
               "mean_output=3,max_len=12")


def test_replay_fleet_counters_and_measurement():
    env = ReplayServingEnv(REPLAY_SPEC, seed=0, trace_seed=0, fleet=True,
                           repeats=1)
    assert tuple(env.counter_names) == REPLAY_FLEET_COUNTER_NAMES
    assert not {"latency", "throughput"} & set(env.counter_names)
    assert {"fleet.num_replicas", "fleet.routing"} <= set(env.space.names)
    cfg = dict(env.space.default_config())
    cfg["fleet.num_replicas"] = 2
    counters, y = env.intervene(cfg)
    assert np.isfinite(y) and y > 0
    assert set(env.counter_names) <= set(counters)
    # fleet.* never touch compiled shapes: replicas share one deployment
    assert env.infeasible_reason(cfg) == ""
    cfg["fleet.num_replicas"] = 16         # > num_devices
    assert env.infeasible_reason(cfg) == "devices"
    _, y_inf = env.intervene(cfg)
    assert y_inf == float("inf")


def test_sim2real_pair_fleet_mode():
    src, tgt = make_sim2real_pair(REPLAY_SPEC, seed=0, trace_seed=0,
                                  fleet=True, repeats=1)
    assert isinstance(src, ServingEnv) and isinstance(tgt, ReplayServingEnv)
    assert src.fleet and tgt.fleet
    assert src.space.names == tgt.space.names
    assert src.trace == tgt.trace
