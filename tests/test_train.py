"""Training-loop behaviour: loss decreases on learnable synthetic data,
microbatch accumulation is consistent, compression error feedback works."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.data.pipeline import make_data
from repro.models.model import build_model
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_train_state, make_train_step
from repro.utils.config import (MeshConfig, ParallelConfig, RunConfig,
                                ShapeConfig, TrainConfig)


def _run(**par_kw):
    cfg = tiny_model_config()
    return RunConfig(
        model=cfg,
        shape=ShapeConfig("train", 32, 8, "train"),
        mesh=MeshConfig(shape=(1,), axes=("data",)),
        parallel=ParallelConfig(**par_kw),
        train=TrainConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                          optimizer="adamw"),
    )


def _train(run, steps=50):
    model = build_model(run.model, run.parallel)
    opt = make_optimizer(run.train)
    step_fn = jax.jit(make_train_step(model, run, opt))
    state = init_train_state(model, run, opt, jax.random.PRNGKey(0))
    data = make_data(run.model, run.shape, seed=0)
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return losses, state


def test_loss_decreases_markov_data():
    losses, _ = _train(_run(), steps=50)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < 0.7 * np.mean(losses[:5]), losses[:5] + losses[-5:]


def test_microbatch_matches_full_batch():
    # same data, same seed; accumulation averages per-microbatch grads so
    # the PARAMETER trajectory must match (the reported loss metric is the
    # last microbatch's half-batch loss, which legitimately differs).
    _, s1 = _train(_run(microbatch=1), steps=3)
    _, s2 = _train(_run(microbatch=2), steps=3)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("optimizer", ["adamw", "adafactor", "sgdm"])
def test_optimizers_step_finite(optimizer):
    run = _run()
    run = run.replace(train=run.train.to_dict() and run.train)  # keep cfg
    run = RunConfig(model=run.model, shape=run.shape, mesh=run.mesh,
                    parallel=run.parallel,
                    train=TrainConfig(optimizer=optimizer, lr=1e-3,
                                      warmup_steps=2, total_steps=10))
    losses, _ = _train(run, steps=6)
    assert np.isfinite(losses).all()


def test_remat_matches_no_remat():
    l1, _ = _train(_run(remat="none"), steps=5)
    l2, _ = _train(_run(remat="full"), steps=5)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_grad_compression_int8_ef_converges():
    losses, state = _train(_run(grad_compression="int8_ef"), steps=50)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < 0.8 * np.mean(losses[:5])
    assert state.error_buf is not None
    # error feedback buffer stays bounded
    norms = [float(jnp.max(jnp.abs(e))) for e in jax.tree.leaves(state.error_buf)]
    assert max(norms) < 1.0


def test_bf16_compression_close_to_none():
    l1, _ = _train(_run(grad_compression="none"), steps=10)
    l2, _ = _train(_run(grad_compression="bf16"), steps=10)
    np.testing.assert_allclose(l1, l2, rtol=0.1, atol=0.1)


def test_scan_vs_unrolled_layers_identical():
    l1, _ = _train(_run(scan_layers=True), steps=4)
    l2, _ = _train(_run(scan_layers=False), steps=4)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_z_loss_and_accuracy_reported():
    run = _run()
    model = build_model(run.model, run.parallel)
    opt = make_optimizer(run.train)
    step_fn = jax.jit(make_train_step(model, run, opt))
    state = init_train_state(model, run, opt, jax.random.PRNGKey(0))
    data = make_data(run.model, run.shape, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    _, metrics = step_fn(state, batch)
    assert "z_loss" in metrics and "accuracy" in metrics
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0
