"""Paged KV cache + chunked prefill: the dense-equivalence anchor (a single
full-size page reproduces the dense path bit-for-bit, at the kernel and
through the whole batcher), page-pool allocation/churn, chunked-prefill
scheduling, PromptTooLong rejection, and the paged replay counters."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.kernels.flash_attention.ref import decode_attention_ref
from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
from repro.kernels.paged_attention.ref import (gather_pages,
                                               paged_decode_attention_ref)
from repro.models.model import build_model
from repro.serving.paging import PagedPlan
from repro.serving.replay import replay_trace
from repro.serving.scheduler import ContinuousBatcher, PromptTooLong, Request
from repro.utils.config import RunConfig, ShapeConfig
from repro.workloads import ServingPlan, make_workload
from repro.workloads.sim import SIM_COUNTER_NAMES

pytestmark = pytest.mark.paged

RNG = np.random.default_rng(0)


def rand(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def _paged_layout(k_cache, v_cache, page_size, perm=None):
    """Scatter a dense (B, L, Hkv, D) cache into a paged pool.  ``perm``
    shuffles which pool page holds which logical page (identity when None),
    so tests cover non-contiguous page tables."""
    b, l, hkv, d = k_cache.shape
    assert l % page_size == 0
    n_pages = l // page_size
    order = np.arange(b * n_pages) if perm is None else np.asarray(perm)
    k_pages = np.zeros((b * n_pages, page_size, hkv, d), np.float32)
    v_pages = np.zeros_like(k_pages)
    table = np.zeros((b, n_pages), np.int32)
    for bi in range(b):
        for p in range(n_pages):
            pid = int(order[bi * n_pages + p])
            k_pages[pid] = k_cache[bi, p * page_size:(p + 1) * page_size]
            v_pages[pid] = v_cache[bi, p * page_size:(p + 1) * page_size]
            table[bi, p] = pid
    return jnp.asarray(k_pages), jnp.asarray(v_pages), jnp.asarray(table)


# --------------------------------------------------------------------------
# kernel level: the dense-equivalence anchor
# --------------------------------------------------------------------------

def test_single_full_page_is_bit_identical_to_dense():
    # one page of exactly cache_len tokens with an identity table: the
    # gathered layout IS the dense cache, so the oracle must match the dense
    # decode reference bit-for-bit — not approximately
    b, l, hq, hkv, d = 3, 16, 4, 2, 8
    q = rand(b, 1, hq, d)
    k_cache, v_cache = rand(b, l, hkv, d), rand(b, l, hkv, d)
    lens = jnp.asarray([5, 16, 1], jnp.int32)
    k_pages, v_pages, table = _paged_layout(np.asarray(k_cache),
                                            np.asarray(v_cache), page_size=l)
    out = paged_decode_attention_ref(q, k_pages, v_pages, table, lens)
    ref = decode_attention_ref(q, k_cache, v_cache, lens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_permuted_multi_page_pool_is_bit_identical_to_dense():
    b, l, ps, hq, hkv, d = 2, 32, 8, 4, 2, 8
    q = rand(b, 1, hq, d)
    k_cache, v_cache = rand(b, l, hkv, d), rand(b, l, hkv, d)
    lens = jnp.asarray([19, 32], jnp.int32)
    perm = np.random.default_rng(3).permutation(b * (l // ps))
    k_pages, v_pages, table = _paged_layout(
        np.asarray(k_cache), np.asarray(v_cache), ps, perm)
    # the gather reconstructs the dense rows exactly...
    np.testing.assert_array_equal(
        np.asarray(gather_pages(k_pages, table)), np.asarray(k_cache))
    # ...so the attention output is bit-identical too
    out = paged_decode_attention_ref(q, k_pages, v_pages, table, lens)
    ref = decode_attention_ref(q, k_cache, v_cache, lens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_pallas_interpret_matches_ref():
    b, l, ps, hq, hkv, d = 2, 32, 8, 4, 2, 16
    q = rand(b, 1, hq, d)
    k_cache, v_cache = rand(b, l, hkv, d), rand(b, l, hkv, d)
    lens = jnp.asarray([13, 27], jnp.int32)
    perm = np.random.default_rng(5).permutation(b * (l // ps))
    k_pages, v_pages, table = _paged_layout(
        np.asarray(k_cache), np.asarray(v_cache), ps, perm)
    ref = paged_decode_attention_ref(q, k_pages, v_pages, table, lens)
    out = paged_decode_attention_pallas(q, k_pages, v_pages, table, lens,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)
    # softcap path too
    ref_c = paged_decode_attention_ref(q, k_pages, v_pages, table, lens,
                                       logit_softcap=5.0)
    out_c = paged_decode_attention_pallas(q, k_pages, v_pages, table, lens,
                                          logit_softcap=5.0, interpret=True)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(ref_c),
                               atol=2e-5, rtol=1e-4)


# --------------------------------------------------------------------------
# batcher level: paged serving reproduces the dense batcher bit-for-bit
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 64, 4, "decode"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, run, model, params


def _prompts(cfg, n, length=5, seed=2):
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    return [np.asarray(jax.random.randint(k, (length,), 0, cfg.vocab_size))
            for k in keys]


def _generated(served, *, paged=None, n_requests=3, max_new=4,
               num_slots=2, cache_len=32, eos_token=None):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=num_slots,
                          cache_len=cache_len, paged=paged,
                          eos_token=eos_token)
    for i, p in enumerate(_prompts(cfg, n_requests)):
        b.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    done = b.run_until_drained()
    return [(d.request.uid, list(d.generated)) for d in done], b


def test_paged_single_full_page_matches_dense_batcher(served):
    dense, _ = _generated(served)
    paged, b = _generated(served, paged=PagedPlan(
        paging=True, pool_pages=2, page_size=32, pages_per_slot_max=1))
    # bit-identical tokens AND identical completion order
    assert paged == dense
    assert sorted(b._free_pages) == [0, 1]  # every page back in the pool


def test_paged_multi_page_matches_dense_batcher(served):
    dense, _ = _generated(served)
    paged, _ = _generated(served, paged=PagedPlan(
        paging=True, pool_pages=8, page_size=4, pages_per_slot_max=8))
    assert paged == dense


def test_chunked_prefill_matches_unchunked(served):
    dense, _ = _generated(served)
    chunked, b = _generated(served, paged=PagedPlan(
        paging=True, pool_pages=8, page_size=4, pages_per_slot_max=8,
        prefill_chunk=2))
    # chunking is a scheduling decision: the jitted prefill still runs once
    # over the full prompt, so tokens AND completion order are unchanged
    assert chunked == dense
    assert b.prefill_chunks >= 3 * 3  # ceil(5/2) chunks per request
    assert b._prefilling is None


def test_pool_exhaustion_defers_admission_not_correctness(served):
    # worst case per request = 5 + 3 tokens = 2 pages of 4; a 2-page pool
    # serializes the requests even though 2 slots are free
    dense, _ = _generated(served)
    paged, b = _generated(served, paged=PagedPlan(
        paging=True, pool_pages=2, page_size=4, pages_per_slot_max=8))
    assert paged == dense
    assert b.mean_occupancy <= 1.0  # never two resident at once


def test_slot_churn_with_eos_matches_dense(served):
    cfg, run, model, params = served
    # greedy first token of the first prompt becomes "EOS": slots churn and
    # freed pages are re-issued to later requests mid-run
    from repro.train.serve_step import generate
    p0 = _prompts(cfg, 1)[0]
    ref = np.asarray(generate(model, run, params,
                              {"tokens": jnp.asarray(p0)[None]},
                              num_steps=1))[0]
    eos = int(ref[0])
    dense, _ = _generated(served, n_requests=4, max_new=6, eos_token=eos)
    paged, _ = _generated(served, n_requests=4, max_new=6, eos_token=eos,
                          paged=PagedPlan(paging=True, pool_pages=4,
                                          page_size=4, pages_per_slot_max=8))
    assert paged == dense


def test_paged_requires_model_support(served):
    cfg, run, model, params = served
    stripped = model._replace(init_paged_decode_state=None)
    with pytest.raises(NotImplementedError, match="paged decode"):
        ContinuousBatcher(stripped, run, params, paged=PagedPlan(paging=True))
    # paging=off never touches the paged path
    b = ContinuousBatcher(stripped, run, params, cache_len=32,
                          paged=PagedPlan(paging=False))
    assert b.paged is None and b.cache_len == 32


# --------------------------------------------------------------------------
# admission limits: PromptTooLong
# --------------------------------------------------------------------------

def test_prompt_too_long_raises_with_geometry(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=1, cache_len=16)
    with pytest.raises(PromptTooLong, match="dense cache") as e:
        b.submit(Request(uid=7, prompt=np.arange(14), max_new_tokens=8))
    assert e.value.uid == 7 and e.value.needed == 21 and e.value.limit == 16
    # paged limit is min(slot capacity, whole pool)
    b = ContinuousBatcher(model, run, params, num_slots=1,
                          paged=PagedPlan(paging=True, pool_pages=2,
                                          page_size=4, pages_per_slot_max=8))
    with pytest.raises(PromptTooLong, match="paged slot") as e:
        b.submit(Request(uid=8, prompt=np.arange(6), max_new_tokens=4))
    assert e.value.limit == 8  # 2 pool pages x 4, not 8 x 4


def test_prompt_too_long_reject_counts_instead(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=1, cache_len=16,
                          on_too_long="reject")
    b.submit(Request(uid=0, prompt=np.arange(14), max_new_tokens=8))
    b.submit(Request(uid=1, prompt=np.asarray([1, 2]), max_new_tokens=2))
    assert b.rejected_too_long == 1
    assert [r.uid for r in b.queue] == [1]
    done = b.run_until_drained()
    assert [d.request.uid for d in done] == [1]
    with pytest.raises(ValueError, match="on_too_long"):
        ContinuousBatcher(model, run, params, on_too_long="bogus")


# --------------------------------------------------------------------------
# replay counters
# --------------------------------------------------------------------------

def test_replay_reports_paged_counters(served):
    cfg, run, model, params = served
    tr = make_workload("poisson:rate=1500,horizon=0.004,mean_prompt=5,"
                       "mean_output=3,max_len=12").generate(0)
    b = ContinuousBatcher(model, run, params, num_slots=2,
                          paged=PagedPlan(paging=True, pool_pages=8,
                                          page_size=4, pages_per_slot_max=4,
                                          prefill_chunk=2),
                          on_too_long="reject")
    rep = replay_trace(b, tr, seed=0)
    assert rep.completed == len(tr)
    c = rep.counters()
    assert {"page_pool_occupancy", "page_faults", "prefill_chunks_inflight",
            "rejected_too_long"} <= set(c)
    assert 0.0 < c["page_pool_occupancy"] <= 1.0
    assert c["page_faults"] == 0.0  # the real batcher defers, never faults
    assert c["prefill_chunks_inflight"] > 0.0
    assert c["rejected_too_long"] == 0.0
    # a dense replay emits the same counter names, pinned to zero
    bd = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    cd = replay_trace(bd, tr, seed=0).counters()
    assert cd["page_pool_occupancy"] == cd["prefill_chunks_inflight"] == 0.0


# --------------------------------------------------------------------------
# simulator: paging off is the pre-refactor sim; paging on moves the price
# --------------------------------------------------------------------------

def test_sim_paging_off_matches_legacy_and_on_differs():
    from repro.envs.measure import KernelWorkload
    from repro.workloads import ServingSimulator

    cell = KernelWorkload(name="tiny", batch=1, seq_len=128, heads=2,
                          kv_heads=1, head_dim=16, d_model=64, channels=64,
                          scan_state=4, ssm_heads=2, ssm_head_dim=16,
                          ssm_state=8)
    tr = make_workload("poisson:rate=2000,horizon=0.02,mean_prompt=32,"
                       "mean_output=16,max_len=96").generate(0)
    sim = ServingSimulator(cell, ("flash_attention", "rmsnorm"))
    plan = ServingPlan()
    legacy = sim.run(tr, plan, {})
    off = sim.run(tr, plan, {"pages.paging": "off"})
    assert off == legacy  # the refactor left the dense sim bit-identical
    on = sim.run(tr, plan, {"pages.paging": "on"})
    assert on.feasible
    assert on.p99_latency_us != legacy.p99_latency_us
    assert on.page_pool_occupancy > 0.0
    assert legacy.page_pool_occupancy == 0.0
    assert set(on.counters()) == set(legacy.counters())
    assert set(SIM_COUNTER_NAMES) <= set(on.counters())
