"""Tuner-space plumbing: the partition of a tuner configuration into
plan-level knobs (``apply_config`` / ``config_to_parallel_kv``) and
kernel-launch knobs (``launch_config_of``), and the overlap rules of
``framework_space(include_kernel_launch=True)`` — plan-level block knobs are
replaced by the dispatch registry's ``family.param`` options so each launch
parameter has exactly one source of truth."""

import numpy as np
import pytest

from conftest import tiny_model_config
from repro.kernels import dispatch
from repro.tuner.space import (
    apply_config, config_to_parallel_kv, framework_space,
    launch_config_of, launch_families_for)
from repro.utils.config import ParallelConfig

# ssm_num_heads absent -> mamba-1 (selective scan dispatches mamba_scan)
SSM_KW = dict(family="ssm", attn_type="none", num_heads=0, num_kv_heads=0,
              d_ff=0, ssm_state=4, ssm_chunk=4)
SSM2_KW = dict(SSM_KW, ssm_num_heads=4)  # mamba-2: dispatches ssd


def _sampled(space, n=20, seed=0):
    rng = np.random.default_rng(seed)
    return [space.default_config()] + space.sample(rng, n)


# --------------------------------------------------------------------------
# launch_config_of / apply_config / config_to_parallel_kv round-trips
# --------------------------------------------------------------------------

def test_config_partition_roundtrip_dense():
    space = framework_space(tiny_model_config(), include_kernel_launch=True)
    for config in _sampled(space):
        lc = launch_config_of(config)
        plan = {k: v for k, v in config.items() if k not in lc}
        # the two halves partition the config exactly
        assert set(lc) | set(plan) == set(config)
        assert all("." in k for k in lc)
        assert all("." not in k for k in plan)
        # launch half is installable as-is
        nested = dispatch.split_launch_config(lc)
        with dispatch.use_launch_config(lc):
            for fam, params in nested.items():
                resolved = dispatch.launch_params(fam)
                for pname, v in params.items():
                    assert resolved[pname] == v
        # plan half lands on ParallelConfig and survives the kv encoding
        par = apply_config(ParallelConfig(), config)
        for k, v in plan.items():
            if k == "ssm_chunk":
                continue
            cur = getattr(par, k)
            assert cur == (type(cur)(v) if not isinstance(cur, str) else v)
        kv = config_to_parallel_kv(config)
        items = dict(p.split("=") for p in kv.split(",")) if kv else {}
        assert set(items) == {k for k in plan if k != "ssm_chunk"}
        for k, sv in items.items():
            assert sv == str(config[k])


def test_config_partition_roundtrip_ssm():
    space = framework_space(tiny_model_config(**SSM_KW),
                            include_kernel_launch=True)
    for config in _sampled(space, n=10, seed=1):
        lc = launch_config_of(config)
        assert "mamba_scan.chunk" in lc  # mamba-1 model
        apply_config(ParallelConfig(), config)  # dotted keys must be skipped
        assert "." not in config_to_parallel_kv(config)


def test_apply_config_casts_to_field_types():
    par = apply_config(ParallelConfig(), {"sp": 1, "fsdp": 2.0,
                                          "remat": "dots"})
    assert par.sp is True and par.fsdp == 2 and par.remat == "dots"
    assert isinstance(par.fsdp, int)


def test_launch_config_of_only_takes_dotted_keys():
    config = {"microbatch": 4, "flash_attention.q_block": 256,
              "rmsnorm.row_block": 64, "remat": "full"}
    assert launch_config_of(config) == {"flash_attention.q_block": 256,
                                        "rmsnorm.row_block": 64}
    assert launch_config_of({}) == {}


# --------------------------------------------------------------------------
# framework_space overlap rules
# --------------------------------------------------------------------------

def test_kernel_launch_replaces_plan_level_block_knobs_dense():
    cfg = tiny_model_config()
    plain = framework_space(cfg)
    merged = framework_space(cfg, include_kernel_launch=True)
    # the plan-level spellings exist without the launch surface...
    assert {"attn_q_block", "attn_kv_block"} <= set(plain.names)
    # ...and are replaced by the registry's family.param options with it
    assert not {"attn_q_block", "attn_kv_block"} & set(merged.names)
    assert {"flash_attention.q_block", "flash_attention.kv_block",
            "rmsnorm.row_block"} <= set(merged.names)
    # dense model: no SSM launch families
    assert not any(n.startswith(("mamba_scan.", "ssd.")) for n in merged.names)
    # non-block plan knobs survive the merge
    assert {"microbatch", "remat", "fsdp"} <= set(merged.names)


def test_kernel_launch_replaces_plan_level_block_knobs_ssm():
    cfg = tiny_model_config(**SSM_KW)
    merged = framework_space(cfg, include_kernel_launch=True)
    assert "ssm_chunk" not in merged.names
    assert {"mamba_scan.chunk", "mamba_scan.c_block",
            "rmsnorm.row_block"} <= set(merged.names)
    # attention-free: no flash_attention launch family; mamba-1: no ssd
    assert not any(n.startswith(("flash_attention.", "ssd."))
                   for n in merged.names)
    # mamba-2 flips the SSM family: ssd in, mamba_scan out
    merged2 = framework_space(tiny_model_config(**SSM2_KW),
                              include_kernel_launch=True)
    assert "ssd.chunk" in merged2.names
    assert not any(n.startswith("mamba_scan.") for n in merged2.names)


def test_launch_families_match_dispatched_kernels():
    assert launch_families_for(tiny_model_config()) == \
        ["rmsnorm", "flash_attention"]
    assert launch_families_for(tiny_model_config(**SSM_KW)) == \
        ["rmsnorm", "mamba_scan"]
    assert launch_families_for(tiny_model_config(**SSM2_KW)) == \
        ["rmsnorm", "ssd"]
    hybrid = tiny_model_config(family="hybrid", ssm_state=4, ssm_num_heads=4,
                               ssm_chunk=4, hybrid_attn_period=2)
    assert launch_families_for(hybrid) == \
        ["rmsnorm", "flash_attention", "ssd"]


def test_kernel_launch_space_serve_kind():
    cfg = tiny_model_config()
    serve = framework_space(cfg, kind="serve", include_kernel_launch=True)
    assert "attn_kv_block" not in serve.names
    assert "flash_attention.kv_block" in serve.names
    assert "microbatch" not in serve.names  # train-only knob filtered

    # every sampled serve config still partitions cleanly
    for config in _sampled(serve, n=5, seed=2):
        lc = launch_config_of(config)
        dispatch.split_launch_config(lc)
        apply_config(ParallelConfig(), config)


def test_launch_options_match_registry_domains():
    merged = framework_space(tiny_model_config(), include_kernel_launch=True)
    for name in merged.names:
        if "." not in name:
            continue
        fam_name, pname = name.split(".", 1)
        opt = merged.by_name[name]
        reg = dispatch.get_family(fam_name).option(pname)
        assert opt.values == reg.values and opt.default == reg.default
