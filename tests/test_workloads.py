"""Workload subsystem: trace-generator determinism/properties, the spec
registry, JSONL replay round-trip, and the discrete-event serving
simulator's conservation/feasibility/counter behavior."""

import dataclasses

import numpy as np
import pytest

from repro.envs.measure import HardwareSpec, KernelWorkload
from repro.serving.scheduler import DrainStall
from repro.workloads import (
    WORKLOAD_KINDS, RequestSpec, ServingPlan, ServingSimulator, Trace,
    make_workload, register_workload, serving_space, workload_kinds)

GENERATED_KINDS = ("poisson", "bursty", "diurnal", "heavy_tail")
TINY_CELL = KernelWorkload(name="tiny", batch=1, seq_len=128, heads=2,
                           kv_heads=1, head_dim=16, d_model=64, channels=64,
                           scan_state=4, ssm_heads=2, ssm_head_dim=16,
                           ssm_state=8)
FAMS = ("flash_attention", "rmsnorm")


def _sim(**kw):
    return ServingSimulator(TINY_CELL, FAMS, **kw)


# --------------------------------------------------------------------------
# registry / spec grammar
# --------------------------------------------------------------------------

def test_at_least_five_kinds_registered():
    assert set(workload_kinds()) >= {"poisson", "bursty", "diurnal",
                                     "heavy_tail", "replay"}
    assert len(workload_kinds()) >= 5


def test_spec_round_trips_and_overrides():
    w = make_workload("poisson:rate=123.5,mean_prompt=7")
    assert dict(w.params)["rate"] == 123.5
    assert dict(w.params)["mean_prompt"] == 7
    # canonical spec re-parses to the same workload
    assert make_workload(w.spec) == w


def test_unknown_kind_and_param_raise_with_names():
    with pytest.raises(ValueError, match=r"unknown workload kind 'bogus'"):
        make_workload("bogus")
    with pytest.raises(ValueError) as e:
        make_workload("bogus:rate=1")
    for kind in workload_kinds():
        assert kind in str(e.value)
    with pytest.raises(ValueError, match=r"no parameter 'nope'.*valid"):
        make_workload("poisson:nope=3")
    with pytest.raises(ValueError, match="not 'param=value'"):
        make_workload("poisson:rate")


def test_register_workload_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        register_workload("poisson")(lambda rng: [])


# --------------------------------------------------------------------------
# generator determinism + properties
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", GENERATED_KINDS)
def test_same_spec_same_seed_identical_trace(kind):
    w = make_workload(kind)
    assert w.generate(5) == w.generate(5)
    assert w.generate(5) != w.generate(6)


@pytest.mark.parametrize("kind", GENERATED_KINDS)
def test_trace_well_formed(kind):
    tr = make_workload(kind).generate(0)
    assert len(tr) > 0
    times = [r.arrival_s for r in tr.requests]
    assert times == sorted(times)
    assert all(t >= 0 for t in times)
    assert all(r.prompt_len >= 1 and r.output_len >= 1 for r in tr.requests)
    assert [r.uid for r in tr.requests] == list(range(len(tr)))
    assert tr.max_context == max(r.prompt_len + r.output_len
                                 for r in tr.requests)


def test_different_specs_differ_under_same_seed():
    a = make_workload("poisson:rate=2000").generate(0)
    b = make_workload("poisson:rate=2001").generate(0)
    assert [r.arrival_s for r in a.requests] != [r.arrival_s
                                                 for r in b.requests]


def test_poisson_rate_approximately_holds():
    tr = make_workload("poisson:rate=3000,horizon=0.2").generate(1)
    assert tr.mean_rate() == pytest.approx(3000, rel=0.2)


def test_bursty_is_burstier_than_poisson():
    # coefficient of variation of inter-arrival gaps: the MMPP must exceed
    # the memoryless process (CV ~ 1)
    def cv(spec):
        t = np.asarray([r.arrival_s
                        for r in make_workload(spec).generate(2).requests])
        gaps = np.diff(t)
        return gaps.std() / gaps.mean()

    assert cv("bursty:rate=2000,burst=8,horizon=0.2") > \
        cv("poisson:rate=2000,horizon=0.2") + 0.2


def test_heavy_tail_is_heavier_than_poisson():
    thin = make_workload("poisson:horizon=0.2").generate(3)
    heavy = make_workload("heavy_tail:horizon=0.2").generate(3)
    assert max(r.prompt_len for r in heavy.requests) > \
        2 * max(r.prompt_len for r in thin.requests)


def test_diurnal_rate_varies_over_period():
    tr = make_workload(
        "diurnal:rate=4000,amplitude=1.0,period=0.1,horizon=0.1").generate(4)
    t = np.asarray([r.arrival_s for r in tr.requests])
    # first half-period is the crest, second the trough
    assert (t < 0.05).sum() > 2 * (t >= 0.05).sum()


def test_replay_round_trip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    orig = make_workload("bursty:horizon=0.02").generate(7)
    orig.save(path)
    replayed = make_workload(f"replay:path={path}").generate(123)
    assert [(r.arrival_s, r.prompt_len, r.output_len)
            for r in replayed.requests] == \
        [(r.arrival_s, r.prompt_len, r.output_len) for r in orig.requests]
    with pytest.raises(ValueError, match="needs path"):
        make_workload("replay").generate(0)


def test_trace_rejects_malformed():
    good = RequestSpec(0, 0.0, 4, 4)
    with pytest.raises(ValueError, match="sorted"):
        Trace("k", "k", 0, (RequestSpec(0, 1.0, 4, 4),
                            RequestSpec(1, 0.5, 4, 4)))
    with pytest.raises(ValueError, match="malformed"):
        Trace("k", "k", 0, (good, RequestSpec(1, 2.0, 0, 4)))


# --------------------------------------------------------------------------
# serving plan / space
# --------------------------------------------------------------------------

def test_serving_space_joins_scheduler_and_launch_options():
    space = serving_space(FAMS)
    names = set(space.names)
    assert {"serving.num_slots", "serving.admit_chunk", "serving.cache_len",
            "serving.interleave"} <= names
    assert {"flash_attention.q_block", "flash_attention.kv_block",
            "rmsnorm.row_block"} <= names
    assert "mamba_scan.chunk" not in names  # families restrict the surface


def test_serving_plan_from_config_and_validation():
    plan = ServingPlan.from_config({"serving.num_slots": 4,
                                    "serving.cache_len": 256,
                                    "serving.interleave": "drain",
                                    "flash_attention.q_block": 128})
    assert plan == ServingPlan(num_slots=4, admit_chunk=4, cache_len=256,
                               interleave="drain")
    with pytest.raises(ValueError, match="interleave"):
        ServingPlan(interleave="bogus")
    with pytest.raises(ValueError, match="malformed"):
        ServingPlan(num_slots=0)


# --------------------------------------------------------------------------
# simulator
# --------------------------------------------------------------------------

def _trace(spec="poisson:rate=2000,horizon=0.02,mean_prompt=32,"
                "mean_output=16,max_len=96", seed=0):
    return make_workload(spec).generate(seed)


def test_sim_deterministic_and_conserves_requests():
    tr = _trace()
    sim = _sim()
    plan = ServingPlan()
    r1 = sim.run(tr, plan, {})
    r2 = _sim().run(tr, plan, {})
    assert r1 == r2
    assert r1.feasible and r1.completed == len(tr)
    assert r1.p99_latency_us >= r1.p50_latency_us > 0
    assert r1.throughput_rps > 0 and r1.tokens_per_s > 0
    assert 0 < r1.occupancy_mean <= plan.num_slots
    assert set(r1.counters()) == {
        "queue_depth_mean", "queue_depth_max", "occupancy_mean",
        "prefill_decode_ratio", "latency", "throughput",
        "slo_violation_rate", "page_pool_occupancy", "page_faults",
        "prefill_chunks_inflight"}


def test_sim_cache_too_small_is_infeasible():
    tr = _trace()
    plan = ServingPlan(cache_len=max(tr.max_context - 1, 1))
    rep = _sim().run(tr, plan, {})
    assert not rep.feasible and rep.reason == "cache_len"
    assert rep.completed == 0


def test_sim_vmem_overflow_is_infeasible():
    cell = dataclasses.replace(TINY_CELL, vmem_limit=1)
    rep = ServingSimulator(cell, FAMS).run(_trace(), ServingPlan(), {})
    assert not rep.feasible and rep.reason == "vmem"


def test_sim_launch_config_changes_price():
    tr = _trace()
    sim = _sim()
    a = sim.run(tr, ServingPlan(), {"flash_attention.q_block": 128,
                                    "flash_attention.kv_block": 256})
    b = sim.run(tr, ServingPlan(), {"flash_attention.q_block": 1024,
                                    "flash_attention.kv_block": 2048})
    assert a.p99_latency_us != b.p99_latency_us
    resolved = sim.resolved_launch({"flash_attention.q_block": 128})
    assert resolved["flash_attention"]["q_block"] == 128


def test_sim_fewer_slots_queues_more():
    tr = _trace("bursty:rate=4000,burst=6,horizon=0.02,mean_prompt=32,"
                "mean_output=16,max_len=96")
    sim = _sim()
    narrow = sim.run(tr, ServingPlan(num_slots=2), {})
    wide = sim.run(tr, ServingPlan(num_slots=16), {})
    assert narrow.queue_depth_mean > wide.queue_depth_mean


def test_sim_drain_policy_differs_from_eager():
    tr = _trace("bursty:rate=4000,burst=6,horizon=0.02,mean_prompt=32,"
                "mean_output=16,max_len=96")
    sim = _sim()
    eager = sim.run(tr, ServingPlan(interleave="eager"), {})
    drain = sim.run(tr, ServingPlan(interleave="drain"), {})
    assert eager != drain


def test_sim_slo_violation_rate_tracks_threshold():
    tr = _trace()
    tight = _sim(slo_us=1.0).run(tr, ServingPlan(), {})
    loose = _sim(slo_us=1e9).run(tr, ServingPlan(), {})
    assert tight.slo_violation_rate == 1.0
    assert loose.slo_violation_rate == 0.0


def test_sim_tick_budget_raises_drain_stall():
    with pytest.raises(DrainStall) as e:
        _sim(max_ticks=3).run(_trace(), ServingPlan(), {})
    assert e.value.pending > 0


def test_sim_tick_budget_counts_like_run_until_drained():
    # >= semantics, matching ContinuousBatcher.run_until_drained: a budget
    # of exactly the ticks the trace needs succeeds; one less is a stall
    tr = _trace()
    need = _sim().run(tr, ServingPlan(), {}).ticks
    assert _sim(max_ticks=need).run(tr, ServingPlan(), {}).ticks == need
    with pytest.raises(DrainStall):
        _sim(max_ticks=need - 1).run(tr, ServingPlan(), {})


def test_sim_latency_stats_guarded():
    # empty-trace rejection is owned by test_sim_empty_trace_rejected; here:
    # the latency statistics of a completed run are always finite
    rep = _sim().run(_trace(), ServingPlan(), {})
    for v in (rep.p50_latency_us, rep.p99_latency_us, rep.mean_latency_us,
              rep.slo_violation_rate):
        assert np.isfinite(v)


def test_sim_empty_trace_rejected():
    with pytest.raises(ValueError, match="empty trace"):
        _sim().run(Trace("k", "k", 0, ()), ServingPlan(), {})


def test_sim_hardware_scales_latency():
    tr = _trace()
    base = _sim().run(tr, ServingPlan(), {})
    slow = ServingSimulator(
        TINY_CELL, FAMS,
        hardware=HardwareSpec().scaled(mxu=0.5, hbm=0.5)).run(
            tr, ServingPlan(), {})
    assert slow.p99_latency_us > base.p99_latency_us
