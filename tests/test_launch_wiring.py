"""End-to-end launch-config wiring: a tuner run's winning kernel-launch
configuration must actually reach the kernel calls inside the jitted
serve/train steps (verified with the dispatch-level resolution spy, not by
inspecting the config plumbing), `use_launch_config` must restore prior
state across exceptions and re-entry, and repeated generation must not
retrace."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.envs.kernel_launch import KernelLaunchEnv, KernelWorkload
from repro.kernels import dispatch
from repro.models.model import build_model
from repro.train.optimizer import make_optimizer
from repro.train.serve_step import (
    freeze_launch_config, generate, jitted_steps, make_decode_step,
    make_prefill_step)
from repro.train.train_step import init_train_state, make_train_step
from repro.tuner.runner import transfer_tune, tune_kernel_launch
from repro.utils.config import RunConfig, ShapeConfig

TINY_SRC = KernelWorkload(name="src", batch=2, seq_len=128, heads=2,
                          kv_heads=1, head_dim=16, d_model=32, channels=64,
                          scan_state=4, ssm_heads=2, ssm_head_dim=16,
                          ssm_state=8)
TINY_TGT = KernelWorkload(name="tgt", batch=1, seq_len=256, heads=2,
                          kv_heads=1, head_dim=16, d_model=32, channels=64,
                          scan_state=4, ssm_heads=2, ssm_head_dim=16,
                          ssm_state=8, launch_overhead_us=3.0)


def _run_for(cfg, seq=16, batch=2):
    return RunConfig(model=cfg, shape=ShapeConfig("t", seq, batch, "decode"))


def _tuner_result(method="random", budget=6, seed=0):
    src = KernelLaunchEnv(TINY_SRC, seed=seed + 1)
    tgt = KernelLaunchEnv(TINY_TGT, seed=seed + 2)
    return transfer_tune(method, src, tgt, budget=budget, n_source=24,
                         n_target_init=2, seed=seed)


def _launch_of(recorded, family):
    return [r.launch for r in recorded if r.family == family]


# --------------------------------------------------------------------------
# tuner -> step factories (the dispatch spy is the ground truth)
# --------------------------------------------------------------------------

def test_tuner_launch_config_reaches_decode_kernels():
    result = _tuner_result()
    lc = result.launch_config
    assert lc and all("." in k for k in lc)
    assert set(lc) == set(KernelLaunchEnv(TINY_TGT).space.names)

    cfg = tiny_model_config()
    run = _run_for(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

    prefill, decode = jitted_steps(model, run, cache_len=12, launch_config=lc)
    with dispatch.record_resolutions() as rec:
        state, logits = prefill(params, {"tokens": toks})
        state, logits = decode(params, state, toks[:, :1])
    attn = _launch_of(rec, "flash_attention")
    assert attn, "no flash_attention dispatch recorded during trace"
    for launch in attn:
        assert launch["q_block"] == lc["flash_attention.q_block"]
        assert launch["kv_block"] == lc["flash_attention.kv_block"]
    # and without a launch_config the registry defaults are what's resolved
    model2 = build_model(cfg)
    prefill2, _ = jitted_steps(model2, run, cache_len=12)
    with dispatch.record_resolutions() as rec2:
        prefill2(model2.init(jax.random.PRNGKey(0)), {"tokens": toks})
    fam = dispatch.get_family("flash_attention")
    for launch in _launch_of(rec2, "flash_attention"):
        assert launch["q_block"] == fam.option("q_block").default


def test_tuner_launch_config_reaches_ssm_kernels():
    result = _tuner_result(seed=3)
    lc = result.launch_config
    cfg = tiny_model_config(family="ssm", attn_type="none", num_heads=0,
                            num_kv_heads=0, d_ff=0, ssm_state=4, ssm_chunk=4)
    run = _run_for(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

    prefill = make_prefill_step(model, run, cache_len=12, launch_config=lc)
    decode = make_decode_step(model, run, launch_config=lc)
    with dispatch.record_resolutions() as rec:
        state, _ = prefill(params, {"tokens": toks})
        decode(params, state, toks[:, :1])
    ssm = _launch_of(rec, "mamba_scan") + _launch_of(rec, "ssd")
    assert ssm, "no SSM-family dispatch recorded"
    for launch in _launch_of(rec, "mamba_scan"):
        assert launch["chunk"] == lc["mamba_scan.chunk"]
    for launch in _launch_of(rec, "ssd"):
        assert launch["chunk"] == lc["ssd.chunk"]


def test_launch_config_reaches_train_step_kernels():
    lc = {"flash_attention.q_block": 128, "flash_attention.kv_block": 256,
          "rmsnorm.row_block": 64}
    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 16, 2, "train"))
    model = build_model(cfg)
    opt = make_optimizer(run.train)
    step = jax.jit(make_train_step(model, run, opt, launch_config=lc))
    state = init_train_state(model, run, opt, jax.random.PRNGKey(0))
    batch = {
        "inputs": jnp.zeros((2, 16), jnp.int32),
        "targets": jnp.zeros((2, 16), jnp.int32),
    }
    with dispatch.record_resolutions() as rec:
        state, metrics = step(state, batch)
    attn = _launch_of(rec, "flash_attention")
    assert attn, "no flash_attention dispatch recorded in train step"
    for launch in attn:
        assert launch["q_block"] == 128 and launch["kv_block"] == 256
    assert np.isfinite(float(metrics["loss"]))
    with pytest.raises(KeyError):
        make_train_step(model, run, opt, launch_config={"bogus.k": 1})


def test_launch_config_reaches_continuous_batcher():
    from repro.serving.scheduler import ContinuousBatcher, Request

    lc = {"flash_attention.kv_block": 256, "rmsnorm.row_block": 64}
    cfg = tiny_model_config()
    run = _run_for(cfg, seq=32, batch=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32,
                          launch_config=lc)
    prompt = np.asarray([1, 2, 3])
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=3))
    with dispatch.record_resolutions() as rec:
        done = b.run_until_drained()
    assert len(done) == 1
    attn = _launch_of(rec, "flash_attention")
    assert attn, "no flash_attention dispatch recorded in batcher trace"
    for launch in attn:
        assert launch["kv_block"] == 256


def test_tune_kernel_launch_and_install():
    result = tune_kernel_launch(TINY_TGT, source_workload=TINY_SRC,
                                method="random", budget=4, n_source=16,
                                n_target_init=2, seed=0)
    assert np.isfinite(result.best_y)
    with result.install():
        for key, v in result.launch_config.items():
            fam, pname = key.split(".", 1)
            assert dispatch.launch_params(fam)[pname] == v
    # restored after exit
    fam = dispatch.get_family("rmsnorm")
    assert dispatch.launch_params("rmsnorm")["row_block"] == \
        fam.option("row_block").default


# --------------------------------------------------------------------------
# use_launch_config: exception safety + re-entrancy
# --------------------------------------------------------------------------

def test_use_launch_config_restores_after_exception():
    default = dispatch.launch_params("rmsnorm")["row_block"]
    with pytest.raises(RuntimeError):
        with dispatch.use_launch_config({"rmsnorm.row_block": 64}):
            assert dispatch.launch_params("rmsnorm")["row_block"] == 64
            raise RuntimeError("boom")
    assert dispatch.launch_params("rmsnorm")["row_block"] == default
    # also when the failure happens inside a nested install
    outer = dispatch.use_launch_config({"rmsnorm.row_block": 128})
    with pytest.raises(RuntimeError):
        with outer:
            with dispatch.use_launch_config({"flash_attention.q_block": 256}):
                raise RuntimeError("inner")
    assert dispatch.launch_params("rmsnorm")["row_block"] == default
    assert dispatch.launch_params("flash_attention")["q_block"] == \
        dispatch.get_family("flash_attention").option("q_block").default


def test_record_resolutions_nested_detach_by_identity():
    # two empty recorder lists compare ==; exit must detach by identity or
    # the outer recorder goes dead
    with dispatch.record_resolutions() as outer:
        with dispatch.record_resolutions() as inner:
            pass  # nothing recorded: outer == inner == []
        dispatch.resolve("rmsnorm")
    assert len(outer) == 1 and inner == []


def test_tune_kernel_launch_families_restricts_surface():
    result = tune_kernel_launch(TINY_TGT, source_workload=TINY_SRC,
                                families=["rmsnorm", "flash_attention"],
                                method="random", budget=3, n_source=8,
                                n_target_init=1, seed=0)
    assert set(result.launch_config) == {
        "rmsnorm.row_block", "flash_attention.q_block",
        "flash_attention.kv_block"}


def test_use_launch_config_reentrant_same_instance():
    cm = dispatch.use_launch_config({"rmsnorm.row_block": 64})
    with cm:
        assert dispatch.launch_params("rmsnorm")["row_block"] == 64
        with cm:  # recursive entry of one instance
            assert dispatch.launch_params("rmsnorm")["row_block"] == 64
        assert dispatch.launch_params("rmsnorm")["row_block"] == 64
    assert dispatch.launch_params("rmsnorm")["row_block"] == 256
    with cm:  # sequential reuse
        assert dispatch.launch_params("rmsnorm")["row_block"] == 64
    assert dispatch.launch_params("rmsnorm")["row_block"] == 256


# --------------------------------------------------------------------------
# generate: jit cache, no per-call retrace
# --------------------------------------------------------------------------

def test_steps_are_hermetic_to_ambient_config():
    # jax traces lazily: a cached step first called inside an ambient
    # use_launch_config must still bake ITS OWN launch_config (here: the
    # registry defaults), or the cache would serve poisoned traces to
    # callers outside the context
    cfg = tiny_model_config()
    run = _run_for(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    prefill, _ = jitted_steps(model, run, cache_len=12)
    default_q = dispatch.get_family("flash_attention").option("q_block").default
    with dispatch.use_launch_config({"flash_attention.q_block": 128}):
        with dispatch.record_resolutions() as rec:
            prefill(params, {"tokens": toks})  # first call -> trace here
    attn = _launch_of(rec, "flash_attention")
    assert attn and all(l["q_block"] == default_q for l in attn)


def test_use_launch_config_shared_instance_across_threads():
    import threading

    cm = dispatch.use_launch_config({"rmsnorm.row_block": 64})
    default = dispatch.launch_params("rmsnorm")["row_block"]
    a_entered, b_done = threading.Event(), threading.Event()
    seen = {}

    def thread_a():
        with cm:
            a_entered.set()
            assert b_done.wait(10)
            seen["a_inside"] = dispatch.launch_params("rmsnorm")["row_block"]
        seen["a_after"] = dispatch.launch_params("rmsnorm")["row_block"]

    def thread_b():
        assert a_entered.wait(10)
        with cm:  # same instance, concurrently, on another thread
            seen["b_inside"] = dispatch.launch_params("rmsnorm")["row_block"]
        seen["b_after"] = dispatch.launch_params("rmsnorm")["row_block"]
        b_done.set()

    ta, tb = threading.Thread(target=thread_a), threading.Thread(target=thread_b)
    ta.start(); tb.start(); ta.join(10); tb.join(10)
    # B entered AND exited while A was still inside: each thread must see
    # its own install while active and its own prior state afterwards
    assert seen == {"a_inside": 64, "b_inside": 64,
                    "a_after": default, "b_after": default}


def test_generate_does_not_retrace_on_repeat_calls():
    cfg = tiny_model_config()
    run = _run_for(cfg)
    base = build_model(cfg)
    counts = {"forward": 0}

    def counting_forward(*args, **kwargs):
        counts["forward"] += 1
        return base.forward(*args, **kwargs)

    model = base._replace(forward=counting_forward)
    params = base.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)

    out1 = generate(model, run, params, {"tokens": toks}, num_steps=5)
    traces = counts["forward"]
    assert traces == 2  # one prefill trace + one decode trace
    out2 = generate(model, run, params, {"tokens": toks}, num_steps=5)
    assert counts["forward"] == traces, "repeat generation retraced"
    np.testing.assert_array_equal(out1, out2)


def test_jitted_steps_cache_identity_and_launch_key():
    cfg = tiny_model_config()
    run = _run_for(cfg)
    model = build_model(cfg)
    a = jitted_steps(model, run, cache_len=12)
    b = jitted_steps(model, run, cache_len=12)
    assert a[0] is b[0] and a[1] is b[1]
    # equivalent flat/nested spellings share one compilation...
    flat = jitted_steps(model, run, cache_len=12,
                        launch_config={"rmsnorm.row_block": 64})
    nested = jitted_steps(model, run, cache_len=12,
                          launch_config={"rmsnorm": {"row_block": 64}})
    assert flat[0] is nested[0]
    # ...but a different tuned config gets a fresh trace
    other = jitted_steps(model, run, cache_len=12,
                         launch_config={"rmsnorm.row_block": 128})
    assert other[0] is not flat[0]
    assert flat[0] is not a[0]


def test_freeze_launch_config_canonicalizes():
    assert freeze_launch_config(None) == ()
    assert freeze_launch_config({}) == ()
    flat = freeze_launch_config(
        {"flash_attention.kv_block": 512, "flash_attention.q_block": 256})
    nested = freeze_launch_config(
        {"flash_attention": {"q_block": 256, "kv_block": 512}})
    assert flat == nested
    with pytest.raises(KeyError):
        freeze_launch_config({"bogus.q_block": 1})
