"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.spaces import ConfigSpace, Option
from repro.core.epsilon import hull_volume_fraction
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.kernels.flash_attention import ref as aref
from repro.kernels.mamba_scan import ref as sref
from repro.kernels.ssd import ref as ssdref

SETTINGS = dict(max_examples=20, deadline=None)


# -- config space -------------------------------------------------------------

@st.composite
def spaces(draw):
    n = draw(st.integers(2, 6))
    opts = []
    for i in range(n):
        kind = draw(st.sampled_from(["numeric", "categorical"]))
        if kind == "numeric":
            vals = tuple(sorted(draw(st.sets(
                st.integers(0, 100), min_size=2, max_size=5))))
        else:
            vals = tuple(f"v{j}" for j in range(draw(st.integers(2, 4))))
        opts.append(Option(f"o{i}", vals, kind=kind))
    return ConfigSpace(opts)


@given(spaces(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_encode_decode_roundtrip(space, seed):
    rng = np.random.default_rng(seed)
    cfg = space.sample(rng, 1)[0]
    assert space.decode(space.encode(cfg)) == cfg


@given(spaces())
@settings(**SETTINGS)
def test_encoding_in_unit_cube(space):
    rng = np.random.default_rng(0)
    for cfg in space.sample(rng, 8):
        x = space.encode(cfg)
        assert (x >= 0).all() and (x <= 1).all()


@given(spaces(), st.integers(0, 100))
@settings(**SETTINGS)
def test_neighbors_are_valid_configs(space, seed):
    rng = np.random.default_rng(seed)
    cfg = space.sample(rng, 1)[0]
    for nb in space.neighbors(cfg, rng, 6):
        for o in space.options:
            assert nb[o.name] in o.values


# -- hull volume ----------------------------------------------------------------

@given(st.integers(2, 30), st.integers(1, 5), st.integers(0, 1000))
@settings(**SETTINGS)
def test_hull_volume_bounds_and_monotonicity(n, d, seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (n, d))
    v = hull_volume_fraction(pts)
    assert 0.0 <= v <= 1.0
    v2 = hull_volume_fraction(np.vstack([pts, rng.uniform(0, 1, (3, d))]))
    assert v2 >= v - 1e-12


# -- data pipeline ------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(1, 4))
@settings(**SETTINGS)
def test_data_deterministic_and_sharded(step, shards):
    base = dict(vocab_size=64, seq_len=16, global_batch=8)
    full = SyntheticLMData(DataConfig(**base, seed=5))
    ref = full.batch_at(step)["inputs"]
    # same step twice -> identical
    np.testing.assert_array_equal(ref, full.batch_at(step)["inputs"])
    if 8 % shards == 0:
        parts = [SyntheticLMData(DataConfig(**base, seed=5,
                                            num_shards=shards, shard_id=i)
                                 ).batch_at(step)["inputs"]
                 for i in range(shards)]
        for p in parts:
            assert p.shape == (8 // shards, 16)


@given(st.integers(0, 500))
@settings(**SETTINGS)
def test_data_tokens_in_vocab(step):
    d = SyntheticLMData(DataConfig(vocab_size=32, seq_len=8, global_batch=4))
    b = d.batch_at(step)
    assert b["inputs"].min() >= 0 and b["inputs"].max() < 32
    # targets are inputs shifted by one
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


# -- kernel semantics ------------------------------------------------------------

@given(st.integers(1, 2), st.integers(4, 24), st.integers(1, 2),
       st.integers(0, 100))
@settings(**SETTINGS)
def test_blockwise_attention_equals_plain(b, s, hkv, seed):
    rng = np.random.default_rng(seed)
    g = 2
    d = 8
    q = jnp.asarray(rng.normal(size=(b, s, hkv * g, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    ref = aref.attention_ref(q, k, v, causal=True)
    out = aref.attention_blockwise_ref(q, k, v, causal=True, kv_block=7)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=1e-3)


@given(st.integers(2, 64), st.integers(1, 64), st.integers(0, 50))
@settings(**SETTINGS)
def test_selective_scan_chunk_invariance(l, chunk, seed):
    rng = np.random.default_rng(seed)
    b, c, n = 1, 4, 3
    x = jnp.asarray(rng.normal(size=(b, l, c)).astype(np.float32))
    dt = jnp.abs(jnp.asarray(rng.normal(size=(b, l, c)).astype(np.float32))) * 0.1
    A = -jnp.abs(jnp.asarray(rng.normal(size=(c, n)).astype(np.float32)))
    Bm = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
    ref = sref.selective_scan_ref(x, dt, A, Bm, Cm, D)
    out = sref.selective_scan_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-3)


@given(st.integers(2, 40), st.sampled_from([4, 8, 16]), st.integers(0, 50))
@settings(**SETTINGS)
def test_ssd_chunk_invariance(l, chunk, seed):
    rng = np.random.default_rng(seed)
    b, h, p, g, n = 1, 2, 4, 1, 3
    x = jnp.asarray(rng.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.abs(jnp.asarray(rng.normal(size=(b, l, h)).astype(np.float32))) * 0.1
    A = -jnp.abs(jnp.asarray(rng.normal(size=(h,)).astype(np.float32)))
    Bm = jnp.asarray(rng.normal(size=(b, l, g, n)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(b, l, g, n)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=(h,)).astype(np.float32))
    r1 = ssdref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
    r2 = ssdref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=l)
    np.testing.assert_allclose(r1, r2, atol=1e-4, rtol=1e-3)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 100))
@settings(**SETTINGS)
def test_paged_decode_bit_identical_to_dense(b, n_pages, seed):
    # any scatter of the dense cache across pool pages (here: a random
    # permutation) gathers back to the identical rows, so paged decode
    # attention equals the dense decode reference bit-for-bit — the invariant
    # the whole paged serving path rests on
    from repro.kernels.paged_attention import ref as pref
    rng = np.random.default_rng(seed)
    ps, hq, hkv, d = 4, 4, 2, 8
    l = n_pages * ps
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)).astype(np.float32))
    k = rng.normal(size=(b, l, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, l, hkv, d)).astype(np.float32)
    lens = jnp.asarray(rng.integers(1, l + 1, size=b), jnp.int32)
    perm = rng.permutation(b * n_pages)
    k_pages = np.zeros((b * n_pages, ps, hkv, d), np.float32)
    v_pages = np.zeros_like(k_pages)
    table = np.zeros((b, n_pages), np.int32)
    for bi in range(b):
        for p in range(n_pages):
            pid = int(perm[bi * n_pages + p])
            k_pages[pid] = k[bi, p * ps:(p + 1) * ps]
            v_pages[pid] = v[bi, p * ps:(p + 1) * ps]
            table[bi, p] = pid
    out = pref.paged_decode_attention_ref(
        q, jnp.asarray(k_pages), jnp.asarray(v_pages), jnp.asarray(table),
        lens)
    ref = aref.decode_attention_ref(q, jnp.asarray(k), jnp.asarray(v), lens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# -- checkpoint roundtrip -------------------------------------------------------

@given(shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                       min_size=1, max_size=4),
       seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip_arbitrary_trees(shapes, seed, tmp_path_factory):
    from repro.checkpoint.manager import CheckpointManager
    from repro.utils.trees import tree_allclose

    rng = np.random.default_rng(seed)
    tree = {f"k{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(shapes)}
    d = tmp_path_factory.mktemp("ckpt")
    mgr = CheckpointManager(str(d), keep=1)
    mgr.save(1, tree, blocking=True)
    out = mgr.restore(1, jax.eval_shape(lambda: tree))
    assert tree_allclose(tree, out)
