"""Tests for the static-analysis subsystem (``repro.analysis``).

Fixture-driven: one known-bad snippet per lint rule (asserting the rule
fires at the right location), a deliberately aliased paged-attention-style
index map the race detector must flag, an over-VMEM launch config the
footprint check must reject, suppression/baseline hygiene, and a clean-tree
run asserting zero unsuppressed findings.
"""

import json
import os
import textwrap

import pytest

from repro.analysis import engine
from repro.analysis.__main__ import main as cli_main
from repro.analysis import audits, contracts, kernels

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lint(tmp_path, source, name="snippet.py"):
    path = tmp_path / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return contracts.lint_file(str(path))


def _lines(findings, rule):
    return sorted(f.line for f in findings if f.rule == rule)


# --------------------------------------------------------------------------
# lint rules: one bad snippet per rule, with location
# --------------------------------------------------------------------------

def test_pallas_tpu_outside_compat(tmp_path):
    findings = _lint(tmp_path, """\
        from jax.experimental.pallas import tpu as pltpu
        import jax.experimental.pallas.tpu as other
    """)
    assert _lines(findings, "pallas-tpu-outside-compat") == [1, 2]


def test_pallas_tpu_attribute_chain(tmp_path):
    findings = _lint(tmp_path, """\
        from jax.experimental import pallas as pl

        def f():
            return pl.tpu.VMEM
    """)
    assert 4 in _lines(findings, "pallas-tpu-outside-compat")


def test_pallas_import_location(tmp_path):
    findings = _lint(tmp_path, """\
        from jax.experimental import pallas as pl
    """)
    assert _lines(findings, "pallas-import-location") == [1]


def test_pallas_import_legal_in_kernel_file(tmp_path):
    findings = _lint(tmp_path / "repro" / "kernels" / "fam", """\
        from jax.experimental import pallas as pl
    """, name="kernel.py")
    assert _lines(findings, "pallas-import-location") == []


def test_sharding_version_gate(tmp_path):
    findings = _lint(tmp_path, """\
        import jax

        def probe():
            m = getattr(jax.sharding, "get_abstract_mesh", None)
            return hasattr(jax, "set_mesh") or m
    """)
    assert _lines(findings, "sharding-version-gate") == [4, 5]


def test_unseeded_randomness(tmp_path):
    findings = _lint(tmp_path, """\
        import numpy as np
        import random

        def f():
            a = np.random.rand(3)
            rng = np.random.default_rng()
            b = random.random()
            return a, rng, b
    """)
    lines = _lines(findings, "unseeded-randomness")
    assert 2 in lines    # stdlib random import
    assert 5 in lines    # np.random.rand
    assert 6 in lines    # argless default_rng()
    assert 7 in lines    # random.random()


def test_seeded_randomness_is_clean(tmp_path):
    findings = _lint(tmp_path, """\
        import numpy as np

        def f(seed):
            return np.random.default_rng(seed).normal(size=3)
    """)
    assert _lines(findings, "unseeded-randomness") == []


def test_wall_clock(tmp_path):
    findings = _lint(tmp_path, """\
        import time
        from time import perf_counter

        def f():
            return time.time() + perf_counter()
    """)
    assert _lines(findings, "wall-clock") == [5, 5]


def test_wall_clock_allow_list():
    # a real allow-listed module lints clean despite perf_counter use
    findings = contracts.lint_file(
        os.path.join(REPO_ROOT, "src", "repro", "serving", "replay.py"))
    assert _lines(findings, "wall-clock") == []


def test_broad_except(tmp_path):
    findings = _lint(tmp_path, """\
        def f():
            try:
                return 1
            except Exception:
                pass
            try:
                return 2
            except:
                pass
    """)
    assert _lines(findings, "broad-except") == [4, 8]


def test_span_balance_async(tmp_path):
    findings = _lint(tmp_path, """\
        from repro.obs import trace as obs_trace

        def f(uid):
            obs_trace.active().async_begin("request", uid)

        def g(uid):
            tr = obs_trace.active()
            tr.async_begin("step", uid)
            tr.async_end("step", uid)
    """)
    assert _lines(findings, "span-balance") == [4]   # "request" never ends


def test_span_balance_unentered_handle(tmp_path):
    findings = _lint(tmp_path, """\
        from repro.obs import trace as obs_trace

        def bad():
            s = obs_trace.span("work")
            return 1

        def discarded():
            obs_trace.span("dropped")

        def good():
            s = obs_trace.span("work")
            with s:
                return 1

        def good_inline():
            with obs_trace.span("work"):
                return 1
    """)
    assert _lines(findings, "span-balance") == [4, 8]


def test_parse_error(tmp_path):
    findings = _lint(tmp_path, "def broken(:\n")
    assert _lines(findings, "parse-error") == [1]


# --------------------------------------------------------------------------
# suppressions
# --------------------------------------------------------------------------

def test_suppression_silences_with_reason(tmp_path):
    path = tmp_path / "s.py"
    path.write_text(textwrap.dedent("""\
        import time

        def f():
            # repro: ignore[wall-clock] -- boot banner only
            return time.time()
    """))
    raw = contracts.lint_file(str(path))
    rep = engine._apply_suppressions(raw, [str(path)], report_unused=True)
    assert rep.findings == []
    assert len(rep.suppressed) == 1
    assert rep.suppressed[0][1] == "boot banner only"


def test_suppression_requires_reason(tmp_path):
    path = tmp_path / "s.py"
    path.write_text(textwrap.dedent("""\
        import time

        def f():
            return time.time()  # repro: ignore[wall-clock]
    """))
    raw = contracts.lint_file(str(path))
    rep = engine._apply_suppressions(raw, [str(path)], report_unused=True)
    rules = {f.rule for f in rep.findings}
    assert "suppression-syntax" in rules   # missing -- reason
    assert "wall-clock" in rules           # and it does NOT suppress


def test_suppression_unknown_rule(tmp_path):
    path = tmp_path / "s.py"
    path.write_text("x = 1  # repro: ignore[no-such-rule] -- whatever\n")
    raw = contracts.lint_file(str(path))
    rep = engine._apply_suppressions(raw, [str(path)], report_unused=True)
    assert [f.rule for f in rep.findings] == ["suppression-syntax"]


def test_unused_suppression_flagged(tmp_path):
    path = tmp_path / "s.py"
    path.write_text("x = 1  # repro: ignore[wall-clock] -- stale excuse\n")
    rep = engine._apply_suppressions([], [str(path)], report_unused=True)
    assert [f.rule for f in rep.findings] == ["unused-suppression"]


def test_suppression_in_string_literal_ignored(tmp_path):
    path = tmp_path / "s.py"
    path.write_text('PATTERN = "# repro: ignore[wall-clock] -- nope"\n')
    supp, bad = engine.parse_suppressions(path.read_text(), str(path))
    assert supp == {} and bad == []


# --------------------------------------------------------------------------
# race detector
# --------------------------------------------------------------------------

ALIASED_PAGED = """\
import jax
from jax.experimental import pallas as pl
from repro import compat

def launch(q, k_pages, v_pages, page_table, *, interpret=False):
    b, hkv, n_pages, g, d = 2, 2, 4, 4, 64
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ip, tbl: (ib, 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 64, 1, d), lambda ib, ih, ip, tbl: (tbl[ib, ip], 0, ih, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(k_pages.shape, q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, q, k_pages, v_pages)
"""


def test_race_detector_flags_aliased_paged_index_map():
    # a paged-attention-style *output* written through the page table: two
    # slots whose tables collide write the same pool block from parallel
    # grid points
    findings = kernels.analyze_kernel_source(ALIASED_PAGED)
    races = [f for f in findings if f.rule == "kernel-write-race"]
    assert races, findings
    assert races[0].line == 13   # the out_specs BlockSpec line


def test_race_detector_simple_alias():
    src = """\
import jax
from jax.experimental import pallas as pl

def launch(x, interpret=False):
    return pl.pallas_call(
        kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
"""
    findings = kernels.analyze_kernel_source(src)
    assert [f.rule for f in findings] == ["kernel-write-race"]


def test_race_detector_sequential_accumulation_legal():
    src = """\
import jax
from jax.experimental import pallas as pl
from repro import compat

def launch(x, interpret=False):
    return pl.pallas_call(
        kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x)
"""
    findings = kernels.analyze_kernel_source(src)
    assert [f.rule for f in findings] == []


def test_race_detector_passes_all_registered_families():
    from repro.kernels import dispatch
    assert len(dispatch.families()) >= 5
    for family in dispatch.families():
        sites, parse_findings = kernels._family_sites(family)
        assert sites, family
        race = [f for s in sites for f in kernels.race_findings(s)
                if f.rule == "kernel-write-race"]
        assert race == [], (family, race)
        assert parse_findings == []


# --------------------------------------------------------------------------
# VMEM footprint
# --------------------------------------------------------------------------

VMEM_FIXTURE = """\
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro import compat

def launch(x, block=128, interpret=False):
    r, d = x.shape
    return pl.pallas_call(
        kernel,
        grid=(r // block,),
        in_specs=[pl.BlockSpec((block, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[compat.vmem((block, d), jnp.float32)],
        interpret=interpret,
    )(x)
"""


def test_vmem_check_rejects_oversized_config():
    budget = 4 * 2 ** 20
    findings = kernels.analyze_kernel_source(
        VMEM_FIXTURE, configs=[{"block": 4096}], vmem_budget=budget)
    assert [f.rule for f in findings] == ["kernel-vmem-budget"]
    assert "4096" in findings[0].message


def test_vmem_check_passes_small_config():
    budget = 4 * 2 ** 20
    findings = kernels.analyze_kernel_source(
        VMEM_FIXTURE, configs=[{"block": 64}], vmem_budget=budget)
    assert findings == []


def test_vmem_cross_check_covers_every_launch_space_config():
    from repro.kernels import dispatch
    kfindings, checked = kernels.check_registered_families()
    errors = [f for f in kfindings if f.severity == engine.ERROR
              and f.rule != "kernel-option-unused"]
    assert errors == []
    expected = 0
    for family in dispatch.families():
        n = 1
        for o in dispatch.get_family(family).launch_options:
            n *= len(o.values)
        expected += n
    assert checked == expected >= 100


def test_static_vmem_monotone_in_block():
    sites = kernels.parse_kernel_source(VMEM_FIXTURE, "<f>")
    assert len(sites) == 1
    small = kernels.static_vmem_bytes(sites[0], {"block": 64})
    big = kernels.static_vmem_bytes(sites[0], {"block": 4096})
    assert 0 < small < big


# --------------------------------------------------------------------------
# registry audits
# --------------------------------------------------------------------------

def test_audits_clean_on_tree():
    assert audits.run_audits() == []


def test_audit_catches_default_outside_domain():
    from repro.core.spaces import ConfigSpace, Option
    space = ConfigSpace([Option("serving.bad", (1, 2), default=1)])
    object.__setattr__(space.options[0], "default", 99)
    findings = audits._audit_space(space, "fixture", audits)
    assert [f.rule for f in findings] == ["audit-option-space"]


def test_audit_registry_names_reject_malformed():
    from repro.envs import measure
    measure.SHIFT_KINDS["Bad Kind!"] = ()
    try:
        findings = audits.audit_registry_names()
        rules = [f.rule for f in findings]
        # ill-formed kind + empty shift tuple (+ the shifted:<kind> backend
        # name derived from it)
        assert rules.count("audit-registry-names") >= 2
    finally:
        del measure.SHIFT_KINDS["Bad Kind!"]
    assert audits.audit_registry_names() == []


# --------------------------------------------------------------------------
# baseline hygiene
# --------------------------------------------------------------------------

def test_baseline_grandfathers_then_goes_stale(tmp_path):
    bad = tmp_path / "legacy.py"
    bad.write_text("import time\nT0 = time.time()\n")
    baseline = tmp_path / "baseline.json"

    rep = engine.run_analysis([str(bad)], kernels=False, audits=False,
                              baseline_path=None)
    assert [f.rule for f in rep.findings] == ["wall-clock"]
    engine.write_baseline(rep.findings, str(baseline))

    # grandfathered: finding still present, baseline absorbs it
    rep2 = engine.run_analysis([str(bad)], kernels=False, audits=False,
                               baseline_path=str(baseline))
    assert rep2.findings == [] and len(rep2.grandfathered) == 1
    assert rep2.gate_ok

    # the violation gets fixed but the baseline is not regenerated: the
    # stale entry is itself a gate failure
    bad.write_text("T0 = 0.0\n")
    rep3 = engine.run_analysis([str(bad)], kernels=False, audits=False,
                               baseline_path=str(baseline))
    assert [f.rule for f in rep3.findings] == ["stale-baseline"]
    assert not rep3.gate_ok


def test_checked_in_baseline_is_empty():
    baseline = engine.load_baseline(
        os.path.join(REPO_ROOT, "analysis_baseline.json"))
    assert baseline == []


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_cli_gate_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\nT0 = time.time()\n")
    missing = str(tmp_path / "no_baseline.json")
    rc = cli_main([str(bad), "--gate", "--no-kernels", "--no-audits",
                   "--baseline", missing])
    assert rc == 1
    out = capsys.readouterr().out
    assert "[wall-clock]" in out

    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    rc = cli_main([str(good), "--gate", "--no-kernels", "--no-audits",
                   "--baseline", missing])
    assert rc == 0


def test_cli_json_and_github_formats(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import random\n")
    missing = str(tmp_path / "no_baseline.json")
    rc = cli_main([str(bad), "--format", "json", "--no-kernels",
                   "--no-audits", "--baseline", missing])
    assert rc == 0  # no --gate: report only
    doc = json.loads(capsys.readouterr().out)
    assert doc["summary"]["errors"] == 1
    assert doc["findings"][0]["rule"] == "unseeded-randomness"

    cli_main([str(bad), "--format", "github", "--gate", "--no-kernels",
              "--no-audits", "--baseline", missing])
    out = capsys.readouterr().out
    assert "::error file=" in out and "title=unseeded-randomness" in out


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("kernel-write-race", "kernel-vmem-budget", "wall-clock",
                 "broad-except", "stale-baseline"):
        assert rule in out


# --------------------------------------------------------------------------
# the tree itself is clean
# --------------------------------------------------------------------------

@pytest.fixture()
def repo_cwd(monkeypatch):
    monkeypatch.chdir(REPO_ROOT)


def test_clean_tree_zero_unsuppressed_findings(repo_cwd):
    rep = engine.run_analysis(
        ["src"], baseline_path=os.path.join(REPO_ROOT,
                                            "analysis_baseline.json"))
    assert rep.errors == [], [f"{f.path}:{f.line} [{f.rule}] {f.message}"
                              for f in rep.errors]
    assert rep.files_scanned > 100
    assert rep.configs_checked >= 100
    # every inline suppression carries its justification
    assert all(reason for _, reason in rep.suppressed)
