"""Environment-shift subsystem: EnvShift composition, ShiftedAnalyticBackend
determinism / fidelity-gap properties, shifted:<kind> backend selection, the
transfer benchmark runner's document shape + gate, and the train launcher's
--tune-launch wiring (spy-verified, mirroring serve)."""

import json

import numpy as np
import pytest

from repro.envs.kernel_launch import KernelLaunchEnv, KernelWorkload
from repro.envs.measure import (
    MEASURE_BACKEND_ENV, SHIFT_KINDS, AnalyticBackend, EnvShift, HardwareSpec,
    LaunchGeometry, ShiftedAnalyticBackend, make_backend,
    resolve_backend_name, shift_kinds, shifts_for)
from repro.kernels import dispatch
from repro.tuner.bench import (
    BenchCell, cell_by_name, gate_summary, make_shifted_pair,
    run_transfer_bench, target_optimum)

SERVE = KernelWorkload()
FAMS = None  # filled lazily per test via dispatch.families()


def _fams():
    return sorted(dispatch.families())


def _grid(n=20, seed=5):
    space = dispatch.launch_space()
    return [space.default_config()] + space.sample(np.random.default_rng(seed), n)


# --------------------------------------------------------------------------
# EnvShift composition
# --------------------------------------------------------------------------

def test_env_shift_applies_scales_and_overrides():
    s = EnvShift(name="s", mxu_scale=0.5, hbm_scale=2.0, seq_scale=2.0,
                 batch_scale=0.25, vmem_scale=0.5,
                 launch_overhead_scale=3.0, noise_scale=2.0,
                 workload_update={"heads": 16})
    w, hw = s.apply(SERVE, HardwareSpec())
    assert w.seq_len == SERVE.seq_len * 2
    assert w.batch == SERVE.batch // 4
    assert w.vmem_limit == SERVE.vmem_limit // 2
    assert w.launch_overhead_us == SERVE.launch_overhead_us * 3
    assert w.noise == SERVE.noise * 2
    assert w.heads == 16
    assert hw.mxu_flops_per_us == HardwareSpec().mxu_flops_per_us * 0.5
    assert hw.hbm_bytes_per_us == HardwareSpec().hbm_bytes_per_us * 2.0
    # identity shift is a no-op returning the same objects
    w2, hw2 = EnvShift().apply(SERVE, HardwareSpec())
    assert w2 is SERVE and hw2.mxu_flops_per_us == HardwareSpec().mxu_flops_per_us


def test_shifts_compose_left_to_right():
    a = EnvShift(name="a", seq_scale=2.0)
    b = EnvShift(name="b", seq_scale=2.0, mxu_scale=0.5)
    w, hw = SERVE, HardwareSpec()
    for s in (a, b):
        w, hw = s.apply(w, hw)
    assert w.seq_len == SERVE.seq_len * 4
    assert hw.mxu_flops_per_us == HardwareSpec().mxu_flops_per_us * 0.5


def test_shift_registry():
    assert set(shift_kinds()) >= {"hardware", "workload", "noise",
                                  "feasibility", "severe"}
    assert shifts_for("severe") == (SHIFT_KINDS["hardware"]
                                    + SHIFT_KINDS["workload"]
                                    + SHIFT_KINDS["feasibility"]
                                    + SHIFT_KINDS["noise"])
    with pytest.raises(ValueError, match="unknown shift kind"):
        shifts_for("bogus")


# --------------------------------------------------------------------------
# ShiftedAnalyticBackend
# --------------------------------------------------------------------------

def test_no_shifts_is_bit_identical_to_analytic():
    a = AnalyticBackend(SERVE, _fams(), seed=0)
    s = ShiftedAnalyticBackend(SERVE, _fams(), seed=0, shifts=())
    for cfg in _grid():
        ca, ya = a.measure(cfg)
        cs, ys = s.measure(cfg)
        assert ca == cs
        assert ya == ys or (np.isinf(ya) and np.isinf(ys))


def test_shifted_backend_deterministic_per_seed():
    for kind in shift_kinds():
        runs = []
        for _ in range(2):
            b = ShiftedAnalyticBackend(SERVE, _fams(), seed=7, shifts=kind)
            runs.append([b.measure(c)[1] for c in _grid(8)])
        assert runs[0] == runs[1], kind


def test_every_kind_opens_a_fidelity_gap():
    # each registered shift kind must CHANGE the measurement somewhere on the
    # grid — a shift that measures identically to the source is not a shift
    base = AnalyticBackend(SERVE, _fams(), seed=0)
    base_ys = [base.measure(c)[1] for c in _grid()]
    for kind in shift_kinds():
        b = ShiftedAnalyticBackend(SERVE, _fams(), seed=0, shifts=kind)
        ys = [b.measure(c)[1] for c in _grid()]
        assert ys != base_ys, kind


def test_feasibility_shift_tightens_the_gate():
    base = AnalyticBackend(SERVE, _fams(), seed=0)
    tight = ShiftedAnalyticBackend(SERVE, _fams(), seed=0,
                                   shifts="feasibility")
    grid = _grid(60)
    inf_base = sum(np.isinf(base.measure(c)[1]) for c in grid)
    inf_tight = sum(np.isinf(tight.measure(c)[1]) for c in grid)
    assert inf_tight > inf_base
    # source-feasible default config is infeasible in the shifted target:
    # the transfer case where blindly deploying the source optimum fails
    assert np.isfinite(base.measure(grid[0])[1])
    assert np.isinf(tight.measure(grid[0])[1])


def test_hetero_noise_grows_with_latency():
    b = ShiftedAnalyticBackend(SERVE, _fams(), seed=0, shifts="noise")
    lo, hi = b._sigma(10.0), b._sigma(1e6)
    assert hi > lo > b.base_workload.noise
    # analytic sigma is constant
    a = AnalyticBackend(SERVE, _fams(), seed=0)
    assert a._sigma(10.0) == a._sigma(1e6) == SERVE.noise


def test_workload_shift_changes_counters_not_just_latency():
    cfg = dispatch.launch_space().default_config()
    base_counters, _ = AnalyticBackend(SERVE, _fams(), 0).measure(cfg)
    w_counters, _ = ShiftedAnalyticBackend(SERVE, _fams(), 0,
                                           shifts="workload").measure(cfg)
    assert w_counters != base_counters


# --------------------------------------------------------------------------
# selection plumbing
# --------------------------------------------------------------------------

def test_shifted_backend_name_resolution(monkeypatch):
    assert resolve_backend_name("shifted:hardware") == "shifted:hardware"
    monkeypatch.setenv(MEASURE_BACKEND_ENV, "shifted:noise")
    assert resolve_backend_name(None) == "shifted:noise"
    b = make_backend(None, SERVE, _fams())
    assert isinstance(b, ShiftedAnalyticBackend)
    assert b.shift_names == ("noise",)
    env = KernelLaunchEnv(SERVE)
    assert isinstance(env.backend, ShiftedAnalyticBackend)
    with pytest.raises(ValueError):
        resolve_backend_name("shifted:bogus")
    monkeypatch.setenv(MEASURE_BACKEND_ENV, "shifted:bogus")
    with pytest.raises(ValueError):
        resolve_backend_name(None)


def test_env_accepts_shifted_instance():
    inst = ShiftedAnalyticBackend(SERVE, _fams(), seed=0, shifts="hardware")
    env = KernelLaunchEnv(SERVE, backend=inst)
    assert env.backend is inst
    assert env.families == list(_fams())
    _, y = env.intervene(env.space.default_config())
    assert np.isfinite(y)


# --------------------------------------------------------------------------
# transfer benchmark runner
# --------------------------------------------------------------------------

TINY_CELL = BenchCell(
    "tiny", KernelWorkload(name="tiny", batch=1, seq_len=128, heads=2,
                           kv_heads=1, head_dim=16, d_model=64, channels=64,
                           scan_state=4, ssm_heads=2, ssm_head_dim=16,
                           ssm_state=8))


def test_make_shifted_pair_shares_the_space():
    src, tgt = make_shifted_pair(TINY_CELL, "hardware", seed=0)
    assert src.space.names == tgt.space.names
    assert isinstance(tgt.backend, ShiftedAnalyticBackend)
    assert not isinstance(src.backend, ShiftedAnalyticBackend)


def test_cell_by_name():
    assert cell_by_name("serve-8b").workload == KernelWorkload()
    with pytest.raises(ValueError, match="unknown bench cell"):
        cell_by_name("nope")


def test_transfer_bench_document_shape_and_gate():
    doc = run_transfer_bench(
        cells=(TINY_CELL,), shifts=("hardware", "noise", "workload"),
        methods=("cameo", "random"), budget=4, n_source=24,
        n_target_init=2, seeds=(0,), pool=48)
    # JSON-clean (no inf/nan): this is the BENCH_transfer.json document
    json.dumps(doc)
    assert doc["meta"]["budget"] == 4
    assert len(doc["cells"]) == 3  # 1 cell x 3 shift kinds
    for cell in doc["cells"]:
        assert cell["y_opt"] > 0
        assert set(cell["methods"]) == {"cameo", "random"}
        for stats in cell["methods"].values():
            assert len(stats["runs"]) == 1
            run = stats["runs"][0]
            assert len(run["regret"]) == len(run["best_y_trace"]) == 4
            finite = [r for r in run["regret"] if r is not None]
            assert all(r >= 0 for r in finite)
            assert run["n_target_init"] == 2
            # regret is monotone non-increasing over finite suffix
            tail = [r for r in run["regret"] if r is not None]
            assert all(a >= b - 1e-12 for a, b in zip(tail, tail[1:]))
    gate = doc["gate"]
    assert gate["checked"] and {"champion_mean_final_regret",
                                "reference_mean_final_regret"} <= set(gate)


def test_gate_summary_orders_and_vacuous_pass():
    doc = {"cells": [{"cell": "c", "shift": "s", "methods": {
        "cameo": {"runs": [{"final_regret": 0.1}]},
        "random": {"runs": [{"final_regret": 0.5}]}}}]}
    g = gate_summary(doc)
    assert g["checked"] and g["passed"]
    g2 = gate_summary({"cells": [{"methods": {
        "cameo": {"runs": [{"final_regret": 0.9}]},
        "random": {"runs": [{"final_regret": 0.2}]}}}]})
    assert g2["checked"] and not g2["passed"]
    assert gate_summary({"cells": []}) == {
        "checked": False, "passed": True, "champion": "cameo",
        "reference": "random"}


def test_target_optimum_is_finite_and_beats_default():
    y_opt = target_optimum(TINY_CELL, "hardware", pool=64)
    assert np.isfinite(y_opt) and y_opt > 0


# --------------------------------------------------------------------------
# launcher wiring: tuned config reaches the train step (mirrors serve)
# --------------------------------------------------------------------------

def test_tune_launch_config_deploys_into_train_step():
    import jax
    import jax.numpy as jnp

    from conftest import tiny_model_config
    from repro.launch.tune import launch_workload_for, tune_launch_config
    from repro.models.model import build_model
    from repro.train.optimizer import make_optimizer
    from repro.train.train_step import init_train_state, make_train_step
    from repro.utils.config import RunConfig, ShapeConfig

    cfg = tiny_model_config()
    w = launch_workload_for(cfg, batch=2, seq_len=16, kind="train")
    assert w.name == f"train-{cfg.name}" and w.d_model == cfg.d_model

    lc = tune_launch_config(cfg, 2, 16, budget=2,
                            backend="shifted:hardware", kind="train", seed=0)
    assert lc and all("." in k for k in lc)
    assert {k.split(".")[0] for k in lc} == {"rmsnorm", "flash_attention"}

    run = RunConfig(model=cfg, shape=ShapeConfig("t", 16, 2, "train"))
    model = build_model(cfg)
    opt = make_optimizer(run.train)
    step = jax.jit(make_train_step(model, run, opt, launch_config=lc))
    state = init_train_state(model, run, opt, jax.random.PRNGKey(0))
    batch = {"inputs": jnp.zeros((2, 16), jnp.int32),
             "targets": jnp.zeros((2, 16), jnp.int32)}
    with dispatch.record_resolutions() as rec:
        state, metrics = step(state, batch)
    attn = [r.launch for r in rec if r.family == "flash_attention"]
    assert attn, "no flash_attention dispatch recorded in train step"
    for launch in attn:
        assert launch["q_block"] == lc["flash_attention.q_block"]
        assert launch["kv_block"] == lc["flash_attention.kv_block"]
    norm = [r.launch for r in rec if r.family == "rmsnorm"]
    assert norm and all(
        l["row_block"] == lc["rmsnorm.row_block"] for l in norm)
    assert np.isfinite(float(metrics["loss"]))


def test_measure_backend_arg_validates():
    import argparse

    from repro.launch.tune import measure_backend_arg

    assert measure_backend_arg("analytic") == "analytic"
    assert measure_backend_arg("shifted:severe") == "shifted:severe"
    with pytest.raises(argparse.ArgumentTypeError):
        measure_backend_arg("bogus")
