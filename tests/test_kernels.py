"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracle,
swept over shapes and dtypes, plus gradient checks for the custom-VJP ops.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import ref as aref
from repro.kernels.flash_attention.kernel import (
    decode_attention_pallas, flash_attention_pallas)
from repro.kernels.mamba_scan import ref as sref
from repro.kernels.mamba_scan.kernel import selective_scan_pallas
from repro.kernels.rmsnorm import ref as rref
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.ssd import ref as ssdref
from repro.kernels.ssd.kernel import ssd_pallas

RNG = np.random.default_rng(0)


def rand(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,skv,hq,hkv,d", [
    (1, 16, 16, 2, 2, 8),       # MHA, tiny
    (2, 96, 96, 8, 2, 32),      # GQA g=4, unaligned seq
    (1, 33, 65, 4, 1, 16),      # MQA, prime-ish seq (padding path)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(b, sq, skv, hq, hkv, d, causal):
    q, k, v = rand(b, sq, hq, d), rand(b, skv, hkv, d), rand(b, skv, hkv, d)
    ref = aref.attention_ref(q, k, v, causal=causal)
    out = flash_attention_pallas(q, k, v, causal=causal, q_block=16,
                                 kv_block=16, interpret=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("sw,cap", [(0, 0.0), (7, 0.0), (0, 20.0), (9, 30.0)])
def test_flash_attention_window_softcap(sw, cap):
    q, k, v = rand(2, 48, 4, 16), rand(2, 48, 2, 16), rand(2, 48, 2, 16)
    ref = aref.attention_ref(q, k, v, causal=True, sliding_window=sw,
                             logit_softcap=cap)
    out = flash_attention_pallas(q, k, v, causal=True, sliding_window=sw,
                                 logit_softcap=cap, q_block=16, kv_block=16,
                                 interpret=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = rand(1, 32, 4, 16).astype(dtype)
    k = rand(1, 32, 2, 16).astype(dtype)
    v = rand(1, 32, 2, 16).astype(dtype)
    ref = aref.attention_ref(q, k, v, causal=True)
    out = flash_attention_pallas(q, k, v, causal=True, q_block=16,
                                 kv_block=16, interpret=True)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), atol=tol, rtol=tol)


def test_blockwise_ref_matches_plain():
    q, k, v = rand(2, 40, 4, 16), rand(2, 40, 2, 16), rand(2, 40, 2, 16)
    for kvb in (8, 16, 64):
        out = aref.attention_blockwise_ref(q, k, v, causal=True, kv_block=kvb)
        ref = aref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("sw", [0, 9])
def test_decode_attention_matches_oracle(sw):
    b, skv, hq, hkv, d = 2, 80, 8, 2, 32
    q = rand(b, 1, hq, d)
    kc, vc = rand(b, skv, hkv, d), rand(b, skv, hkv, d)
    clen = jnp.asarray([13, 77], jnp.int32)
    ref = aref.decode_attention_ref(q, kc, vc, clen, sliding_window=sw)
    out = decode_attention_pallas(q, kc, vc, clen, sliding_window=sw,
                                  kv_block=32, interpret=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


def test_attention_grads_match_plain_ref():
    q, k, v = rand(2, 24, 4, 16), rand(2, 24, 2, 16), rand(2, 24, 2, 16)
    f_op = lambda q, k, v: (ops.flash_attention(q, k, v, causal=True,
                                                kv_block=8) ** 2).sum()
    f_ref = lambda q, k, v: (aref.attention_ref(q, k, v, causal=True) ** 2).sum()
    g1 = jax.grad(f_op, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-3)


# --------------------------------------------------------------------------
# mamba selective scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,c,n,chunk,cblk", [
    (1, 16, 8, 4, 8, 8),
    (2, 72, 48, 8, 16, 16),
    (1, 50, 24, 16, 32, 8),   # pad path
])
def test_selective_scan_matches_oracle(b, l, c, n, chunk, cblk):
    x, dt = rand(b, l, c), jnp.abs(rand(b, l, c)) * 0.1
    A = -jnp.abs(rand(c, n))
    Bm, Cm, D = rand(b, l, n), rand(b, l, n), rand(c)
    ref = sref.selective_scan_ref(x, dt, A, Bm, Cm, D)
    out = selective_scan_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                                c_block=cblk, interpret=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-3)


def test_selective_scan_chunked_equals_unchunked():
    x, dt = rand(2, 40, 12), jnp.abs(rand(2, 40, 12)) * 0.1
    A = -jnp.abs(rand(12, 4))
    Bm, Cm, D = rand(2, 40, 4), rand(2, 40, 4), rand(12)
    ref = sref.selective_scan_ref(x, dt, A, Bm, Cm, D)
    for chunk in (5, 8, 40):
        out = sref.selective_scan_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-3)


def test_selective_scan_final_state_consistent_with_steps():
    b, l, c, n = 1, 12, 6, 4
    x, dt = rand(b, l, c), jnp.abs(rand(b, l, c)) * 0.1
    A = -jnp.abs(rand(c, n))
    Bm, Cm, D = rand(b, l, n), rand(b, l, n), rand(c)
    _, h_final = sref.selective_scan_chunked_ref(x, dt, A, Bm, Cm, D, chunk=4,
                                                 return_state=True)
    h = jnp.zeros((b, c, n))
    for t in range(l):
        h, _ = sref.selective_scan_step_ref(h, x[:, t], dt[:, t], A,
                                            Bm[:, t], Cm[:, t], D)
    np.testing.assert_allclose(h_final, h, atol=1e-4, rtol=1e-3)


def test_selective_scan_grads():
    x, dt = rand(2, 32, 8), jnp.abs(rand(2, 32, 8)) * 0.1
    A = -jnp.abs(rand(8, 4))
    Bm, Cm, D = rand(2, 32, 4), rand(2, 32, 4), rand(8)
    f_op = lambda *a: (ops.selective_scan(*a, chunk=8) ** 2).sum()
    f_ref = lambda *a: (sref.selective_scan_ref(*a) ** 2).sum()
    g1 = jax.grad(f_op, argnums=tuple(range(6)))(x, dt, A, Bm, Cm, D)
    g2 = jax.grad(f_ref, argnums=tuple(range(6)))(x, dt, A, Bm, Cm, D)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-3)


# --------------------------------------------------------------------------
# SSD
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (1, 16, 2, 8, 1, 4, 8),
    (2, 48, 4, 16, 2, 8, 16),
    (1, 30, 4, 8, 4, 4, 16),   # pad path
])
def test_ssd_matches_oracle(b, l, h, p, g, n, chunk):
    x, dt = rand(b, l, h, p), jnp.abs(rand(b, l, h)) * 0.1
    A = -jnp.abs(rand(h))
    Bm, Cm, D = rand(b, l, g, n), rand(b, l, g, n), rand(h)
    ref = ssdref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
    out = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-3)


def test_ssd_matches_naive_recurrence():
    b, l, h, p, g, n = 1, 10, 2, 4, 1, 3
    x, dt = rand(b, l, h, p), jnp.abs(rand(b, l, h)) * 0.1
    A = -jnp.abs(rand(h))
    Bm, Cm, D = rand(b, l, g, n), rand(b, l, g, n), rand(h)
    out = ssdref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=5)
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(l):
        state, y = ssdref.ssd_step_ref(state, x[:, t], dt[:, t], A,
                                       Bm[:, t], Cm[:, t], D)
        ys.append(y)
    naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(out, naive, atol=1e-4, rtol=1e-3)


def test_ssd_final_state():
    b, l, h, p, g, n = 1, 12, 2, 4, 1, 3
    x, dt = rand(b, l, h, p), jnp.abs(rand(b, l, h)) * 0.1
    A = -jnp.abs(rand(h))
    Bm, Cm, D = rand(b, l, g, n), rand(b, l, g, n), rand(h)
    _, s_final = ssdref.ssd_ref(x, dt, A, Bm, Cm, D, chunk=4,
                                return_state=True)
    state = jnp.zeros((b, h, n, p))
    for t in range(l):
        state, _ = ssdref.ssd_step_ref(state, x[:, t], dt[:, t], A,
                                       Bm[:, t], Cm[:, t], D)
    np.testing.assert_allclose(s_final, state, atol=1e-4, rtol=1e-3)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 64), (2, 5, 7, 32)])
@pytest.mark.parametrize("residual", [False, True])
def test_rmsnorm_matches_oracle(shape, residual):
    x = rand(*shape)
    w = rand(shape[-1])
    r = rand(*shape) if residual else None
    ref = rref.rmsnorm_ref(x, w, eps=1e-5, residual=r)
    out = rmsnorm_pallas(x, w, eps=1e-5, residual=r, row_block=8,
                         interpret=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)
