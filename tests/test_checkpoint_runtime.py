"""Checkpoint manager + fault-tolerant driver + elastic/straggler logic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import make_data
from repro.models.model import build_model
from repro.runtime.driver import FaultInjector, TrainDriver
from repro.runtime.elastic import adjust_run_for_devices, viable_mesh_shape
from repro.runtime.straggler import StragglerMonitor
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_train_state, make_train_step
from repro.utils.config import (MeshConfig, ParallelConfig, RunConfig,
                                ShapeConfig, TrainConfig)
from repro.utils.logging import MetricsLogger
from repro.utils.trees import tree_allclose


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jax.random.normal(k, (3,)).astype(jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(7, t, extra={"note": "hi"}, blocking=True)
    assert mgr.latest_step() == 7
    restored = mgr.restore(7, jax.eval_shape(lambda: t))
    assert tree_allclose(t, restored)
    assert mgr.restore_extra(7)["note"] == "hi"


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_ignores_partial_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(), blocking=True)
    # a crashed save leaves a tmp dir: must not be listed as a step
    os.makedirs(tmp_path / "step_9.tmp.1234")
    assert mgr.all_steps() == [1]
    # a committed dir without manifest is also ignored
    os.makedirs(tmp_path / "step_8")
    assert mgr.all_steps() == [1]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"a": jnp.zeros((2, 2))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(1, {"a": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


# --------------------------------------------------------------------------
# driver fault tolerance
# --------------------------------------------------------------------------

def _make_driver(tmp_path, fault_steps=()):
    cfg = tiny_model_config()
    run = RunConfig(
        model=cfg, shape=ShapeConfig("train", 16, 4, "train"),
        mesh=MeshConfig(shape=(1,), axes=("data",)),
        parallel=ParallelConfig(),
        train=TrainConfig(lr=1e-3, warmup_steps=2, total_steps=20),
        checkpoint_dir=str(tmp_path), checkpoint_every=3, log_every=100,
    )
    model = build_model(cfg, run.parallel)
    opt = make_optimizer(run.train)
    step_fn = jax.jit(make_train_step(model, run, opt))

    def init_state():
        return init_train_state(model, run, opt, jax.random.PRNGKey(0))

    data = make_data(cfg, run.shape, seed=0)
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    return TrainDriver(run, step_fn, init_state, data, ckpt,
                       logger=MetricsLogger(name="test"),
                       fault_injector=FaultInjector(list(fault_steps)))


def test_driver_runs_to_completion(tmp_path):
    d = _make_driver(tmp_path)
    state = d.run_steps(10)
    assert int(state.step) == 10


def test_driver_restarts_after_faults_bitexact(tmp_path):
    d_fault = _make_driver(tmp_path / "a", fault_steps=[5, 8])
    s_fault = d_fault.run_steps(10)
    assert d_fault.restarts == 2

    d_clean = _make_driver(tmp_path / "b")
    s_clean = d_clean.run_steps(10)
    assert int(s_fault.step) == int(s_clean.step) == 10
    assert tree_allclose(s_fault.params, s_clean.params, rtol=1e-6, atol=1e-7)


def test_driver_gives_up_after_max_restarts(tmp_path):
    d = _make_driver(tmp_path, fault_steps=list(range(1, 50)))
    d.max_restarts = 3
    with pytest.raises(RuntimeError):
        d.run_steps(10)


# --------------------------------------------------------------------------
# straggler + elastic
# --------------------------------------------------------------------------

def test_straggler_flags_persistently_slow_host():
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, patience=3)
    for _ in range(5):
        mon.report({0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0})
    assert 3 in mon.flagged()
    assert mon.should_exclude(3)
    assert not mon.should_exclude(0)


def test_straggler_recovers():
    mon = StragglerMonitor(num_hosts=2, threshold=1.5, patience=2)
    mon.report({0: 1.0, 1: 5.0})
    for _ in range(20):
        mon.report({0: 1.0, 1: 1.0})
    assert mon.flagged() == []


def test_viable_mesh_shape():
    assert viable_mesh_shape(256, 16) == (16, 16)
    assert viable_mesh_shape(192, 16) == (12, 16)
    # degradation lands on the largest divisor <= the request, not the
    # nearest halving: 100 devices at TP 16 keep TP 10 (halving gave TP 4)
    assert viable_mesh_shape(100, 16) == (10, 10)


def test_adjust_run_for_devices_preserves_global_batch():
    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 48, "train"),
                    mesh=MeshConfig((16, 16), ("data", "model")),
                    parallel=ParallelConfig(tp=16, microbatch=1))
    new = adjust_run_for_devices(run, 128)
    assert new.mesh.num_devices == 128
    data_size = dict(zip(new.mesh.axes, new.mesh.shape)).get("data")
    assert new.shape.global_batch % (data_size * new.parallel.microbatch) == 0


def test_elastic_restore_roundtrip(tmp_path):
    """Checkpoint written under one config restores under another mesh
    (single-device CPU: exercises the template/sharding plumbing)."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    t = _tree()
    mgr.save(3, t, blocking=True)
    restored = mgr.restore(3, jax.eval_shape(lambda: t), shardings=None)
    assert tree_allclose(t, restored)
