"""Continuous-batching scheduler: correctness vs the single-request
generate() path, slot reuse, EOS/max-token stopping, occupancy, admission
edge cases, and drain-stall detection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.models.model import build_model
from repro.serving.scheduler import ContinuousBatcher, DrainStall, Request
from repro.train.serve_step import generate
from repro.utils.config import RunConfig, ShapeConfig


@pytest.fixture(scope="module")
def served():
    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 64, 4, "decode"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, run, model, params


def test_matches_single_request_greedy(served):
    cfg, run, model, params = served
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (6,), 0, cfg.vocab_size))
    ref = np.asarray(generate(model, run, params,
                              {"tokens": jnp.asarray(prompt)[None]},
                              num_steps=5))[0]
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    done = b.run_until_drained()
    assert len(done) == 1
    np.testing.assert_array_equal(np.asarray(done[0].generated), ref)


def test_concurrent_requests_match_sequential(served):
    cfg, run, model, params = served
    rng = jax.random.PRNGKey(2)
    prompts = [np.asarray(jax.random.randint(k, (5,), 0, cfg.vocab_size))
               for k in jax.random.split(rng, 3)]
    refs = [np.asarray(generate(model, run, params,
                                {"tokens": jnp.asarray(p)[None]},
                                num_steps=4))[0] for p in prompts]
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    for i, p in enumerate(prompts):  # 3 requests > 2 slots: forces reuse
        b.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = b.run_until_drained()
    assert len(done) == 3
    by_uid = {d.request.uid: d.generated for d in done}
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(np.asarray(by_uid[i]), ref)


def test_eos_stops_early(served):
    cfg, run, model, params = served
    prompt = np.asarray([1, 2, 3])
    b = ContinuousBatcher(model, run, params, num_slots=1, cache_len=32)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=30))
    # pick the greedy first token as "EOS" so it stops immediately
    ref = np.asarray(generate(model, run, params,
                              {"tokens": jnp.asarray(prompt)[None]},
                              num_steps=1))[0]
    b.eos_token = int(ref[0])
    done = b.run_until_drained()
    assert len(done) == 1
    assert len(done[0].generated) == 1


def test_occupancy_tracked(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    for i in range(4):
        b.submit(Request(uid=i, prompt=np.asarray([1, 2]), max_new_tokens=3))
    b.run_until_drained()
    assert 1.0 <= b.mean_occupancy <= 2.0
    assert len(b.completed) == 4


# --------------------------------------------------------------------------
# admission edge cases
# --------------------------------------------------------------------------

def test_submit_while_full_queues_until_slot_frees(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=1, cache_len=32)
    b.submit(Request(uid=0, prompt=np.asarray([1, 2]), max_new_tokens=4))
    b.tick()  # admits uid 0; the only slot is now busy
    b.submit(Request(uid=1, prompt=np.asarray([3, 4]), max_new_tokens=2))
    b.tick()
    # uid 1 stays queued while uid 0 holds the slot
    assert [r.uid for r in b.queue] == [1]
    assert b._slots[0] is not None and b._slots[0].request.uid == 0
    done = b.run_until_drained()
    assert {d.request.uid for d in done} == {0, 1}
    assert not b.queue


def test_zero_free_slots_after_maybe_finish(served):
    # both requests finish on the same tick: _maybe_finish frees both slots
    # and the next tick admits from the queue into the freed slots
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    for i in range(2):
        b.submit(Request(uid=i, prompt=np.asarray([1, 2]),
                         max_new_tokens=3))
    b.submit(Request(uid=2, prompt=np.asarray([5, 6]), max_new_tokens=3))
    b.tick()   # admit 0, 1 (token 1 from prefill, token 2 decoded)
    assert b._free_slots() == [] and [r.uid for r in b.queue] == [2]
    b.tick()   # token 3 for both -> both finish, both slots free
    assert len(b._free_slots()) == 2
    assert len(b.completed) == 2
    done = b.run_until_drained()
    assert {d.request.uid for d in done} == {0, 1, 2}


def test_mean_occupancy_of_empty_run(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    assert b.run_until_drained() == []
    assert b.mean_occupancy == 0.0   # no div-by-zero on zero ticks
    assert b.ticks == 0 and not b.stalled


# --------------------------------------------------------------------------
# interleave policy
# --------------------------------------------------------------------------

def test_drain_policy_refills_only_when_batch_empties(served):
    # mirror of the simulator's 'drain' admission gate: with a resident
    # request, queued work must wait until every slot frees
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32,
                          interleave="drain")
    b.submit(Request(uid=0, prompt=np.asarray([1, 2]), max_new_tokens=4))
    b.tick()   # admits uid 0 (empty batch)
    b.submit(Request(uid=1, prompt=np.asarray([3, 4]), max_new_tokens=2))
    b.tick()
    # a free slot exists, but drain holds uid 1 back while uid 0 runs
    assert [r.uid for r in b.queue] == [1]
    done = b.run_until_drained()
    assert {d.request.uid for d in done} == {0, 1}
    with pytest.raises(ValueError, match="interleave"):
        ContinuousBatcher(model, run, params, interleave="bogus")


# --------------------------------------------------------------------------
# mixed-temperature batches
# --------------------------------------------------------------------------

def test_mixed_temperature_batch_samples_per_request(served):
    # a hot request in slot 0 must not drag a greedy request resident in
    # slot 1 onto its temperature (the live[0] sampling bug): the greedy
    # request still reproduces the single-request greedy reference exactly
    cfg, run, model, params = served
    greedy_prompt = np.asarray([1, 2, 3])
    ref = np.asarray(generate(model, run, params,
                              {"tokens": jnp.asarray(greedy_prompt)[None]},
                              num_steps=5))[0]
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    b.submit(Request(uid=0, prompt=np.asarray([4, 5]), max_new_tokens=5,
                     temperature=8.0))      # occupies slot 0
    b.submit(Request(uid=1, prompt=greedy_prompt, max_new_tokens=5,
                     temperature=0.0))      # slot 1, decodes greedily
    done = b.run_until_drained()
    by_uid = {d.request.uid: d.generated for d in done}
    np.testing.assert_array_equal(np.asarray(by_uid[1]), ref)
    assert all(0 <= t < cfg.vocab_size for t in by_uid[0])


def test_mixed_temperature_batch_deterministic_per_seed(served):
    cfg, run, model, params = served

    def tokens(seed):
        b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32,
                              seed=seed)
        b.submit(Request(uid=0, prompt=np.asarray([4, 5]), max_new_tokens=6,
                         temperature=5.0))
        b.submit(Request(uid=1, prompt=np.asarray([1, 2]), max_new_tokens=6))
        done = b.run_until_drained()
        return {d.request.uid: list(d.generated) for d in done}

    assert tokens(7) == tokens(7)
    # the hot stream actually samples: across seeds it almost surely moves
    assert tokens(7)[0] != tokens(8)[0] or tokens(7)[0] != tokens(9)[0]


# --------------------------------------------------------------------------
# drain-stall detection
# --------------------------------------------------------------------------

def test_run_until_drained_raises_on_tick_budget(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=1, cache_len=32)
    b.submit(Request(uid=0, prompt=np.asarray([1, 2]), max_new_tokens=8))
    b.submit(Request(uid=1, prompt=np.asarray([3, 4]), max_new_tokens=8))
    with pytest.raises(DrainStall, match="not drained after 2 ticks") as e:
        b.run_until_drained(max_ticks=2)
    assert e.value.pending > 0
    # the budget is per call, not cumulative: a fresh call finishes the work
    done = b.run_until_drained(max_ticks=100)
    assert {d.request.uid for d in done} == {0, 1}
    assert not b.stalled


def test_run_until_drained_warn_flags_partial(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=1, cache_len=32)
    b.submit(Request(uid=0, prompt=np.asarray([1, 2]), max_new_tokens=8))
    with pytest.warns(RuntimeWarning, match="not drained"):
        done = b.run_until_drained(max_ticks=1, on_limit="warn")
    assert b.stalled and done == []
    with pytest.raises(ValueError, match="on_limit"):
        b.run_until_drained(on_limit="bogus")
