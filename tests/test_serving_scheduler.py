"""Continuous-batching scheduler: correctness vs the single-request
generate() path, slot reuse, EOS/max-token stopping, occupancy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.models.model import build_model
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.train.serve_step import generate
from repro.utils.config import RunConfig, ShapeConfig


@pytest.fixture(scope="module")
def served():
    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 64, 4, "decode"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, run, model, params


def test_matches_single_request_greedy(served):
    cfg, run, model, params = served
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (6,), 0, cfg.vocab_size))
    ref = np.asarray(generate(model, run, params,
                              {"tokens": jnp.asarray(prompt)[None]},
                              num_steps=5))[0]
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    done = b.run_until_drained()
    assert len(done) == 1
    np.testing.assert_array_equal(np.asarray(done[0].generated), ref)


def test_concurrent_requests_match_sequential(served):
    cfg, run, model, params = served
    rng = jax.random.PRNGKey(2)
    prompts = [np.asarray(jax.random.randint(k, (5,), 0, cfg.vocab_size))
               for k in jax.random.split(rng, 3)]
    refs = [np.asarray(generate(model, run, params,
                                {"tokens": jnp.asarray(p)[None]},
                                num_steps=4))[0] for p in prompts]
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    for i, p in enumerate(prompts):  # 3 requests > 2 slots: forces reuse
        b.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = b.run_until_drained()
    assert len(done) == 3
    by_uid = {d.request.uid: d.generated for d in done}
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(np.asarray(by_uid[i]), ref)


def test_eos_stops_early(served):
    cfg, run, model, params = served
    prompt = np.asarray([1, 2, 3])
    b = ContinuousBatcher(model, run, params, num_slots=1, cache_len=32)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=30))
    # pick the greedy first token as "EOS" so it stops immediately
    ref = np.asarray(generate(model, run, params,
                              {"tokens": jnp.asarray(prompt)[None]},
                              num_steps=1))[0]
    b.eos_token = int(ref[0])
    done = b.run_until_drained()
    assert len(done) == 1
    assert len(done[0].generated) == 1


def test_occupancy_tracked(served):
    cfg, run, model, params = served
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    for i in range(4):
        b.submit(Request(uid=i, prompt=np.asarray([1, 2]), max_new_tokens=3))
    b.run_until_drained()
    assert 1.0 <= b.mean_occupancy <= 2.0
    assert len(b.completed) == 4
