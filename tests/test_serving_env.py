"""ServingEnv: the serving stack as a CAMEO environment under workload
swaps — space composition, objective directions, transfer end-to-end
(tuned beats default on the target trace), the serving benchmark document +
gate, and the deployment path: a spy-verified tuned serving config reaching
both the simulator and the real ContinuousBatcher via the serve launcher."""

import numpy as np
import pytest

from repro.envs.measure import KernelWorkload, backend_names, make_backend
from repro.envs.serving_env import ServingEnv, make_serving_pair
from repro.tuner.bench import (
    DEFAULT_TARGET_TRACES, ServingCell, make_serving_bench_pair,
    run_serving_bench, serving_cell_by_name, serving_target_optimum)
from repro.tuner.runner import transfer_tune
from repro.workloads import ServingPlan, make_workload

TINY_CELL = KernelWorkload(name="tiny", batch=1, seq_len=128, heads=2,
                           kv_heads=1, head_dim=16, d_model=64, channels=64,
                           scan_state=4, ssm_heads=2, ssm_head_dim=16,
                           ssm_state=8)
FAMS = ("flash_attention", "rmsnorm")
SRC = "poisson:rate=2500,horizon=0.02,mean_prompt=32,mean_output=16,max_len=96"
TGT = ("bursty:rate=2500,burst=6,horizon=0.02,mean_prompt=32,"
       "mean_output=16,max_len=96")


def _env(workload=SRC, **kw):
    kw.setdefault("cell", TINY_CELL)
    kw.setdefault("families", FAMS)
    return ServingEnv(workload, **kw)


# --------------------------------------------------------------------------
# environment basics
# --------------------------------------------------------------------------

def test_space_and_counters():
    env = _env()
    assert {"serving.num_slots", "serving.cache_len",
            "flash_attention.q_block"} <= set(env.space.names)
    counters, y = env.intervene(env.space.default_config())
    assert np.isfinite(y) and y > 0
    assert set(env.counter_names) <= set(counters)
    # objective-metric copies stay OUT of the causal-discovery counters
    # (an objective clone in the graph collapses the ACE ranking) but IN
    # the metrics dict, where query constraints bind on them
    assert {"latency", "throughput"} <= set(counters)
    assert not {"latency", "throughput"} & set(env.counter_names)
    assert env.query_text == "minimize latency within {budget} samples"


def test_env_accepts_spec_workload_or_trace():
    w = make_workload(SRC)
    tr = w.generate(0)
    assert _env(SRC, seed=0).trace == _env(w, seed=0).trace == \
        _env(tr, seed=0).trace
    # trace_seed pins the realization independently of the noise seed
    assert _env(SRC, seed=1, trace_seed=0).trace == _env(SRC, seed=0).trace


def test_env_deterministic_per_seed():
    cfgs = _env().space.sample(np.random.default_rng(0), 6)
    ys1 = [_env(seed=3).intervene(c)[1] for c in cfgs]
    ys2 = [_env(seed=3).intervene(c)[1] for c in cfgs]
    assert ys1 == ys2


LONG = ("poisson:rate=2000,horizon=0.02,mean_prompt=180,mean_output=40,"
        "max_len=384")


def test_infeasible_direction_aware():
    bad = {"serving.cache_len": 128}   # trace max_context exceeds it
    env = _env(LONG)
    big = dict(env.space.default_config(), **bad)
    assert env.trace.max_context > 128
    _, y = env.intervene(big)
    assert y == float("inf")
    envT = _env(LONG, objective="throughput")
    _, yT = envT.intervene(big)
    assert yT == float("-inf")
    assert "maximize throughput" in envT.query_text
    with pytest.raises(ValueError, match="unknown serving objective"):
        _env(objective="energy")


def test_workload_swap_changes_measurement_not_space():
    src, tgt = make_serving_pair(SRC, TGT, TINY_CELL, families=FAMS, seed=0)
    assert src.space.names == tgt.space.names
    assert src.workload_spec != tgt.workload_spec
    cfg = src.space.default_config()
    assert src.simulate(cfg) != tgt.simulate(cfg)


def test_plan_of_and_apply_split_the_config():
    from repro.kernels import dispatch
    from repro.tuner.space import launch_config_of

    env = _env()
    cfg = dict(env.space.default_config())
    cfg.update({"serving.num_slots": 16, "flash_attention.q_block": 128})
    assert ServingEnv.plan_of(cfg).num_slots == 16
    launch = launch_config_of(cfg)
    assert "serving.num_slots" not in launch
    assert launch["flash_attention.q_block"] == 128
    with env.apply(cfg):
        assert dispatch.launch_params("flash_attention")["q_block"] == 128


# --------------------------------------------------------------------------
# transfer end-to-end: poisson source -> bursty target
# --------------------------------------------------------------------------

def test_transfer_tune_beats_default_on_target():
    src, tgt = make_serving_pair(SRC, TGT, TINY_CELL, families=FAMS, seed=0)
    default = tgt.space.default_config()
    y_default = tgt.simulate(default).p99_latency_us
    res = transfer_tune("cameo", src, tgt, budget=10, n_source=48,
                        n_target_init=3, query_text=tgt.query_text, seed=0)
    assert res.best_config is not None and np.isfinite(res.best_y)
    tuned = tgt.simulate(res.best_config)
    assert tuned.feasible
    assert tuned.p99_latency_us < y_default
    # the launch half of the winner is deployable as-is
    assert all(not k.startswith("serving.") for k in res.launch_config)


def test_throughput_objective_under_slo_constraint():
    src, tgt = make_serving_pair(SRC, TGT, TINY_CELL, families=FAMS,
                                 objective="throughput", slo_us=5e4, seed=0)
    res = transfer_tune("cameo", src, tgt, budget=6, n_source=32,
                        n_target_init=3, query_text=tgt.query_text, seed=0)
    assert np.isfinite(res.best_y) and res.best_y > 0
    rep = tgt.simulate(res.best_config)
    assert rep.p99_latency_us < 5e4  # the winner satisfies the SLO


# --------------------------------------------------------------------------
# serving benchmark sweep
# --------------------------------------------------------------------------

TINY_SERVING_CELL = ServingCell("tiny", TINY_CELL, families=FAMS, source=SRC)


def test_serving_bench_document_shape_and_gate():
    import json

    doc = run_serving_bench(cells=(TINY_SERVING_CELL,), targets=(TGT,),
                            methods=("cameo", "random"), budget=4,
                            n_source=24, n_target_init=2, seeds=(0,),
                            pool=32)
    json.dumps(doc)  # JSON-clean
    assert doc["meta"]["targets"] == [TGT]
    (cell,) = doc["cells"]
    assert cell["source"] == SRC and cell["target"] == TGT
    assert cell["y_opt"] > 0
    assert cell["y_default"] is None or cell["y_default"] >= cell["y_opt"]
    for stats in cell["methods"].values():
        (run,) = stats["runs"]
        assert len(run["regret"]) == len(run["best_y_trace"]) == 4
        tail = [r for r in run["regret"] if r is not None]
        assert all(r >= 0 for r in tail)
        assert all(a >= b - 1e-12 for a, b in zip(tail, tail[1:]))
    assert doc["gate"]["checked"]


def test_serving_target_optimum_finite_and_below_default():
    y_opt, y_default = serving_target_optimum(TINY_SERVING_CELL, TGT,
                                              pool=32)
    assert np.isfinite(y_opt) and y_opt > 0
    assert y_default is None or y_opt <= y_default


def test_serving_cell_lookup():
    assert serving_cell_by_name("serve-8b").cell == KernelWorkload()
    with pytest.raises(ValueError, match="unknown serving cell"):
        serving_cell_by_name("nope")
    assert len(DEFAULT_TARGET_TRACES) >= 3
    src, tgt = make_serving_bench_pair(TINY_SERVING_CELL, TGT, seed=0)
    assert src.space.names == tgt.space.names


# --------------------------------------------------------------------------
# make_backend registry errors (and the workload registry mirror)
# --------------------------------------------------------------------------

def test_make_backend_unknown_names_list_registry_keys():
    with pytest.raises(ValueError) as e:
        make_backend("bogus", TINY_CELL, FAMS)
    msg = str(e.value)
    for name in ("analytic", "wallclock", "shifted:hardware",
                 "shifted:severe"):
        assert name in msg
    with pytest.raises(ValueError) as e2:
        make_backend("shifted:bogus", TINY_CELL, FAMS)
    assert "shifted:noise" in str(e2.value)
    assert set(backend_names()) >= {"analytic", "wallclock",
                                    "shifted:hardware"}


def test_register_backend_extends_selection():
    from repro.envs import measure

    class NullBackend(measure.AnalyticBackend):
        pass

    measure.register_backend("null-test", NullBackend)
    try:
        assert isinstance(make_backend("null-test", TINY_CELL, FAMS),
                          NullBackend)
        assert "null-test" in backend_names()
        with pytest.raises(ValueError, match="already registered"):
            measure.register_backend("null-test", NullBackend)
        with pytest.raises(ValueError, match="already registered"):
            measure.register_backend("shifted:custom", NullBackend)
    finally:
        del measure.BACKEND_FACTORIES["null-test"]


# --------------------------------------------------------------------------
# deployment: tuned serving config reaches simulator AND real batcher
# --------------------------------------------------------------------------

def test_tuned_config_reaches_sim_and_real_batcher():
    import jax
    from conftest import tiny_model_config
    from repro.kernels import dispatch
    from repro.launch.serve import serve_workload
    from repro.launch import tune as tune_mod
    from repro.models.model import build_model
    from repro.utils.config import RunConfig, ShapeConfig

    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 64, 4, "decode"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    spec = ("poisson:rate=2000,horizon=0.005,mean_prompt=5,"
            "mean_output=3,max_len=12")
    captured = {}
    real_tune = tune_mod.tune_serving_config

    def spy_tune(*a, **kw):
        res = real_tune(*a, **kw)
        captured["result"] = res
        return res

    tune_mod.tune_serving_config = spy_tune
    # serve_workload resolved tune_serving_config at import time
    import repro.launch.serve as serve_mod
    serve_mod.tune_serving_config = spy_tune
    try:
        with dispatch.record_resolutions() as rec:
            plan, launch_config, report = serve_workload(
                model, run, params, spec, tune_budget=2, seed=0)
    finally:
        tune_mod.tune_serving_config = real_tune
        serve_mod.tune_serving_config = real_tune

    res = captured["result"]
    # 1) the tuned plan is the one the batcher ran under
    assert plan == ServingPlan.from_config(res.best_config)
    assert launch_config == res.launch_config and launch_config
    # 2) the simulator side priced exactly these launch params
    src, tgt = make_serving_pair("poisson", spec, cell=TINY_CELL,
                                 families=FAMS, seed=0)
    resolved = tgt.sim.resolved_launch(res.best_config)
    for key, val in launch_config.items():
        fam, pname = key.split(".")
        if fam in resolved:
            assert resolved[fam][pname] == val
    # 3) the real batcher's traced kernels saw the tuned launch params
    attn = [r.launch for r in rec if r.family == "flash_attention"]
    assert attn, "no flash_attention dispatch recorded in the replay"
    for launch in attn:
        assert launch["q_block"] == launch_config["flash_attention.q_block"]
        assert launch["kv_block"] == \
            launch_config["flash_attention.kv_block"]
    assert report.completed > 0 and report.rejected == 0
