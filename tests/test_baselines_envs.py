"""Baseline tuners + environments: interfaces, improvement, env properties."""

import numpy as np
import pytest

from repro.core.baselines import make_baseline
from repro.core.cameo import Dataset
from repro.envs.analytic import (AnalyticTPUEnv, TPUEnvSpec, environment_pair,
                                 tpu_config_space)
from repro.envs.sandbox import SandboxSCMEnv, make_sandbox_pair

BASELINES = ["smac", "cello", "restune-w/o-ml", "restune", "unicorn", "random"]


@pytest.fixture(scope="module")
def sandbox_pair():
    src, tgt = make_sandbox_pair(0)
    return src, tgt, src.dataset(150, seed=1)


@pytest.mark.parametrize("name", BASELINES)
def test_baseline_improves_over_init(name, sandbox_pair):
    src, tgt, d_s = sandbox_pair
    t = make_baseline(name, tgt.space, d_s, counter_names=src.counter_names,
                      seed=0)
    cfg, y = t.run(tgt, budget=20)
    assert np.isfinite(y)
    assert cfg is not None
    trace = t.trace.best_y
    assert trace[-1] <= trace[0]
    assert all(b <= a + 1e-12 for a, b in zip(trace, trace[1:]))


def test_cello_spends_less_budget_per_bad_config(sandbox_pair):
    src, tgt, d_s = sandbox_pair
    t = make_baseline("cello", tgt.space, d_s, seed=0)
    t.run(tgt, budget=15)
    # early-terminated (0.5-cost) evaluations appear in the spend trace
    assert t.trace.spent[-1] >= 15


def test_analytic_env_correlation_flip():
    """The paper's Fig. 2 mechanism: collective_bytes vs step-time
    correlation flips between compute-bound and bandwidth-degraded envs."""
    base = TPUEnvSpec()
    fast = AnalyticTPUEnv(base, seed=0)
    from dataclasses import replace
    slow = AnalyticTPUEnv(replace(base, cross_pod=True, chips=512), seed=1)

    def corr(env, n=200):
        rng = np.random.default_rng(3)
        xs, ys = [], []
        for cfg in env.space.sample(rng, n):
            counters, y = env.intervene(cfg)
            if np.isfinite(y):
                xs.append(counters["collective_bytes"])
                ys.append(y)
        return np.corrcoef(xs, ys)[0, 1]

    c_fast, c_slow = corr(fast), corr(slow)
    assert c_slow > c_fast  # degraded links push the correlation up
    assert c_slow > 0.1


def test_analytic_env_invalid_configs_are_inf():
    env = AnalyticTPUEnv(TPUEnvSpec(global_batch=6), seed=0)
    # tp=16 -> dp=16, 6 % 16 != 0 -> invalid
    _, y = env.intervene({"tp": 16, "microbatch": 4, "remat": "none",
                          "seq_parallel": 0, "grad_compression": "none",
                          "attn_kv_block": 1024, "collective_overlap": 0,
                          "compute_dtype": "bf16"})
    assert not np.isfinite(y)


@pytest.mark.parametrize("change", ["hardware", "workload", "software",
                                    "topology", "severe"])
def test_environment_pairs_constructible(change):
    src, tgt = environment_pair(change, seed=0)
    _, y_s = src.intervene(src.space.default_config())
    assert np.isfinite(y_s)
    # the target may make the default infeasible (e.g. severe: batch 32 on
    # 512 chips) — but some configuration must be feasible
    rng = np.random.default_rng(0)
    ys = [tgt.intervene(c)[1] for c in tgt.space.sample(rng, 32)]
    assert np.isfinite(ys).any()


def test_environment_optimum_differs_across_envs():
    """Fig. 1 of the paper: the optimal configuration in the source is not
    optimal in the target. (Unpadded space + noise-free model so the
    comparison is exact.)"""
    src, tgt = environment_pair("workload", seed=0, padded=0)
    src_best_cfg, _ = src.optimum(4096)
    _, y_src_best_in_tgt, valid = tgt._step_model(src_best_cfg)  # noise-free
    _, y_tgt_best = tgt.optimum(4096)
    assert (not valid) or y_src_best_in_tgt > y_tgt_best * 1.02


def test_sandbox_correlation_flip():
    src, tgt = make_sandbox_pair(0)

    def corr(env):
        d = env.dataset(300, seed=9)
        ipc = np.array([c["ipc"] for c in d.counters])
        y = np.array(d.ys)
        return np.corrcoef(ipc, y)[0, 1]

    assert corr(src) > 0.2    # small memory: IPC rises with latency
    assert corr(tgt) < -0.2   # large memory: reversed


def test_pooled_env_observe_is_cached():
    env = SandboxSCMEnv("small", seed=0)
    rng = np.random.default_rng(0)
    for _ in range(5):
        cfg, counters, y = env.observe(rng)
        assert np.isfinite(y)


def test_dataset_matrix_sanitizes_inf():
    env = AnalyticTPUEnv(TPUEnvSpec(), seed=0)
    d = Dataset()
    d.add(env.space.default_config(), {}, float("inf"))
    d.add(env.space.default_config(), {}, 1.0)
    m, names = d.matrix(env.space, [])
    assert np.isfinite(m).all()
