"""Trace replay through the real batcher: per-replay delta accounting on a
reused batcher (the stale-state regression), single-request / all-rejected
edge cases, and DrainStall progress reporting."""

import dataclasses

import jax
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.models.model import build_model
from repro.serving.replay import (ReplayReport, default_ticks_per_s,
                                  replay_trace, trace_requests)
from repro.serving.scheduler import ContinuousBatcher, DrainStall
from repro.utils.config import RunConfig, ShapeConfig
from repro.workloads import Trace, RequestSpec, make_workload

SPEC = ("poisson:rate=1500,horizon=0.004,mean_prompt=5,mean_output=3,"
        "max_len=12")


@pytest.fixture(scope="module")
def served():
    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 64, 4, "decode"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, run, model, params


def _batcher(served, **kw):
    cfg, run, model, params = served
    kw.setdefault("num_slots", 2)
    kw.setdefault("cache_len", 32)
    return ContinuousBatcher(model, run, params, **kw)


def _trace(spec=SPEC, seed=0):
    return make_workload(spec).generate(seed)


# --------------------------------------------------------------------------
# the stale-state regression: a reused batcher must report per-replay deltas
# --------------------------------------------------------------------------

def test_replay_twice_on_one_batcher_reports_identical_deltas(served):
    tr = _trace()
    b = _batcher(served)
    r1 = replay_trace(b, tr, seed=0)
    r2 = replay_trace(b, tr, seed=0)
    # lifetime state kept accumulating...
    assert len(b.completed) == 2 * r1.completed
    # ...but each report covers only its own replay: every deterministic
    # field is identical (wall-clock fields naturally vary)
    for f in ("completed", "rejected", "ticks", "tokens", "mean_occupancy",
              "queue_depth_mean", "queue_depth_max"):
        assert getattr(r1, f) == getattr(r2, f), f
    assert r1.completed == len(tr) and r1.completed > 0
    assert r1.p99_latency_ms > 0 and r2.p99_latency_ms > 0
    assert len(r2.latencies_ms) == r2.completed


def test_replay_wall_counters_are_per_replay(served):
    tr = _trace()
    b = _batcher(served)
    r1 = replay_trace(b, tr, seed=0)
    r2 = replay_trace(b, tr, seed=0)
    # prefill/decode wall-time split diffs the batcher's lifetime counters;
    # the second replay must not include the first's compile-heavy prefills
    assert 0 < r2.prefill_s <= b.prefill_s - r1.prefill_s + 1e-9
    assert 0 < r2.decode_s <= b.decode_s - r1.decode_s + 1e-9
    assert r2.prefill_decode_ratio > 0
    assert r2.throughput_rps == pytest.approx(
        r2.completed / r2.wall_s, rel=1e-6)


# --------------------------------------------------------------------------
# edge cases
# --------------------------------------------------------------------------

def test_single_request_trace_zero_span(served):
    tr = Trace("k", "k", 0, (RequestSpec(0, 0.0, 4, 3),))
    assert tr.span_s == 0.0
    # span 0 drives default_ticks_per_s through the 1e-9 clamp: a huge but
    # finite rate that still maps the single arrival to tick 0
    assert np.isfinite(default_ticks_per_s(tr, 2))
    rep = replay_trace(_batcher(served), tr, seed=0)
    assert rep.completed == 1 and rep.rejected == 0
    assert rep.tokens == 3
    assert rep.p99_latency_ms > 0


def test_all_requests_rejected_empty_latencies(served):
    # every request overflows prompt+output > cache_len -> nothing replays,
    # and the empty latency vector must not NaN the percentiles
    tr = Trace("k", "k", 0, (RequestSpec(0, 0.0, 40, 3),
                             RequestSpec(1, 0.001, 41, 2)))
    b = _batcher(served, cache_len=32)
    rep = replay_trace(b, tr, seed=0)
    assert rep.completed == 0 and rep.rejected == 2
    assert rep.ticks == 0 and rep.tokens == 0
    assert rep.p50_latency_ms == rep.p99_latency_ms == 0.0
    assert rep.rejected_rate == 1.0
    assert rep.latencies_ms == ()
    assert not any(np.isnan(v) for v in rep.counters().values())


def test_trace_requests_drops_only_oversized(served):
    tr = Trace("k", "k", 0, (RequestSpec(0, 0.0, 4, 3),
                             RequestSpec(1, 0.001, 40, 3)))
    reqs = trace_requests(tr, vocab_size=64, cache_len=32)
    assert [r.uid for r in reqs] == [0]
    assert reqs[0].max_new_tokens == 3


def test_drain_stall_counts_only_this_replay(served):
    tr = _trace()
    b = _batcher(served)
    first = replay_trace(b, tr, seed=0)     # leaves completed history
    assert first.completed == len(tr)
    with pytest.raises(DrainStall) as e:
        replay_trace(b, tr, seed=0, max_ticks=1)
    # progress counters cover the stalled replay, not the batcher lifetime
    assert e.value.completed < len(tr)
    assert e.value.completed + e.value.pending >= len(tr)
    assert e.value.pending > 0


def test_replay_report_slo_violation_rate():
    rep = ReplayReport(completed=3, rejected=0, ticks=3, wall_s=1.0,
                       tokens=9, mean_occupancy=1.0, p50_latency_ms=20.0,
                       p99_latency_ms=30.0, latencies_ms=(10.0, 20.0, 30.0))
    assert rep.slo_violation_rate(15.0) == pytest.approx(2 / 3)
    assert rep.slo_violation_rate(100.0) == 0.0
    assert rep.counters(15.0)["slo_violation_rate"] == pytest.approx(2 / 3)
    assert {"latency", "throughput", "rejected_rate"} <= set(rep.counters())
