"""CAMEO core: CI tests, discovery, ACE, Markov blankets, GP/CGP,
acquisition, epsilon, query parsing, and the full Algorithm 1 loop."""

import numpy as np
import pytest

from repro.core.ace import adjusted_effect, choose_k, rank_by_ace
from repro.core.acquisition import combined_acquisition, expected_improvement
from repro.core.cameo import Cameo, Dataset
from repro.core.ci_tests import fisher_z, mutual_info, partial_correlation
from repro.core.discovery import (BIDIRECTED, DIRECTED, UNDIRECTED,
                                  CausalGraph, fci_lite)
from repro.core.epsilon import hull_volume_fraction, observation_epsilon
from repro.core.gp import fit_gp, gp_predict
from repro.core.markov_blanket import top_k_blanket
from repro.core.query import parse_query
from repro.core.spaces import ConfigSpace, Option
from repro.envs.sandbox import SandboxSCMEnv, make_sandbox_pair


# -- CI tests ---------------------------------------------------------------

def test_fisher_z_detects_dependence_and_independence(rng):
    n = 400
    x = rng.standard_normal(n)
    z = rng.standard_normal(n)
    y = 2 * x + 0.1 * rng.standard_normal(n)
    w = rng.standard_normal(n)
    data = np.column_stack([x, y, z, w])
    _, ind_xy = fisher_z(data, 0, 1, [])
    _, ind_xw = fisher_z(data, 0, 3, [])
    assert not ind_xy
    assert ind_xw


def test_fisher_z_conditional_independence(rng):
    n = 600
    z = rng.standard_normal(n)
    x = z + 0.3 * rng.standard_normal(n)
    y = z + 0.3 * rng.standard_normal(n)
    data = np.column_stack([x, y, z])
    _, ind_marginal = fisher_z(data, 0, 1, [])
    _, ind_given_z = fisher_z(data, 0, 1, [2])
    assert not ind_marginal
    assert ind_given_z


def test_partial_correlation_range(rng):
    data = rng.standard_normal((100, 3))
    r = partial_correlation(data, 0, 1, [2])
    assert -1.0 <= r <= 1.0


def test_mutual_info_discrete(rng):
    n = 500
    x = rng.integers(0, 3, n)
    y = (x + rng.integers(0, 2, n)) % 3   # dependent
    w = rng.integers(0, 3, n)             # independent
    data = np.column_stack([x, y, w]).astype(float)
    _, ind_xy = mutual_info(data, 0, 1, [], rng=rng)
    _, ind_xw = mutual_info(data, 0, 2, [], rng=rng)
    assert not ind_xy
    assert ind_xw


# -- graph + discovery -------------------------------------------------------

def test_graph_markov_blanket():
    g = CausalGraph(["a", "b", "c", "d", "e"])
    g.add_edge("a", "c", DIRECTED)    # parent
    g.add_edge("c", "d", DIRECTED)    # child
    g.add_edge("e", "d", DIRECTED)    # spouse
    assert g.markov_blanket("c") == {"a", "d", "e"}


def test_graph_shd():
    g1 = CausalGraph(["a", "b", "c"])
    g1.add_edge("a", "b", DIRECTED)
    g2 = CausalGraph(["a", "b", "c"])
    g2.add_edge("b", "a", DIRECTED)
    g2.add_edge("b", "c", DIRECTED)
    assert g1.shd(g1.copy()) == 0
    assert g1.shd(g2) == 2  # reversed + extra


def test_discovery_chain(rng):
    # x -> y -> z: skeleton must be x-y-z without x-z
    n = 800
    x = rng.standard_normal(n)
    y = 1.5 * x + 0.4 * rng.standard_normal(n)
    z = 1.5 * y + 0.4 * rng.standard_normal(n)
    g = fci_lite(np.column_stack([x, y, z]), ["x", "y", "z"])
    assert g.has_edge("x", "y")
    assert g.has_edge("y", "z")
    assert not g.has_edge("x", "z")


def test_discovery_v_structure(rng):
    # x -> z <- y (collider): discovery must orient both into z
    n = 1000
    x = rng.standard_normal(n)
    y = rng.standard_normal(n)
    z = x + y + 0.3 * rng.standard_normal(n)
    g = fci_lite(np.column_stack([x, y, z]), ["x", "y", "z"],
                 entropic_orient=False)
    assert g.edge_kind("x", "z") == DIRECTED
    assert g.edge_kind("y", "z") == DIRECTED
    assert not g.has_edge("x", "y")


def test_discovery_sandbox_recovers_invariant_cause():
    env = SandboxSCMEnv("small", seed=0)
    d = env.dataset(600, seed=1)
    data, names = d.matrix(env.space, env.counter_names)
    g = fci_lite(data, names)
    # swappiness must be connected to the objective (directly or via blanket)
    mb = g.markov_blanket("__objective__")
    assert "swappiness" in mb or g.has_edge("swappiness", "__objective__")


# -- ACE + blanket ------------------------------------------------------------

def test_ace_ranks_true_cause_above_inert():
    env = SandboxSCMEnv("small", seed=0)
    d = env.dataset(600, seed=1)
    data, names = d.matrix(env.space, env.counter_names)
    g = fci_lite(data, names)
    ranked = dict(rank_by_ace(data, names, "__objective__", g))
    assert ranked["swappiness"] > ranked["vfs_cache_pressure"]
    assert ranked["dirty_ratio"] > ranked["vfs_cache_pressure"]


def test_choose_k_elbow():
    ranked = [("a", 1.0), ("b", 0.9), ("c", 0.1), ("d", 0.05)]
    assert choose_k(ranked) == 2


def test_top_k_blanket_includes_top_nodes():
    g = CausalGraph(["a", "b", "y"])
    g.add_edge("a", "y", DIRECTED)
    mb = top_k_blanket(g, [("a", 1.0), ("b", 0.01)], 1, "y")
    assert "a" in mb


# -- GP / acquisition ---------------------------------------------------------

def test_gp_fits_smooth_function(rng):
    x = rng.uniform(0, 1, (40, 2))
    y = np.sin(3 * x[:, 0]) + x[:, 1] ** 2
    fit = fit_gp(x, y)
    xq = rng.uniform(0, 1, (20, 2))
    mu, sd = gp_predict(fit, xq)
    yq = np.sin(3 * xq[:, 0]) + xq[:, 1] ** 2
    assert np.mean(np.abs(np.asarray(mu) - yq)) < 0.25
    assert np.all(np.asarray(sd) > 0)


def test_gp_interpolates_training_points(rng):
    x = rng.uniform(0, 1, (15, 1))
    y = 2 * x[:, 0]
    fit = fit_gp(x, y, noises=(1e-4,))
    mu, sd = gp_predict(fit, x)
    np.testing.assert_allclose(np.asarray(mu), y, atol=0.1)


def test_expected_improvement_properties():
    mu = np.array([0.0, 1.0, 2.0])
    sd = np.array([0.5, 0.5, 0.5])
    ei = expected_improvement(mu, sd, best=1.0)
    assert ei[0] > ei[1] > ei[2]   # lower predicted mean -> higher EI (min)
    assert (ei >= 0).all()


def test_combined_acquisition_gating():
    ei_warm = np.array([1.0, 0.95, 0.2])
    ei_cold = np.array([0.1, 0.9, 0.9])
    alpha, lam = combined_acquisition(ei_warm, ei_cold, l_alpha=0.1)
    assert lam[0] == 1.0 and lam[1] == 1.0 and lam[2] == 0.0
    # near-warm-optimal points scored by cold, others by warm
    assert alpha[2] == pytest.approx(0.0, abs=1e-9)  # normalized warm min


# -- epsilon -------------------------------------------------------------------

def test_hull_volume_monotone(rng):
    pts = rng.uniform(0.4, 0.6, (10, 3))
    v1 = hull_volume_fraction(pts)
    pts2 = np.vstack([pts, [[0.0, 0.0, 0.0], [1.0, 1.0, 1.0]]])
    v2 = hull_volume_fraction(pts2)
    assert 0.0 <= v1 <= v2 <= 1.0


def test_observation_epsilon_bounds(rng):
    pts = rng.uniform(0, 1, (5, 2))
    assert 0.0 <= observation_epsilon(pts, 5, 50) <= 1.0
    assert observation_epsilon(pts, 0, 50) <= 0.1


# -- query engine ----------------------------------------------------------------

def test_parse_query_budget_samples():
    q = parse_query("How to improve latency within 1 hour or 50 samples")
    assert q.objective == "latency"
    assert q.budget_samples == 50
    assert q.budget_seconds == 3600.0


def test_parse_query_constraint():
    q = parse_query("I want to find the configuration with minimum energy "
                    "for which latency is less than 20 seconds within 45 minutes")
    assert q.objective == "energy"
    assert ("latency", "<", 20.0) in q.constraints
    assert q.budget_seconds == 45 * 60


def test_parse_query_throughput_maximizes():
    q = parse_query("maximize throughput within 30 samples")
    assert q.maximize


def test_query_satisfies():
    q = parse_query("minimize energy for which latency is less than 10 s")
    assert q.satisfies({"latency": 5.0, "energy": 1.0})
    assert not q.satisfies({"latency": 15.0, "energy": 1.0})


# -- full Algorithm 1 -----------------------------------------------------------

def test_cameo_end_to_end_sandbox():
    src, tgt = make_sandbox_pair(0)
    d_s = src.dataset(300, seed=1)
    q = parse_query("How to improve latency within 30 samples")
    cam = Cameo(src.space, q, d_s, counter_names=src.counter_names, seed=0)
    # knowledge extraction found the true causal options
    assert "swappiness" in cam.reduced_names
    cam.seed_target(tgt.dataset(5, seed=2))
    cfg, y = cam.run(tgt, budget=25)
    assert np.isfinite(y)
    opt = tgt.optimum()
    assert y < opt * 1.25   # within 25% of the noise-free optimum
    # budget accounting: exactly 25 rounds
    assert len(cam.trace.action) == 25


def test_cameo_constraint_handling():
    src, tgt = make_sandbox_pair(0)
    d_s = src.dataset(150, seed=1)
    # unsatisfiable: latency can never go below 0.001
    q = parse_query("minimize latency for which latency is less than 0.001 "
                    "within 10 samples")
    assert ("latency", "<", 0.001) in q.constraints
    cam = Cameo(src.space, q, d_s, counter_names=src.counter_names, seed=0)
    cam.run(tgt, budget=6)
    _, y = cam.best
    assert not np.isfinite(y)  # nothing feasible -> inf


def test_cameo_best_monotone():
    src, tgt = make_sandbox_pair(1)
    d_s = src.dataset(200, seed=3)
    q = parse_query("minimize latency within 20 samples")
    cam = Cameo(src.space, q, d_s, counter_names=src.counter_names, seed=1)
    cam.seed_target(tgt.dataset(5, seed=4))
    cam.run(tgt, budget=15)
    b = cam.trace.best_y
    assert all(b[i + 1] <= b[i] + 1e-9 for i in range(len(b) - 1))
