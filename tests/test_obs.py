"""Observability subsystem: the span tracer and its Chrome trace-event
exports, the metrics registry as single source of truth for the
discovery-variable names, bit-identity of traced vs untraced runs,
MetricsLogger lifecycle, kernel-dispatch profiling, and the report CLI."""

import json
import threading

import jax
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.core.cameo import Cameo
from repro.core.query import parse_query
from repro.envs.measure import KernelWorkload
from repro.envs.replay_env import (REPLAY_COUNTER_NAMES,
                                   REPLAY_FLEET_COUNTER_NAMES,
                                   make_sim2real_pair)
from repro.envs.sandbox import make_sandbox_pair
from repro.envs.serving_env import ServingEnv
from repro.kernels import dispatch
from repro.models.model import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry
from repro.serving.replay import replay_trace
from repro.serving.scheduler import ContinuousBatcher
from repro.train.serve_step import jitted_steps
from repro.tuner.runner import transfer_tune
from repro.utils.config import RunConfig, ShapeConfig
from repro.utils.logging import MetricsLogger
from repro.workloads import make_workload
from repro.workloads.sim import FLEET_COUNTER_NAMES, SIM_COUNTER_NAMES

TINY_CELL = KernelWorkload(name="tiny", batch=1, seq_len=128, heads=2,
                           kv_heads=1, head_dim=16, d_model=64, channels=64,
                           scan_state=4, ssm_heads=2, ssm_head_dim=16,
                           ssm_state=8)
FAMS = ("flash_attention", "rmsnorm")
SIM_SPEC = ("poisson:rate=2500,horizon=0.02,mean_prompt=32,mean_output=16,"
            "max_len=96")
REPLAY_SPEC = ("poisson:rate=1500,horizon=0.004,mean_prompt=6,"
               "mean_output=4,max_len=16")


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    yield
    obs_trace.stop()


@pytest.fixture(scope="module")
def sim2real():
    return make_sim2real_pair(REPLAY_SPEC, seed=0, repeats=1)


# --------------------------------------------------------------------------
# tracer: event vocabulary, export schema, disabled path, bounds
# --------------------------------------------------------------------------

def test_tracer_exports_valid_chrome_trace(tmp_path):
    path = str(tmp_path / "trace.json")
    with obs_trace.trace_to(path) as tr:
        with obs_trace.span("work", cat="test", n=1):
            pass
        obs_trace.instant("marker", cat="test", note="hi")
        obs_trace.counter("depth", 3.0)
        tr.async_begin("request", 7, prompt_len=4)
        tr.async_end("request", 7, generated=2)
        obs_trace.tuner_event("ask", tuner="cameo", round=1, k=2)
    with open(path) as f:
        doc = json.load(f)
    events = obs_report.validate_trace_doc(doc)
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped"] == 0
    phases = {e["ph"] for e in events}
    assert {"M", "X", "i", "C", "b", "e"} <= phases
    # track-name metadata covers every logical track
    meta = {e["pid"] for e in events if e["ph"] == "M"}
    assert set(obs_trace.TRACK_NAMES) <= meta
    # the tuner event is both an exported instant and a structured record
    assert [e for e in events if e.get("cat") == "tuner"]
    assert tr.tuner_rounds == [{"kind": "ask", "tuner": "cameo",
                                "round": 1, "k": 2}]


def test_tracing_disabled_is_noop():
    assert not obs_trace.enabled()
    assert obs_trace.active() is None
    assert obs_trace.span("x") is obs_trace.NULL_SPAN
    with obs_trace.span("x", cat="c") as s:
        s.set(a=1)
    # helpers must not raise (and must not allocate a tracer)
    obs_trace.instant("x")
    obs_trace.counter("x", 1.0)
    obs_trace.tuner_event("ask", round=1)
    assert not obs_trace.enabled()


def test_tracer_bounds_events_and_counts_drops():
    tr = obs_trace.Tracer(max_events=3)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 3
    assert tr.dropped == 7
    doc = tr.to_json()
    assert doc["otherData"]["dropped"] == 7
    assert doc["otherData"]["num_events"] == 3


def test_trace_to_exports_on_exception_and_restores(tmp_path):
    path = str(tmp_path / "partial.json")
    outer = obs_trace.start()
    with pytest.raises(RuntimeError):
        with obs_trace.trace_to(path):
            with obs_trace.span("failing", cat="test"):
                raise RuntimeError("boom")
    # the partial trace was exported, with the error recorded on the span
    events = obs_report.load_trace(path)
    fail = [e for e in events if e.get("name") == "failing"]
    assert fail and fail[0]["args"]["error"] == "RuntimeError"
    # and the previously-active tracer was restored
    assert obs_trace.active() is outer


def test_span_records_error_and_duration():
    tr = obs_trace.start()
    try:
        with pytest.raises(ValueError):
            with tr.span("s", cat="test"):
                raise ValueError("x")
        ev = tr.events()[-1]
        assert ev["ph"] == "X" and ev["dur"] >= 0
        assert ev["args"]["error"] == "ValueError"
    finally:
        obs_trace.stop()


def test_validate_trace_doc_rejects_malformed():
    with pytest.raises(ValueError):
        obs_report.validate_trace_doc({"no": "traceEvents"})
    with pytest.raises(ValueError):
        obs_report.validate_trace_doc(
            {"traceEvents": [{"name": "x", "ph": "Z", "ts": 0}]})
    with pytest.raises(ValueError):
        obs_report.validate_trace_doc(
            {"traceEvents": [{"name": "x", "ph": "i"}]})  # missing ts
    with pytest.raises(ValueError):
        obs_report.validate_trace_doc(
            {"traceEvents": [{"name": "x", "ph": "X", "ts": 0}]})  # no dur
    with pytest.raises(ValueError):
        obs_report.validate_trace_doc(
            {"traceEvents": [{"name": "x", "ph": "b", "ts": 0}]})  # no id


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_registry_declare_idempotent_and_conflicting():
    reg = MetricsRegistry()
    a = reg.declare("m", kind="gauge", group="g")
    assert reg.declare("m", kind="gauge", group="g") is a
    with pytest.raises(ValueError):
        reg.declare("m", kind="counter", group="g")
    with pytest.raises(ValueError):
        reg.declare("bad", kind="nope")


def test_registry_discovery_names_compose_by_group_order():
    reg = MetricsRegistry()
    reg.declare("a1", group="a")
    reg.declare("b1", group="b")
    reg.declare("a2", group="a")
    reg.declare("a_obj", group="a", discovery=False)
    assert reg.discovery_names("a") == ("a1", "a2")
    assert reg.discovery_names("b") == ("b1",)
    # the caller's group order defines the composite, not global
    # registration order — column order is the discovery-matrix contract
    assert reg.discovery_names("a", "b") == ("a1", "a2", "b1")
    assert reg.discovery_names("b", "a") == ("b1", "a1", "a2")
    assert "a_obj" in reg.names("a")


def test_registry_instruments_and_kind_enforcement():
    reg = MetricsRegistry()
    assert reg.inc("hits") == 1.0
    assert reg.inc("hits", 2.0) == 3.0
    reg.set("depth", 4.0, replica=1)
    reg.observe("lat_ms", 10.0)
    reg.observe("lat_ms", 30.0)
    assert reg.value("hits") == 3.0
    assert reg.value("depth", replica=1) == 4.0
    assert reg.value("depth", replica=2) is None
    with pytest.raises(ValueError):
        reg.set("hits", 1.0)       # declared (auto) as counter
    snap = reg.snapshot()
    assert snap["lat_ms"][""]["count"] == 2.0
    assert snap["lat_ms"][""]["max"] == 30.0
    # auto-declared instruments are runtime bookkeeping, never mediators
    assert reg.spec("hits").group == "runtime"
    assert not reg.spec("hits").discovery
    reg.reset_values()
    assert reg.value("hits") is None
    assert reg.names("runtime")  # declarations survive a value reset


def test_derived_counter_tuples_are_the_historical_contract():
    sim = ("queue_depth_mean", "queue_depth_max", "occupancy_mean",
           "prefill_decode_ratio", "slo_violation_rate",
           "page_pool_occupancy", "page_faults", "prefill_chunks_inflight")
    fleet = ("routing_imbalance", "replica_queue_depth_max",
             "straggler_flagged")
    replay = ("rejected_rate", "rejected_too_long")
    assert SIM_COUNTER_NAMES == sim
    assert FLEET_COUNTER_NAMES == sim + fleet
    assert REPLAY_COUNTER_NAMES == sim + replay
    assert REPLAY_FLEET_COUNTER_NAMES == sim + replay + fleet
    # and they are exactly what the global registry derives
    assert SIM_COUNTER_NAMES == obs_metrics.discovery_names("serving")
    assert REPLAY_FLEET_COUNTER_NAMES == obs_metrics.discovery_names(
        "serving", "replay", "fleet")
    # objective clones are declared but excluded from discovery
    assert "latency" in obs_metrics.REGISTRY.names("serving")
    assert "latency" not in SIM_COUNTER_NAMES


@pytest.mark.parametrize("kind", ["sim", "fleet", "replay"])
def test_envs_emit_registered_discovery_names(kind, request):
    """sim, fleet, and replay measurements emit exactly the names their
    subsystem declared in the registry — the counter dict covers the
    derived discovery tuple, and the env's counter_names IS that tuple."""
    if kind == "sim":
        env = ServingEnv(SIM_SPEC, cell=TINY_CELL, families=FAMS, seed=0)
        expected, groups = SIM_COUNTER_NAMES, ("serving",)
    elif kind == "fleet":
        env = ServingEnv(SIM_SPEC, cell=TINY_CELL, families=FAMS, seed=0,
                         fleet=True)
        expected, groups = FLEET_COUNTER_NAMES, ("serving", "fleet")
    else:
        env = request.getfixturevalue("sim2real")[1]
        expected, groups = REPLAY_COUNTER_NAMES, ("serving", "replay")
    assert tuple(env.counter_names) == expected
    assert expected == obs_metrics.REGISTRY.discovery_names(*groups)
    counters, _ = env.intervene(env.space.default_config())
    assert set(expected) <= set(counters)


# --------------------------------------------------------------------------
# bit-identity: tracing must not perturb anything measured or tuned
# --------------------------------------------------------------------------

def test_sim_counters_bit_identical_under_tracing():
    env = ServingEnv(SIM_SPEC, cell=TINY_CELL, families=FAMS, seed=0)
    cfg = env.space.default_config()
    base = env.simulate(cfg)
    with obs_trace.trace_to(None) as tr:
        traced = env.simulate(cfg)
    assert traced.counters() == base.counters()
    assert (traced.completed, traced.ticks, traced.makespan_us) == \
        (base.completed, base.ticks, base.makespan_us)
    # and the traced run did emit modeled-time lifecycle events
    sim_events = [e for e in tr.events()
                  if e.get("pid") == obs_trace.TRACK_SIM]
    assert sim_events


def _replay_tokens(served_model, traced: bool):
    cfg, run, model, params = served_model
    trace = make_workload(REPLAY_SPEC).generate(0)
    b = ContinuousBatcher(model, run, params, num_slots=2, cache_len=32)
    if traced:
        with obs_trace.trace_to(None):
            rep = replay_trace(b, trace, seed=0)
    else:
        rep = replay_trace(b, trace, seed=0)
    toks = [(rs.request.uid, [int(t) for t in rs.generated])
            for rs in b.completed]
    return rep, sorted(toks)


@pytest.fixture(scope="module")
def served_model():
    cfg = tiny_model_config()
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 64, 4, "decode"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, run, model, params


def test_replay_tokens_and_counters_bit_identical_under_tracing(served_model):
    r0, t0 = _replay_tokens(served_model, traced=False)
    r1, t1 = _replay_tokens(served_model, traced=True)
    assert t0 == t1 and t0
    for f in ("completed", "rejected", "ticks", "tokens", "mean_occupancy",
              "queue_depth_mean", "queue_depth_max"):
        assert getattr(r0, f) == getattr(r1, f), f


def test_cameo_trajectory_bit_identical_under_tracing():
    def run_tune():
        src, tgt = make_sandbox_pair(0)
        d_s = src.dataset(150, seed=1)
        q = parse_query("minimize latency within 12 samples")
        cam = Cameo(src.space, q, d_s, counter_names=src.counter_names,
                    seed=0)
        cam.run(tgt, budget=8)
        return cam

    base = run_tune()
    with obs_trace.trace_to(None) as tr:
        traced = run_tune()
    assert traced.trace.action == base.trace.action
    assert traced.trace.best_y == base.trace.best_y
    assert traced.best == base.best
    # the traced run produced structured per-round ask/tell events
    kinds = [ev["kind"] for ev in tr.tuner_rounds]
    assert "ask" in kinds and "tell" in kinds
    tells = [ev for ev in tr.tuner_rounds if ev["kind"] == "tell"]
    assert tells[-1]["round"] == 8
    assert all("best_y" in ev for ev in tells)


# --------------------------------------------------------------------------
# traced replay smoke: the acceptance-criteria run
# --------------------------------------------------------------------------

def test_traced_sim2real_run_exports_lifecycle_and_tuner(tmp_path, sim2real):
    src, tgt = sim2real
    path = str(tmp_path / "sim2real_trace.json")
    with obs_trace.trace_to(path):
        res = transfer_tune("cameo", src, tgt, budget=2, n_source=16,
                            n_target_init=2, query_text=tgt.query_text,
                            seed=0)
    assert np.isfinite(res.best_y)
    events = obs_report.load_trace(path)  # validates the schema
    names = {e.get("name") for e in events}
    # per-request lifecycle spans from the real batcher
    assert {"queue", "prefill", "decode_tick"} <= names
    # async request lifecycles paired by uid
    assert obs_report.request_latencies(events)
    # env deployment spans and per-round tuner events
    assert "deployment" in names and "measure" in names
    tuner = [e for e in events if e.get("cat") == "tuner"]
    assert tuner and {"ask", "tell"} <= {e["name"] for e in tuner}
    # the report CLI summarizes it without error
    rep = obs_report.summarize(events)
    assert rep["lifecycle_us"].get("queue", 0) > 0
    assert rep["tuner_rounds"]
    assert obs_report.main([path, "--slo-ms", "30"]) == 0
    assert obs_report.main([path, "--json"]) == 0


def test_report_cli_rejects_invalid_file(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert obs_report.main([str(bad)]) == 2
    worse = tmp_path / "worse.json"
    worse.write_text(json.dumps({"traceEvents": [{"ph": "?", "ts": 0}]}))
    assert obs_report.main([str(worse)]) == 2


# --------------------------------------------------------------------------
# MetricsLogger: context manager, idempotent close, registry routing
# --------------------------------------------------------------------------

def test_metrics_logger_context_manager_and_registry(tmp_path):
    p = tmp_path / "m.jsonl"
    with MetricsLogger(path=str(p), name="obs-test") as log:
        log.log(1, loss=0.5, event="init")
        fh = log._fh
        assert fh is not None and not fh.closed
    assert log._fh is None and fh.closed
    log.close()                     # idempotent
    log.log(2, loss=0.25)           # after close: stderr only, no raise
    recs = [json.loads(line) for line in p.read_text().splitlines()]
    assert len(recs) == 1 and recs[0]["loss"] == 0.5
    # numeric metrics are mirrored into the registry as labeled gauges
    assert obs_metrics.REGISTRY.value("loss", logger="obs-test") == 0.25


def test_metrics_logger_closes_on_exception(tmp_path):
    p = tmp_path / "m.jsonl"
    with pytest.raises(RuntimeError):
        with MetricsLogger(path=str(p), name="t") as log:
            log.log(0, a=1.0)
            raise RuntimeError("boom")
    assert log._fh is None


def test_metrics_logger_no_path_is_safe():
    with MetricsLogger(name="nofile") as log:
        log.log(0, x=1.0)
    log.close()


# --------------------------------------------------------------------------
# dispatch: spy isolation regressions + profiling hooks
# --------------------------------------------------------------------------

def test_record_resolutions_nested_spies_are_isolated():
    with dispatch.record_resolutions() as outer:
        dispatch.resolve("rmsnorm")
        with dispatch.record_resolutions() as inner:
            dispatch.resolve("ssd")
        dispatch.resolve("mamba_scan")
    assert [r.family for r in outer] == ["rmsnorm", "ssd", "mamba_scan"]
    assert [r.family for r in inner] == ["ssd"]


def test_record_resolutions_out_of_order_exit_keeps_inner_spy():
    # an ExitStack can close the older spy first; the younger one must
    # keep recording and detach itself cleanly afterwards
    a = dispatch.record_resolutions()
    b = dispatch.record_resolutions()
    ra = a.__enter__()
    rb = b.__enter__()
    a.__exit__(None, None, None)
    dispatch.resolve("rmsnorm")
    b.__exit__(None, None, None)
    dispatch.resolve("ssd")     # nothing should record this
    assert ra == []
    assert [r.family for r in rb] == ["rmsnorm"]


def test_record_resolutions_concurrent_threads_are_isolated():
    seen = {}
    go = threading.Barrier(2)

    def spy(name, family, n):
        with dispatch.record_resolutions() as rec:
            go.wait()
            for _ in range(n):
                dispatch.resolve(family)
        seen[name] = [r.family for r in rec]

    t1 = threading.Thread(target=spy, args=("a", "rmsnorm", 3))
    t2 = threading.Thread(target=spy, args=("b", "ssd", 2))
    t1.start(); t2.start(); t1.join(); t2.join()
    assert seen["a"] == ["rmsnorm"] * 3
    assert seen["b"] == ["ssd"] * 2


def test_profile_dispatches_counts_and_times():
    x = np.ones((2, 8), np.float32)
    w = np.ones((8,), np.float32)
    mode = dispatch.default_mode()
    with dispatch.profile_dispatches() as prof:
        dispatch.resolve("ssd")
        t = threading.Thread(target=lambda: dispatch.resolve("ssd"))
        t.start(); t.join()
        dispatch.dispatch("rmsnorm", x, w)
    # cross-thread resolutions all observed
    assert prof.resolutions[("ssd", mode)] == 2
    assert prof.resolutions[("rmsnorm", mode)] == 1
    assert prof.wall_s[("rmsnorm", mode)] > 0
    summ = prof.summary()
    assert summ[f"ssd [{mode}]"]["resolutions"] == 2
    # nothing recorded once the profile exits
    dispatch.resolve("ssd")
    assert prof.resolutions[("ssd", mode)] == 2


def test_dispatch_traced_emits_kernel_track_span():
    x = np.ones((2, 8), np.float32)
    w = np.ones((8,), np.float32)
    mode = dispatch.default_mode()
    before = obs_metrics.REGISTRY.value("dispatch_resolutions_total",
                                        family="rmsnorm", mode=mode) or 0.0
    with obs_trace.trace_to(None) as tr:
        dispatch.dispatch("rmsnorm", x, w)
    spans = [e for e in tr.events()
             if e.get("pid") == obs_trace.TRACK_KERNEL and e["ph"] == "X"]
    assert spans and spans[0]["name"] == "rmsnorm"
    assert spans[0]["args"]["mode"] == mode
    after = obs_metrics.REGISTRY.value("dispatch_resolutions_total",
                                       family="rmsnorm", mode=mode)
    assert after == before + 1


def test_jit_cache_hit_miss_instants(served_model):
    cfg, run, model, params = served_model
    with obs_trace.trace_to(None) as tr:
        s1 = jitted_steps(model, run, cache_len=24)
        s2 = jitted_steps(model, run, cache_len=24)
    assert s1 is s2
    names = [e["name"] for e in tr.events() if e.get("cat") == "jit_cache"]
    assert "jit_cache_miss" in names and "jit_cache_hit" in names
    assert (obs_metrics.REGISTRY.value("jit_cache_hits") or 0) >= 1
    assert (obs_metrics.REGISTRY.value("jit_cache_misses") or 0) >= 1
