"""Per-architecture smoke tests: the REDUCED config of each assigned family
runs one forward and one train step on CPU, asserting output shapes and the
absence of NaNs (per the task spec)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config, list_archs
from repro.data.pipeline import make_data
from repro.models.model import build_model
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_train_state, make_train_step
from repro.utils.config import MeshConfig, RunConfig, ShapeConfig, TrainConfig

ARCHS = list_archs()


def _smoke_run(arch):
    cfg = get_smoke_config(arch)
    shape = ShapeConfig("train_smoke", seq_len=32, global_batch=2, kind="train")
    return RunConfig(model=cfg, shape=shape,
                     mesh=MeshConfig(shape=(1,), axes=("data",)),
                     train=TrainConfig(total_steps=4, warmup_steps=1))


def _batch_for(run):
    data = make_data(run.model, run.shape, seed=0)
    return {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    run = _smoke_run(arch)
    model = build_model(run.model, run.parallel)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(run)
    fkw = {}
    if run.model.family == "vlm":
        fkw["vision_embeds"] = batch["vision_embeds"]
    if run.model.family == "audio":
        fkw["frames"] = batch["frames"]
    logits, _, aux = model.forward(params, batch["inputs"], **fkw)
    b, s = batch["inputs"].shape
    assert logits.shape == (b, s, run.model.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    run = _smoke_run(arch)
    model = build_model(run.model, run.parallel)
    optimizer = make_optimizer(run.train)
    step_fn = jax.jit(make_train_step(model, run, optimizer))
    state = init_train_state(model, run, optimizer, jax.random.PRNGKey(0))
    batch = _batch_for(run)
    new_state, metrics = step_fn(state, batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert np.isfinite(float(metrics["grad_norm"])), f"{arch}: NaN grads"
    # params actually changed
    before = jax.tree.leaves(state.params)[1]
    after = jax.tree.leaves(new_state.params)[1]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive_and_moe_active_smaller(arch):
    cfg = get_smoke_config(arch)
    n = cfg.param_count()
    assert n > 0
    if cfg.is_moe:
        assert cfg.active_param_count() < n


def test_full_config_param_counts_match_public_scale():
    """Full (non-smoke) configs land in the right parameter ballpark."""
    from repro.configs.registry import get_model_config

    expect = {
        "falcon-mamba-7b": (6e9, 9e9),
        "zamba2-2.7b": (2.0e9, 3.5e9),
        "nemotron-4-15b": (12e9, 18e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
        "command-r-35b": (30e9, 40e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "deepseek-v3-671b": (600e9, 750e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_model_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"
