"""Serving correctness: prefill -> decode logits must match the full
(cacheless) forward pass at every position, per architecture family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_model_config
from repro.models.model import build_model
from repro.train.serve_step import (
    generate, make_decode_step, make_prefill_step, sample_token)
from repro.utils.config import RunConfig, ShapeConfig


def _run_for(cfg):
    return RunConfig(model=cfg, shape=ShapeConfig("t", 16, 2, "decode"))


def _check_consistency(cfg, extras=None, steps=4, tol=2e-3):
    run = _run_for(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + steps), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    if extras:
        batch.update(extras)
    fkw = {}
    if cfg.family == "vlm":
        fkw["vision_embeds"] = extras["vision_embeds"]
    if cfg.family == "audio":
        from repro.models import encdec
        enc = encdec.encode(params, cfg, run.parallel, extras["frames"])
        full_logits, _ = encdec.decode_forward(params, cfg, run.parallel,
                                               toks, enc)
    else:
        full_logits, _, _ = model.forward(params, toks, **fkw)

    prefill = make_prefill_step(model, run, cache_len=S + steps)
    decode = make_decode_step(model, run)
    state, logits = prefill(params, batch)
    errs = [float(jnp.max(jnp.abs(logits - full_logits[:, S - 1])))]
    for i in range(steps):
        state, logits = decode(params, state, toks[:, S + i][:, None])
        if i < steps - 1:
            errs.append(float(jnp.max(jnp.abs(logits - full_logits[:, S + i]))))
    assert max(errs) < tol, errs


def test_dense():
    _check_consistency(tiny_model_config())


def test_sliding_window_ring_cache():
    _check_consistency(tiny_model_config(sliding_window=5))


def test_mla():
    _check_consistency(tiny_model_config(
        attn_type="mla", q_lora_rank=16, kv_lora_rank=16,
        qk_rope_head_dim=8, qk_nope_head_dim=8, v_head_dim=8))


def test_ssm():
    _check_consistency(tiny_model_config(
        family="ssm", attn_type="none", num_heads=0, num_kv_heads=0, d_ff=0,
        ssm_state=4, ssm_chunk=4))


def test_hybrid():
    _check_consistency(tiny_model_config(
        family="hybrid", ssm_state=4, ssm_num_heads=4, ssm_chunk=4,
        hybrid_attn_period=2))


def test_moe():
    _check_consistency(tiny_model_config(
        family="moe", moe_num_experts=4, moe_top_k=2, moe_d_ff=32,
        moe_capacity_factor=8.0))  # no-drop so train/serve paths agree


def test_vlm():
    ve = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 32))
    _check_consistency(tiny_model_config(
        family="vlm", cross_attn_period=2, vision_seq=6, vision_dim=32),
        extras={"vision_embeds": ve})


def test_audio():
    fr = jax.random.normal(jax.random.PRNGKey(4), (2, 10, 32))
    _check_consistency(tiny_model_config(
        family="audio", encoder_layers=2, encoder_seq=10),
        extras={"frames": fr})


def test_generate_shapes_and_greedy_determinism():
    cfg = tiny_model_config()
    run = _run_for(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    out1 = generate(model, run, params, {"tokens": toks}, num_steps=5)
    out2 = generate(model, run, params, {"tokens": toks}, num_steps=5)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(out1, out2)


def test_sample_token_temperature():
    logits = jnp.asarray([[0.0, 10.0, 0.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample_token(logits, key, 0.0)[0]) == 1
    # high temperature still returns a valid index
    assert 0 <= int(sample_token(logits, key, 5.0)[0]) < 3
