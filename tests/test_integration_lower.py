"""Integration: the launch/build path lowers + compiles smoke-scale cells on
the single CPU device (the production-mesh version is exercised by
``repro.launch.dryrun`` under its 512-device flag)."""

import jax
import pytest

from repro.configs.registry import (arch_shapes, input_specs, list_archs,
                                    make_run)
from repro.launch.build import lower_step
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh


@pytest.mark.parametrize("shape", ["train_smoke", "prefill_smoke",
                                   "decode_smoke"])
def test_lower_compile_smoke_cells(shape):
    run = make_run("llama3.2-1b", shape, smoke=True)
    mesh = make_mesh(run.mesh)
    bundle, lowered = lower_step(run, mesh)
    compiled = lowered.compile()
    costs = analyze_hlo(compiled.as_text())
    assert costs.flops > 0
    assert costs.bytes_accessed > 0


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "deepseek-v3-671b",
                                  "whisper-large-v3", "zamba2-2.7b"])
def test_lower_compile_other_families(arch):
    run = make_run(arch, "train_smoke", smoke=True)
    mesh = make_mesh(run.mesh)
    _, lowered = lower_step(run, mesh)
    lowered.compile()


def test_input_specs_cover_all_cells():
    for arch in list_archs():
        for shape in arch_shapes(arch):
            run = make_run(arch, shape)
            specs = input_specs(run)
            if run.shape.kind == "train":
                assert specs["batch"]["inputs"].shape == (
                    run.shape.global_batch, run.shape.seq_len)
            elif run.shape.kind == "decode":
                assert specs["tokens"].shape == (run.shape.global_batch, 1)
                leaves = jax.tree.leaves(specs["state"])
                assert all(hasattr(l, "shape") for l in leaves)


def test_long_500k_skips_are_exactly_the_full_attention_archs():
    skipped = [a for a in list_archs() if "long_500k" not in arch_shapes(a)]
    assert sorted(skipped) == sorted([
        "nemotron-4-15b", "llama3.2-1b", "command-r-35b", "deepseek-v3-671b",
        "llama4-maverick-400b-a17b", "llama-3.2-vision-11b",
        "whisper-large-v3"])
    runnable = [a for a in list_archs() if "long_500k" in arch_shapes(a)]
    assert sorted(runnable) == sorted(
        ["falcon-mamba-7b", "zamba2-2.7b", "h2o-danube-1.8b"])


def test_make_run_rejects_long500k_for_full_attention():
    with pytest.raises(ValueError):
        make_run("llama3.2-1b", "long_500k")


def test_tuner_space_round_trips_parallel_config():
    from repro.configs.registry import get_model_config
    from repro.tuner.space import (apply_config, config_to_parallel_kv,
                                   framework_space)
    from repro.utils.config import ParallelConfig

    cfg = get_model_config("llama3.2-1b")
    space = framework_space(cfg, "train")
    c = space.default_config()
    c["remat"] = "full"
    c["microbatch"] = 4
    par = apply_config(ParallelConfig(), c)
    assert par.remat == "full" and par.microbatch == 4
    kv = config_to_parallel_kv(c)
    assert "remat=full" in kv and "microbatch=4" in kv
