"""Direction-aware infeasibility handling + transfer-tuning fairness.

Pins the two clamp sites for maximize-direction queries (``Dataset.matrix``
and ``_fit_surrogates``), ``Cameo.best`` tie-breaking, the
constant-objective-column guard in ``_refresh_graph_t``, and the identical
initial-target-dataset contract of ``transfer_tune``."""

import numpy as np
import pytest

from repro.core.ace import rank_by_ace
from repro.core.cameo import Cameo, Dataset
from repro.core.query import parse_query
from repro.core.spaces import ConfigSpace, Option
from repro.envs.base import PooledEnv
from repro.tuner.runner import transfer_tune


# --------------------------------------------------------------------------
# Dataset.matrix: direction-aware clamp
# --------------------------------------------------------------------------

def _space():
    return ConfigSpace([Option("a", (1, 2, 4, 8)), Option("b", (1, 2, 4))])


def _dataset(ys):
    d = Dataset()
    for i, y in enumerate(ys):
        d.add({"a": [1, 2, 4, 8][i % 4], "b": [1, 2, 4][i % 3]},
              {"c": float(i)}, y)
    return d


def test_matrix_clamps_neg_inf_below_for_maximize():
    # maximize: constraint handling stores -inf; the clamp must land BELOW
    # every feasible value, not above (the pre-fix poisoning)
    d = _dataset([10.0, 30.0, float("-inf"), 20.0])
    m, names = d.matrix(_space(), ["c"], maximize=True)
    obj = m[:, names.index("__objective__")]
    assert np.isfinite(obj).all()
    assert obj[2] < 10.0  # pessimistically low
    assert obj[2] == 10.0 - 2.0 * (30.0 - 10.0 + 1.0)


def test_matrix_clamps_pos_inf_above_for_minimize():
    d = _dataset([10.0, 30.0, float("inf"), 20.0])
    m, names = d.matrix(_space(), ["c"])  # default: minimize
    obj = m[:, names.index("__objective__")]
    assert np.isfinite(obj).all()
    assert obj[2] > 30.0
    assert obj[2] == 30.0 + 2.0 * (30.0 - 10.0 + 1.0)


def test_matrix_counter_clamp_unchanged_by_direction():
    d = Dataset()
    d.add({"a": 1, "b": 1}, {"c": 1.0}, 5.0)
    d.add({"a": 2, "b": 2}, {"c": float("inf")}, 7.0)
    for maximize in (False, True):
        m, names = d.matrix(_space(), ["c"], maximize=maximize)
        c = m[:, names.index("c")]
        assert np.isfinite(c).all() and c[1] > c[0]


def test_matrix_all_infeasible_column_clamps_to_zero():
    d = _dataset([float("-inf"), float("-inf")])
    m, names = d.matrix(_space(), [], maximize=True)
    assert (m[:, -1] == 0.0).all()


# --------------------------------------------------------------------------
# a maximize environment (throughput objective, latency constraint)
# --------------------------------------------------------------------------

class ThroughputEnv(PooledEnv):
    """Deterministic 12-point landscape: y = throughput (maximize), counters
    carry the latency the query constrains on.  Optimum under latency < 16
    is (a=8, b=1) -> 81.0."""

    def __init__(self, seed=0):
        super().__init__(_space(), ("latency", "throughput"), seed=seed)

    def _measure(self, cfg):
        a, b = float(cfg["a"]), float(cfg["b"])
        throughput = 10.0 * a + 5.0 * b - 0.5 * a * b
        latency = a * b
        return {"latency": latency, "throughput": throughput}, throughput


def _source_dataset(n=60, seed=1):
    env = ThroughputEnv(seed=seed)
    return env.dataset(n, seed=seed)


def test_maximize_query_end_to_end():
    q = parse_query("maximize throughput for which latency is "
                    "less than 16 within 20 samples")
    assert q.maximize and q.objective == "throughput"
    assert q.constraints == [("latency", "<", 16.0)]

    env = ThroughputEnv(seed=0)
    cam = Cameo(env.space, q, _source_dataset(),
                counter_names=env.counter_names, seed=0)
    cam.seed_target(env.dataset(4, seed=2))
    cfg, y = cam.run(env, 20)
    assert cfg is not None
    # the optimum of the constrained problem: a=8, b=1 -> 81, latency 8 < 16
    assert cfg == {"a": 8, "b": 1}
    assert y == 81.0
    # infeasible measurements were stored as -inf (maximize sentinel), and
    # best never surfaces one
    assert all(np.isfinite(v) or v == float("-inf") for v in cam.d_t.ys)
    # clamp site 1: the discovery matrix is finite with infeasible rows
    # pessimistically LOW
    m, names = cam.d_t.matrix(env.space, cam.counter_names, maximize=True)
    obj = m[:, -1]
    assert np.isfinite(obj).all()
    feas = [v for v in cam.d_t.ys if np.isfinite(v)]
    if len(feas) < len(cam.d_t.ys):
        assert obj.min() < min(feas)


def test_maximize_infeasible_does_not_poison_ranking():
    # pre-fix: -inf clamped HIGH made infeasible rows the "best" objective
    # values, so options correlated with infeasibility ranked as strong
    # causes; post-fix the clamp is pessimistic and the top-ACE option must
    # be one that actually drives feasible throughput
    env = ThroughputEnv(seed=0)
    d = env.dataset(48, seed=3)
    q = parse_query("maximize throughput for which latency is "
                    "less than 16 within 10 samples")
    # apply constraint handling the way Cameo stores target data
    constrained = Dataset()
    for c, cnt, y in zip(d.configs, d.counters, d.ys):
        ok = cnt["latency"] < 16.0
        constrained.add(c, cnt, y if ok else float("-inf"))
    cam = Cameo(env.space, q, constrained,
                counter_names=env.counter_names, seed=0)
    data_s, names_s = constrained.matrix(env.space, cam.counter_names,
                                         maximize=True)
    obj = data_s[:, -1]
    feasible_max = max(y for y in constrained.ys if np.isfinite(y))
    assert obj.max() <= feasible_max  # no artificially-good rows


def test_fit_surrogates_clamp_is_direction_aware():
    # clamp site 2: -inf target measurements become pessimistic (worst) in
    # the internal minimize space, so the cold GP's incumbent stays feasible
    env = ThroughputEnv(seed=0)
    q = parse_query("maximize throughput within 10 samples")
    cam = Cameo(env.space, q, _source_dataset(), seed=0)
    init = Dataset()
    init.add({"a": 1, "b": 1}, {}, 14.5)
    init.add({"a": 2, "b": 2}, {}, 28.0)
    init.add({"a": 4, "b": 4}, {}, float("-inf"))  # infeasible
    cam.seed_target(init)
    cam._fit_surrogates()
    mu, sd = cam._cold.predict([{"a": 4, "b": 4}])
    assert np.isfinite(mu).all() and np.isfinite(sd).all()
    # internal best (minimize space) is the best FEASIBLE value, not -inf
    finite = cam._ys_internal()[np.isfinite(cam._ys_internal())]
    assert float(np.min(finite)) == -28.0


def test_best_tie_breaking_first_index_both_directions():
    env = ThroughputEnv(seed=0)
    q_max = parse_query("maximize throughput within 5 samples")
    cam = Cameo(env.space, q_max, _source_dataset(), seed=0)
    d = Dataset()
    d.add({"a": 1, "b": 1}, {}, 3.0)
    d.add({"a": 2, "b": 1}, {}, 7.0)   # first maximal
    d.add({"a": 4, "b": 1}, {}, 7.0)   # tied
    cam.seed_target(d)
    cfg, y = cam.best
    assert y == 7.0 and cfg == {"a": 2, "b": 1}

    q_min = parse_query("minimize latency within 5 samples")
    cam2 = Cameo(env.space, q_min, _source_dataset(), seed=0)
    d2 = Dataset()
    d2.add({"a": 4, "b": 1}, {}, 2.0)  # first minimal
    d2.add({"a": 2, "b": 1}, {}, 2.0)  # tied
    d2.add({"a": 1, "b": 1}, {}, 9.0)
    cam2.seed_target(d2)
    cfg2, y2 = cam2.best
    assert y2 == 2.0 and cfg2 == {"a": 4, "b": 1}


# --------------------------------------------------------------------------
# _refresh_graph_t: constant objective column survives
# --------------------------------------------------------------------------

def test_refresh_graph_t_retains_constant_objective():
    env = ThroughputEnv(seed=0)
    q = parse_query("minimize latency within 10 samples")
    cam = Cameo(env.space, q, _source_dataset(), seed=0)
    init = Dataset()
    rng = np.random.default_rng(0)
    for cfg in env.space.sample(rng, 9):
        init.add(cfg, {}, 5.0)  # identical early target ys
    cam.seed_target(init)
    assert cam.g_t is not None
    assert "__objective__" in cam.g_t.nodes
    # the later ACE re-ranking against g_t must see its objective node
    data_t, names_t = cam.d_t.matrix(cam.space, cam.counter_names)
    ranked = rank_by_ace(data_t, names_t, "__objective__", cam.g_t)
    assert [n for n, _ in ranked]  # well-posed, no missing-node collapse


# --------------------------------------------------------------------------
# transfer_tune: identical initial target dataset for every method
# --------------------------------------------------------------------------

class QuadraticEnv(PooledEnv):
    def __init__(self, seed=0):
        space = ConfigSpace([Option("x", tuple(range(8))),
                             Option("z", (0, 1, 2, 3))])
        super().__init__(space, (), seed=seed)

    def _measure(self, cfg):
        return {}, float((cfg["x"] - 5) ** 2 + 0.5 * (cfg["z"] - 1) ** 2)


@pytest.mark.parametrize("method", ["random", "smac", "cameo"])
def test_transfer_tune_records_identical_target_init(method):
    res = transfer_tune(method, QuadraticEnv(seed=1), QuadraticEnv(seed=2),
                        budget=4, n_source=16, n_target_init=3, seed=0)
    assert res.extras["n_target_init"] == 3
    assert len(res.extras["target_init_ys"]) == 3
    # the init samples count toward the incumbent from round one
    assert res.best_y <= min(res.extras["target_init_ys"])
    assert res.trace_best_y[0] <= min(res.extras["target_init_ys"])


def test_transfer_tune_init_identical_across_methods():
    ys = {}
    for method in ("cameo", "random", "restune"):
        res = transfer_tune(method, QuadraticEnv(seed=1),
                            QuadraticEnv(seed=2), budget=3, n_source=16,
                            n_target_init=4, seed=7)
        ys[method] = res.extras["target_init_ys"]
    assert ys["cameo"] == ys["random"] == ys["restune"]
