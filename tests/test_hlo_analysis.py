"""HLO analyzer unit tests on synthetic module text (no devices needed)."""

import pytest

from repro.launch.hlo_analysis import analyze_hlo, collective_schedule

SIMPLE = """
HloModule jit_f, entry_computation_layout={(f32[8,16])->f32[8,16]}

ENTRY %main.1 (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  %w = f32[16,16]{1,0} constant({...})
  ROOT %dot = f32[8,16]{1,0} dot(%p, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_simple_dot_flops_and_bytes():
    c = analyze_hlo(SIMPLE)
    assert c.flops == 2 * 8 * 16 * 16
    # reads p + w, writes result
    assert c.bytes_accessed == (8 * 16 + 16 * 16 + 8 * 16) * 4


WHILE = """
HloModule jit_g

%body (param: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %param = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%param), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%param), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %x2 = f32[4,4]{1,0} multiply(%x, %x)
  ROOT %t = (s32[], f32[4,4]) tuple(%i2, %x2)
}

%cond (param.1: (s32[], f32[4,4])) -> pred[] {
  %param.1 = (s32[], f32[4,4]) parameter(0)
  %i.1 = s32[] get-tuple-element(%param.1), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i.1, %n), direction=LT
}

ENTRY %main.2 (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]) tuple(%z, %a)
  %w = (s32[], f32[4,4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_from_condition():
    c = analyze_hlo(WHILE)
    # multiply: 16 flops/iteration x 10 trips (plus the scalar add)
    assert c.flops == pytest.approx(10 * (16 + 1))


WHILE_BACKEND = WHILE.replace(
    "body=%body", 'body=%body, backend_config={"known_trip_count":{"n":"7"}}')


def test_while_trip_count_from_backend_config():
    c = analyze_hlo(WHILE_BACKEND)
    assert c.flops == pytest.approx(7 * 17)


COLL = """
HloModule jit_h

ENTRY %main.3 (a: bf16[128,64]) -> bf16[128,64] {
  %a = bf16[128,64]{1,0} parameter(0)
  %ag = bf16[128,256]{1,0} all-gather(%a), dimensions={1}, replica_groups=[2,4]<=[8]
  %c = bf16[128,64]{1,0} slice(%ag), slice={[0:128],[0:64]}
  ROOT %ar = bf16[128,64]{1,0} all-reduce(%c), to_apply=%add
}
"""


def test_collective_bytes_by_kind():
    c = analyze_hlo(COLL)
    assert c.collective_bytes["all-gather"] == 128 * 64 * 2
    assert c.collective_bytes["all-reduce"] == 128 * 64 * 2
    assert c.collective_count == {"all-gather": 1, "all-reduce": 1}
    assert c.total_collective_bytes == 2 * 128 * 64 * 2


SCOPED = """
HloModule jit_k

ENTRY %main.4 (q: bf16[64,32]) -> f32[64,64] {
  %q = bf16[64,32]{1,0} parameter(0)
  %s = f32[64,64]{1,0} dot(%q, %q), lhs_contracting_dims={1}, rhs_contracting_dims={1}, metadata={op_name="jit(k)/repro_kernel.flash_attention/dot"}
  %e = f32[64,64]{1,0} exponential(%s), metadata={op_name="jit(k)/repro_kernel.flash_attention/exp"}
  ROOT %o = f32[64,64]{1,0} add(%e, %e), metadata={op_name="jit(k)/consumer/add"}
}
"""


def test_kernel_scope_elides_interior_bytes_keeps_flops():
    c = analyze_hlo(SCOPED)
    # flops: dot 2*64*64*32 + exp 64*64 + add 64*64
    assert c.flops == 2 * 64 * 64 * 32 + 2 * 64 * 64
    # bytes: dot reads q twice (both operands cross the scope boundary),
    # e's write is charged (read by the out-of-scope add), the s->e interior
    # round-trip is elided; add charges its operands + result
    q_reads = 2 * 64 * 32 * 2
    e_write = 64 * 64 * 4
    add_io = 3 * 64 * 64 * 4
    assert c.bytes_accessed == q_reads + e_write + add_io


def test_collective_schedule_listing():
    sched = collective_schedule(COLL)
    assert len(sched) == 2
    assert "all-gather" in sched[0]
