"""Batched serving example: prefill a batch of prompts, decode with a KV
cache, report per-phase latency statistics.

    PYTHONPATH=src python examples/serve_lm.py --batch 8 --prompt-len 128 --gen 64
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ModelConfig, RunConfig, build_model
from repro.data import make_data
from repro.train.serve_step import jitted_steps, sample_token
from repro.utils.config import MeshConfig, ShapeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--arch-style", choices=["dense", "swa", "ssm"],
                    default="dense")
    args = ap.parse_args()

    if args.arch_style == "ssm":
        cfg = ModelConfig(name="serve-ssm", family="ssm", attn_type="none",
                          num_layers=6, d_model=384, num_heads=0,
                          num_kv_heads=0, d_ff=0, ssm_state=16,
                          vocab_size=8192, dtype="float32")
    elif args.arch_style == "swa":
        cfg = ModelConfig(name="serve-swa", num_layers=6, d_model=384,
                          num_heads=6, num_kv_heads=2, d_ff=1536,
                          sliding_window=64, vocab_size=8192,
                          dtype="float32")
    else:
        cfg = ModelConfig(name="serve-dense", num_layers=6, d_model=384,
                          num_heads=6, num_kv_heads=2, d_ff=1536,
                          vocab_size=8192, dtype="float32")

    cache_len = args.prompt_len + args.gen
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve", cache_len, args.batch, "decode"),
                    mesh=MeshConfig(shape=(1,), axes=("data",)))
    model = build_model(cfg, run.parallel)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.0f}M params), "
          f"batch={args.batch} prompt={args.prompt_len} gen={args.gen}")

    data = make_data(cfg, run.shape, seed=0)
    prompts = jnp.asarray(
        data.batch_at(0)["inputs"][:args.batch, :args.prompt_len])

    # cached jitted pair: repeated runs in one process reuse the compilation
    prefill, decode = jitted_steps(model, run, cache_len=cache_len)

    t0 = time.perf_counter()
    state, logits = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = sample_token(logits, jax.random.PRNGKey(1))
    lat = []
    for i in range(args.gen):
        t1 = time.perf_counter()
        state, logits = decode(params, state, tok[:, None])
        jax.block_until_ready(logits)
        lat.append(time.perf_counter() - t1)
        tok = sample_token(logits, jax.random.PRNGKey(2 + i), 0.8)
    lat_ms = np.asarray(lat[1:]) * 1000  # drop the first (warmup) step
    print(f"prefill: {t_prefill*1000:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode:  p50={np.percentile(lat_ms, 50):.2f} ms  "
          f"p99={np.percentile(lat_ms, 99):.2f} ms  "
          f"({args.batch / np.mean(lat_ms) * 1000:.0f} tok/s)")


if __name__ == "__main__":
    main()
