"""Tuned paged-KV serving vs tuned dense serving under a heavy-tailed trace.

The dense serving stack provisions one static ``(num_slots, cache_len)``
cache: every resident request pays decode-attention prices for the full
``cache_len``, sized for the longest request the trace can produce.  The
paged stack provisions a shared page pool instead — each request holds only
the pages its context actually needs — so a heavy-tailed workload (mostly
short requests, a long tail forcing the dense cache large) is exactly where
paging should win.

This example runs the same CAMEO transfer loop twice on the workload
simulator: once over the dense surface (``serving.*`` + launch geometry) and
once over the paged surface (same plus ``pages.*`` and the
``paged_attention.*`` launch knobs, with ``pages.paging=off`` still
available so the tuner can fall back to dense if paging loses).  Both
transfer from a calm Poisson source to the heavy-tailed target, and the
final comparison is the noise-free simulated p99 of each tuned deployment.

    PYTHONPATH=src python examples/paged_serving.py
    PYTHONPATH=src python examples/paged_serving.py --budget 20 \
        --target "heavy_tail:rate=3000"
"""

import argparse

from repro.envs.measure import KernelWorkload
from repro.envs.serving_env import ServingEnv, make_serving_pair
from repro.serving.paging import PagedPlan
from repro.tuner.runner import transfer_tune

DENSE_FAMILIES = ("flash_attention", "rmsnorm")

#: a small served model: short typical contexts make the heavy tail hurt —
#: the dense cache must be sized for the tail while the paged pool is not
CELL = KernelWorkload(name="serve-1b", batch=8, seq_len=512, heads=8,
                      kv_heads=2, head_dim=64, d_model=512)


def tune(tag, families, args):
    # trace_seed pins the arrival realization so both surfaces (and repeat
    # runs) score against the same trace; the env seed only drives noise
    src, tgt = make_serving_pair(args.source, args.target, CELL,
                                 families=families, seed=0,
                                 trace_seed=args.trace_seed)
    res = transfer_tune(args.method, src, tgt, budget=args.budget,
                        n_source=args.n_source,
                        n_target_init=args.n_target_init,
                        query_text=tgt.query_text, seed=0)
    cfg = res.best_config or {}
    report = tgt.simulate(cfg)  # noise-free: both surfaces score identically
    plan = ServingEnv.plan_of(cfg)
    paged = PagedPlan.from_config(cfg)
    if not report.feasible:
        print(f"\n[{tag}] no feasible config in budget "
              f"({res.wall_s:.1f}s tuning) — raise --budget or "
              f"--n-target-init")
        return report
    print(f"\n[{tag}] tuned p99 = {report.p99_latency_us:.1f} us  "
          f"(mean {report.mean_latency_us:.1f} us, {res.wall_s:.1f}s tuning)")
    print(f"  plan: slots={plan.num_slots} admit={plan.admit_chunk} "
          f"cache={plan.cache_len} interleave={plan.interleave}")
    if paged.paging:
        print(f"  pages: pool={paged.pool_pages} page_size={paged.page_size} "
              f"pages/slot<={paged.pages_per_slot_max} "
              f"prefill_chunk={paged.prefill_chunk} "
              f"(slot capacity {paged.slot_capacity})")
        print(f"  pool occupancy {report.page_pool_occupancy:.2f}, "
              f"{report.page_faults:.0f} page faults")
    else:
        print("  pages: off (dense cache)")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--source", default="poisson:rate=2600")
    ap.add_argument("--target", default="heavy_tail:rate=2600")
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--n-source", type=int, default=64)
    ap.add_argument("--n-target-init", type=int, default=8,
                    help="free initial target measurements; the dense "
                         "surface needs a few to find a feasible cache_len "
                         "under the heavy tail")
    ap.add_argument("--method", default="cameo")
    ap.add_argument("--trace-seed", type=int, default=0)
    args = ap.parse_args()

    print(f"workload shift: {args.source} -> {args.target}")
    dense = tune("dense", DENSE_FAMILIES, args)
    paged = tune("paged", DENSE_FAMILIES + ("paged_attention",), args)

    dp, pp = dense.p99_latency_us, paged.p99_latency_us
    if not dense.feasible or not paged.feasible:
        loser = "dense" if not dense.feasible else "paged"
        print(f"\nno comparison: the {loser} surface found no feasible "
              f"config in budget")
        return
    verdict = "paged wins" if pp < dp else "dense wins"
    print(f"\ntuned dense p99 {dp:.1f} us vs tuned paged p99 {pp:.1f} us "
          f"-> {verdict} ({100.0 * (dp - pp) / dp:+.1f}%)")


if __name__ == "__main__":
    main()
