"""CAMEO tuning the serving stack under a workload shift — minimal loop.

Source environment: a calm Poisson request trace (cheap staging traffic).
Target environment: the same served model under a bursty trace (the paper's
workload-fluctuation environment change).  The tuned surface is the whole
serving stack: scheduler knobs (decode slots, admission chunk, cache
length, interleave policy) joined with the kernel launch geometry.
Everything runs in the deterministic workload simulator — seconds on CPU.

    PYTHONPATH=src python examples/serving_tuning.py
    PYTHONPATH=src python examples/serving_tuning.py \
        --target "heavy_tail:rate=2000" --budget 15 --methods cameo,random
"""

import argparse

from repro.envs.serving_env import ServingEnv, make_serving_pair
from repro.tuner.runner import transfer_tune


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--source", default="poisson:rate=2500")
    ap.add_argument("--target", default="bursty:rate=2500,burst=6")
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--n-source", type=int, default=48)
    ap.add_argument("--methods", default="cameo,random")
    args = ap.parse_args()

    src, tgt = make_serving_pair(args.source, args.target,
                                 families=("flash_attention", "rmsnorm"),
                                 seed=0)
    print(f"workload shift: {src.workload_spec} -> {tgt.workload_spec}")
    print(f"serving space: {tgt.space.names}")

    default = tgt.space.default_config()
    report = tgt.simulate(default)
    print(f"\ndefault plan: p99={report.p99_latency_us:.0f} us  "
          f"queue_depth={report.queue_depth_mean:.1f}  "
          f"occupancy={report.occupancy_mean:.1f}")

    for method in args.methods.split(","):
        res = transfer_tune(method, src, tgt, budget=args.budget,
                            n_source=args.n_source, n_target_init=3,
                            query_text=tgt.query_text, seed=0)
        plan = ServingEnv.plan_of(res.best_config or {})
        tuned = tgt.simulate(res.best_config or {})
        print(f"\n[{method}] tuned p99: {tuned.p99_latency_us:.0f} us "
              f"(best measured {res.best_y:.0f} us, {res.wall_s:.1f}s)")
        print(f"  plan: slots={plan.num_slots} admit={plan.admit_chunk} "
              f"cache={plan.cache_len} interleave={plan.interleave}")
        print(f"  launch: {res.launch_config}")


if __name__ == "__main__":
    main()
