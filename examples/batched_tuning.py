"""Batched ask/tell tuning — q-batch proposal through batched replay.

Same sim-to-real loop as ``examples/sim2real.py``, but driven explicitly
through the round-structured interface ``Cameo.run`` wraps:
``Cameo.ask(k)`` proposes a diverse batch of k candidates (acquisition
argmax as the anchor, later slots repelled in the reduced causal
subspace but pinned to the anchor's compile key),
``ReplayServingEnv.intervene_batch`` measures them against one warmed
deployment per compile-key group, and one ``tell`` refreshes the
surrogate per round.  The budget counts measurements, so k=1 is the
historical sequential loop and larger k trades surrogate freshness for
wall-clock — on the replay environment the win is large because the
expensive part is per-(cache_len, launch) jit compilation, not the
replay itself.

    PYTHONPATH=src python examples/batched_tuning.py
    PYTHONPATH=src python examples/batched_tuning.py --query-batch 2 \
        --workload "bursty:rate=1500,burst=6,horizon=0.004"
"""

import argparse
import time

from repro.core.cameo import Cameo
from repro.core.query import parse_query
from repro.envs.replay_env import ReplayServingEnv, make_sim2real_pair

DEFAULT_WORKLOAD = ("poisson:rate=1500,horizon=0.004,mean_prompt=6,"
                    "mean_output=4,max_len=16")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=DEFAULT_WORKLOAD)
    ap.add_argument("--budget", type=int, default=8,
                    help="real-replay intervention budget (measurements, "
                         "not rounds)")
    ap.add_argument("--query-batch", type=int, default=4, metavar="K",
                    help="proposals measured per ask/tell round")
    ap.add_argument("--n-source", type=int, default=32,
                    help="cheap simulator observations")
    args = ap.parse_args()

    src, tgt = make_sim2real_pair(args.workload, seed=0, repeats=3)
    print(f"trace: {len(tgt.trace)} requests ({tgt.workload_spec})")
    print(f"compile-key dims (shared within a batch group): "
          f"{list(tgt.batch_share_dims)}")

    d_obs = src.dataset(args.n_source, seed=1)
    d_init = tgt.dataset(2, seed=2, query_batch=args.query_batch)
    query = parse_query(tgt.query_text.format(budget=args.budget))
    cam = Cameo(tgt.space, query, d_obs,
                counter_names=src.counter_names, seed=0)
    cam.seed_target(d_init)

    spent = 0
    while spent < args.budget:
        k = min(args.query_batch, args.budget - spent)
        t0 = time.perf_counter()
        props = cam.ask(k, share_dims=tgt.batch_share_dims)
        configs, counters, ys, actions = [], [], [], []
        pending = []
        for p in props:
            if p.kind == "observe":
                cfg, cnt, y = tgt.observe(cam.rng)
                configs.append(cfg)
                counters.append(cnt)
                ys.append(y)
                actions.append("observe")
            else:
                pending.append(p.config)
        for cfg, (cnt, y) in zip(pending, tgt.intervene_batch(pending)):
            configs.append(cfg)
            counters.append(cnt)
            ys.append(y)
            actions.append("intervene")
        cam.tell(configs, counters, ys, actions)
        spent += len(props)
        wall = time.perf_counter() - t0
        ys_s = ", ".join("inf" if y != y or y == float("inf")
                         else f"{y:.1f}" for y in ys)
        print(f"round of {len(props)}: [{ys_s}] ms in {wall:.1f}s "
              f"({len(props) - len(pending)} observed, "
              f"{len(pending)} replayed)")

    best_cfg, best_y = cam.best
    plan = ReplayServingEnv.plan_of(best_cfg or {})
    print(f"\nbest replayed p99: {best_y:.1f} ms wall")
    print(f"  plan: slots={plan.num_slots} admit={plan.admit_chunk} "
          f"cache={plan.cache_len} interleave={plan.interleave}")


if __name__ == "__main__":
    main()
