"""Quickstart: build a tiny LM, train it a little on the synthetic Markov
stream, and generate from it — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import (ModelConfig, RunConfig, TrainConfig, build_model,
                   make_optimizer, make_train_step)
from repro.data import make_data
from repro.train.serve_step import generate
from repro.train.train_step import init_train_state
from repro.utils.config import MeshConfig, ShapeConfig


def main():
    cfg = ModelConfig(name="quickstart-20m", num_layers=4, d_model=256,
                      num_heads=8, num_kv_heads=4, d_ff=1024,
                      vocab_size=512, dtype="float32")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", seq_len=128, global_batch=16, kind="train"),
        mesh=MeshConfig(shape=(1,), axes=("data",)),
        train=TrainConfig(lr=1e-3, warmup_steps=20, total_steps=200),
    )
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    model = build_model(cfg, run.parallel)
    optimizer = make_optimizer(run.train)
    train_step = jax.jit(make_train_step(model, run, optimizer))
    state = init_train_state(model, run, optimizer, jax.random.PRNGKey(0))
    data = make_data(cfg, run.shape, seed=0)

    for step in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state, metrics = train_step(state, batch)
        if step % 10 == 0:
            print(f"step {step:3d}  loss {float(metrics['loss']):.3f}  "
                  f"acc {float(metrics['accuracy']):.3f}")

    prompt = jnp.asarray(data.batch_at(999)["inputs"][:2, :16])
    out = generate(model, run, state.params, {"tokens": prompt}, num_steps=12)
    print("generated continuation tokens:\n", out)


if __name__ == "__main__":
    main()
