"""Trace a tuned sim-to-real replay run end to end with the obs subsystem.

Runs the minimal sim-to-real loop (tune in the simulator, spend the budget
on real replays) with request-lifecycle tracing enabled, exports a Chrome
trace-event JSON you can open in chrome://tracing or Perfetto, then
replays the *winning* configuration once more under a fresh tracer and
prints its queue / prefill / decode time breakdown plus the tuner-round
trajectory.

    PYTHONPATH=src python examples/observability.py
    PYTHONPATH=src python examples/observability.py \
        --trace-out /tmp/tuned_replay_trace.json --budget 4

Inspect the exported file with the report CLI:

    PYTHONPATH=src python -m repro.obs.report /tmp/tuned_replay_trace.json
"""

import argparse

from repro.envs.replay_env import make_sim2real_pair
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.tuner.runner import transfer_tune

DEFAULT_WORKLOAD = ("poisson:rate=1500,horizon=0.004,mean_prompt=6,"
                    "mean_output=4,max_len=16")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=DEFAULT_WORKLOAD)
    ap.add_argument("--budget", type=int, default=3,
                    help="real-replay intervention budget")
    ap.add_argument("--n-source", type=int, default=24,
                    help="cheap simulator observations")
    ap.add_argument("--trace-out", default="/tmp/tuned_replay_trace.json",
                    help="Chrome trace-event JSON for the full tuned run")
    args = ap.parse_args()

    src, tgt = make_sim2real_pair(args.workload, seed=0, repeats=1)
    print(f"trace: {len(tgt.trace)} requests ({tgt.workload_spec})")

    # 1. the full tuned run, traced end to end: simulator observations,
    #    tuner ask/tell rounds, warmup, and every real replay lifecycle
    with obs_trace.trace_to(args.trace_out):
        res = transfer_tune("cameo", src, tgt, budget=args.budget,
                            n_source=args.n_source, n_target_init=2,
                            query_text=tgt.query_text, seed=0)
        tuner_rounds = list(obs_trace.active().tuner_rounds)
    print(f"\ntuned: best replayed p99={res.best_y:.1f} ms wall "
          f"({res.wall_s:.1f}s); full trace -> {args.trace_out}")

    print(f"\ntuner trajectory ({len(tuner_rounds)} events):")
    for ev in tuner_rounds:
        kind = ev.get("kind")
        rnd = ev.get("round")
        if kind == "ask":
            print(f"  round {rnd}: ask k={ev.get('k')} "
                  f"eps={ev.get('eps')} kinds={ev.get('kinds')} "
                  f"candidates={ev.get('n_candidates')}")
        else:
            by = ev.get("best_y")
            print(f"  round {rnd}: tell told={ev.get('told')} "
                  f"best_y={f'{by:.1f}' if by is not None else 'n/a'} "
                  f"graph_refreshed={ev.get('graph_refreshed')}")

    # 2. replay ONLY the winning configuration under a fresh tracer and
    #    break its wall time down by lifecycle stage
    winner = res.best_config or tgt.space.default_config()
    tracer = obs_trace.start()
    try:
        _, y_win = tgt.intervene(winner)
    finally:
        events = tracer.events()
        obs_trace.stop()
    stats = obs_report.span_stats(events)
    print(f"\nwinning config replayed at {y_win:.1f} ms wall; "
          f"lifecycle breakdown:")
    for name in ("queue", "prefill", "prefill_chunk", "decode_tick"):
        s = stats.get(name)
        if s is None:
            continue
        print(f"  {name:14s} n={s['count']:4d} total={s['total_us']/1e3:9.2f} ms "
              f"mean={s['mean_us']/1e3:7.3f} ms max={s['max_us']/1e3:7.3f} ms")
    lats = obs_report.request_latencies(events)
    if lats:
        lat_ms = sorted(v / 1e3 for v in lats.values())
        print(f"  {len(lat_ms)} completed requests, "
              f"p50={lat_ms[len(lat_ms) // 2]:.2f} ms "
              f"max={lat_ms[-1]:.2f} ms")


if __name__ == "__main__":
    main()
