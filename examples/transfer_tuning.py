"""CAMEO tuning the framework itself — the paper's technique as a
first-class feature.

Source environment: the cheap analytic TPU model (staging).
Target environment: either another analytic environment (default; runs in
seconds) or the real compiled dry-run backend (--compiled; each intervention
lowers + compiles the actual step for the production mesh, ~10-60 s each).

    PYTHONPATH=src python examples/transfer_tuning.py
    PYTHONPATH=src python examples/transfer_tuning.py --change topology
    PYTHONPATH=src python examples/transfer_tuning.py \
        --compiled --arch llama3.2-1b --shape train_4k --budget 8
"""

import argparse

import numpy as np

from repro.envs.analytic import environment_pair
from repro.tuner.runner import transfer_tune


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--change", default="hardware",
                    choices=["hardware", "workload", "software", "topology",
                             "severe"])
    ap.add_argument("--budget", type=int, default=40)
    ap.add_argument("--methods", default="cameo,restune,smac")
    ap.add_argument("--compiled", action="store_true",
                    help="tune the real compiled dry-run backend")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    if args.compiled:
        from repro.tuner.compiled_env import CompiledPerfEnv, make_aligned_source

        src = make_aligned_source(args.arch, seed=0)
        tgt = CompiledPerfEnv(args.arch, args.shape)
        print(f"target: compiled {args.arch} x {args.shape} "
              f"(each intervention = one XLA compile)")
    else:
        src, tgt = environment_pair(args.change, seed=0)
        print(f"environment change: {args.change}")

    for method in args.methods.split(","):
        res = transfer_tune(method, src, tgt, budget=args.budget,
                            n_source=300, seed=0)
        print(f"\n[{method}] best objective: {res.best_y:.5g} "
              f"({res.wall_s:.1f}s)")
        print(f"  config: {res.best_config}")
        if res.extras:
            print(f"  reduced space: {res.extras.get('reduced_space')}")


if __name__ == "__main__":
    main()
