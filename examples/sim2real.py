"""Tune in the simulator, evaluate in the real batcher — the sim-to-real
serving loop, minimal.

Source environment: the deterministic continuous-batching simulator pricing
a pinned request trace through the analytic kernel-cost model (cheap staging
measurements, microseconds of modeled time).  Target environment: the SAME
trace replayed through the real ``ContinuousBatcher`` — actual jitted
prefill/decode steps on a tiny model — measured in wall-clock milliseconds.
CAMEO extracts its causal model from simulator observations and spends its
small intervention budget on real replays; the tuned plan is then compared
against the default deployment *in the replay environment*, which is the
only comparison that counts.

    PYTHONPATH=src python examples/sim2real.py
    PYTHONPATH=src python examples/sim2real.py \
        --workload "bursty:rate=1500,burst=6,horizon=0.004" --budget 6
"""

import argparse

from repro.envs.replay_env import ReplayServingEnv, make_sim2real_pair
from repro.tuner.runner import transfer_tune

DEFAULT_WORKLOAD = ("poisson:rate=1500,horizon=0.004,mean_prompt=6,"
                    "mean_output=4,max_len=16")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default=DEFAULT_WORKLOAD)
    ap.add_argument("--budget", type=int, default=4,
                    help="real-replay intervention budget")
    ap.add_argument("--n-source", type=int, default=32,
                    help="cheap simulator observations")
    args = ap.parse_args()

    src, tgt = make_sim2real_pair(args.workload, seed=0, repeats=3)
    print(f"trace: {len(tgt.trace)} requests ({tgt.workload_spec})")
    print(f"space: {len(tgt.space.names)} options (identical in sim and "
          f"replay)")

    default = tgt.space.default_config()
    sim_pred = src.simulate(default)
    _, y_default = tgt.intervene(default)
    print(f"\ndefault plan: sim-predicted p99={sim_pred.p99_latency_us:.0f} "
          f"us modeled, replayed-actual p99={y_default:.1f} ms wall")

    res = transfer_tune("cameo", src, tgt, budget=args.budget,
                        n_source=args.n_source, n_target_init=2,
                        query_text=tgt.query_text, seed=0)
    plan = ReplayServingEnv.plan_of(res.best_config or {})
    tuned_pred = src.simulate(res.best_config or {})
    print(f"\ntuned plan: sim-predicted p99={tuned_pred.p99_latency_us:.0f} "
          f"us modeled, replayed-actual p99={res.best_y:.1f} ms wall "
          f"({res.wall_s:.1f}s)")
    print(f"  plan: slots={plan.num_slots} admit={plan.admit_chunk} "
          f"cache={plan.cache_len} interleave={plan.interleave}")
    print(f"  launch: {res.launch_config}")
    verdict = "beats" if res.best_y < y_default else "does not beat"
    print(f"\ntuned {verdict} the default deployment in the replay "
          f"environment ({res.best_y:.1f} vs {y_default:.1f} ms)")


if __name__ == "__main__":
    main()
