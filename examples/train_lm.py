"""End-to-end training driver: a ~100M-parameter llama-style model trained
for a few hundred steps on the synthetic Markov stream through the full
production stack — data pipeline, fault-tolerant driver, async atomic
checkpointing, restart-and-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 300 --inject-fault 120

The second form kills the step at 120 and demonstrates that the driver
restores from the latest checkpoint and continues to an identical loss
trajectory.
"""

import argparse
import shutil

import jax

from repro import ModelConfig, RunConfig, TrainConfig, build_model
from repro.checkpoint import CheckpointManager
from repro.data import make_data
from repro.runtime import FaultInjector, TrainDriver
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_train_state, make_train_step
from repro.utils.config import MeshConfig, ShapeConfig
from repro.utils.logging import MetricsLogger


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-fault", type=int, default=0,
                    help="inject a crash at this step to demo restart")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = ModelConfig(
        name="llama-110m", family="dense", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=8192,
        rope_theta=10000.0, dtype="float32")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                          kind="train"),
        mesh=MeshConfig(shape=(1,), axes=("data",)),
        train=TrainConfig(lr=6e-4, warmup_steps=50, total_steps=args.steps),
        checkpoint_dir=args.ckpt_dir, checkpoint_every=50, log_every=10,
    )
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.0f}M params), "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    model = build_model(cfg, run.parallel)
    optimizer = make_optimizer(run.train)
    train_step = jax.jit(make_train_step(model, run, optimizer))

    def init_state():
        return init_train_state(model, run, optimizer, jax.random.PRNGKey(0))

    with MetricsLogger(path=f"{args.ckpt_dir}/metrics.jsonl",
                       name="train_lm") as logger:
        driver = TrainDriver(
            run, train_step, init_state,
            make_data(cfg, run.shape, seed=0),
            CheckpointManager(args.ckpt_dir, keep=run.keep_checkpoints),
            logger=logger,
            fault_injector=(FaultInjector([args.inject_fault])
                            if args.inject_fault else None),
        )
        state = driver.run_steps(args.steps)
    print(f"done at step {int(state.step)}; restarts: {driver.restarts}")


if __name__ == "__main__":
    main()
