"""Fig 5: Markov-blanket pruning of the source graph reaches the optimum
faster than reusing the full graph (the Unicorn-style wholesale transfer)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.cameo import Cameo
from repro.core.query import parse_query
from repro.envs.analytic import environment_pair


def _re_at(trace, target, it):
    ys = [y for y in trace[:it] if np.isfinite(y)]
    if not ys:
        return 1000.0
    return abs(min(ys) - target) / target * 100.0


def main(fast: bool = True):
    t0 = time.perf_counter()
    budget = 30 if fast else 60
    src, tgt = environment_pair("hardware", seed=0)
    d_s = src.dataset(200 if fast else 500, seed=1)
    _, y_opt = tgt.optimum(2048)
    q = parse_query(f"minimize step_time within {budget} samples")

    results = {}
    for label, kwargs in [("with Mb pruning", {}),
                          ("without pruning (full space)", {"k": 10 ** 6})]:
        res = []
        for seed in [0, 1, 2]:
            cam = Cameo(src.space, q, d_s, counter_names=src.counter_names,
                        seed=seed, **kwargs)
            if kwargs:
                cam.reduced_names = list(src.space.names)  # no reduction
            cam.seed_target(tgt.dataset(5, seed=seed + 2))
            cam.run(tgt, budget)
            res.append(_re_at(cam.trace.best_y, y_opt, budget // 2))
        results[label] = float(np.mean(res))

    print("\n== Fig 5: RE%% at half budget (early efficiency) ==")
    for k, v in results.items():
        print(f"  {k:32s} RE%={v:.2f}")
    pruned = results["with Mb pruning"]
    full = results["without pruning (full space)"]
    us = (time.perf_counter() - t0) * 1e6
    return [("fig5_mb_pruning", us,
             f"pruned_re={pruned:.1f}%,full_re={full:.1f}%,"
             f"gain={full / max(pruned, 1e-9):.2f}x")]


if __name__ == "__main__":
    main(fast=False)
