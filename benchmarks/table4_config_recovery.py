"""Table 4 + Fig 12: does each method recover the optimal option VALUES, and
how close does the evolving causal model get to the ground-truth structure
(Hamming distance over iterations)?"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import run_method
from repro.core.cameo import Cameo
from repro.core.discovery import DIRECTED, CausalGraph, fci_lite
from repro.core.query import parse_query
from repro.core.baselines import make_baseline
from repro.envs.analytic import environment_pair


def _true_graph(space, counter_names):
    """Ground-truth causal structure of the analytic model: every option
    influences the three roofline counters it enters; counters drive the
    objective."""
    names = list(space.names) + list(counter_names) + ["__objective__"]
    g = CausalGraph(names)
    influences = {
        "tp": ["flops_per_chip", "collective_bytes", "compute_s",
               "collective_s"],
        "microbatch": ["collective_s"],
        "remat": ["flops_per_chip", "hbm_bytes", "compute_s", "memory_s"],
        "seq_parallel": ["hbm_bytes", "collective_bytes", "memory_s",
                         "collective_s"],
        "grad_compression": ["collective_bytes", "collective_s"],
        "attn_kv_block": ["hbm_bytes", "memory_s"],
        "collective_overlap": ["collective_s"],
        "compute_dtype": ["flops_per_chip", "hbm_bytes", "compute_s",
                          "memory_s", "energy"],
    }
    for opt, targets in influences.items():
        if opt in names:
            for t in targets:
                if t in names:
                    g.add_edge(opt, t, DIRECTED)
    for c in ("compute_s", "memory_s", "collective_s"):
        if c in names:
            g.add_edge(c, "__objective__", DIRECTED)
    return g


def main(fast: bool = True):
    t0 = time.perf_counter()
    budget = 30 if fast else 60
    src, tgt = environment_pair("hardware", seed=0)
    opt_cfg, opt_y = tgt.optimum(4096)

    print("\n== Table 4: optimal-option recovery ==")
    print(f"  ground truth: {opt_cfg}  (step={opt_y:.4f})")
    recover = {}
    for m in ["smac", "unicorn", "restune", "cameo"]:
        d_s = src.dataset(200 if fast else 500, seed=1)
        if m == "cameo":
            q = parse_query(f"minimize step_time within {budget} samples")
            cam = Cameo(src.space, q, d_s, counter_names=src.counter_names,
                        seed=0)
            cam.seed_target(tgt.dataset(5, seed=2))
            cfg, _ = cam.run(tgt, budget)
        else:
            tun = make_baseline(m, tgt.space, d_s,
                                counter_names=src.counter_names, seed=0)
            cfg, _ = tun.run(tgt, budget)
        match = sum(cfg.get(k) == v for k, v in opt_cfg.items())
        recover[m] = match
        print(f"  {m:10s} matched {match}/{len(opt_cfg)} options: {cfg}")

    # Fig 12: Hamming distance of discovered graphs to the ground truth
    print("\n== Fig 12: structural distance to the true causal model ==")
    true_g = _true_graph(tgt.space, tgt.counter_names)
    d_s = src.dataset(300, seed=1)
    data_s, names_s = d_s.matrix(src.space, list(src.counter_names))
    g_s = fci_lite(data_s, names_s, max_cond=1)
    d_t = tgt.dataset(40, seed=3)
    data_t, names_t = d_t.matrix(tgt.space, list(tgt.counter_names))
    g_t = fci_lite(data_t, names_t, max_cond=1)
    combined = g_s.copy()
    for a, b, k in g_t.edge_list():
        if not combined.has_edge(a, b):
            combined.add_edge(a, b, k)
    rows = [("G_s only", g_s.shd(true_g)),
            ("G_t only (40 samples)", g_t.shd(true_g)),
            ("combined", combined.shd(true_g))]
    for name, s in rows:
        print(f"  {name:24s} SHD={s}")
    us = (time.perf_counter() - t0) * 1e6
    return [("table4_config_recovery", us,
             f"cameo_matched={recover['cameo']},shd_combined={rows[2][1]}")]


if __name__ == "__main__":
    main(fast=False)
