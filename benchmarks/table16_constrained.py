"""Table 16 (appendix): constrained optimization — step time under an energy
budget and energy under a step-time budget, CAMEO vs CELLO (the only
baseline with constraint support)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.cameo import Cameo
from repro.core.query import Query
from repro.core.baselines import Cello
from repro.envs.analytic import environment_pair


def _constrained_optimum(env, objective, c_metric, c_val, n=1500):
    rng = np.random.default_rng(7)
    best = np.inf
    for cfg in env.space.sample(rng, n):
        counters, y = env.intervene(cfg)
        if not np.isfinite(y):
            continue
        val = counters[c_metric] if c_metric != "step_time" else y
        obj = counters[c_metric := c_metric] if False else (
            counters["energy"] if objective == "energy" else y)
        if val < c_val and obj < best:
            best = obj
    return float(best)


def main(fast: bool = True):
    t0 = time.perf_counter()
    budget = 25 if fast else 50
    results = []
    for objective, c_metric in [("step_time", "energy"),
                                ("energy", "step_time")]:
        src, tgt = environment_pair("hardware", seed=0)
        src.objective = tgt.objective = objective

        # constraint at the 45th percentile of the constrained metric
        rng = np.random.default_rng(11)
        vals = []
        for cfg in tgt.space.sample(rng, 200):
            counters, y = tgt.intervene(cfg)
            if np.isfinite(y):
                vals.append(counters[c_metric] if c_metric != "step_time"
                            else counters["compute_s"] + counters["memory_s"]
                            + counters["collective_s"])
        c_val = float(np.percentile(vals, 45))

        q = Query(objective=objective,
                  constraints=[(c_metric, "<", c_val)])
        d_s = src.dataset(200 if fast else 500, seed=1)

        cam = Cameo(src.space, q, d_s, counter_names=src.counter_names,
                    seed=0)
        cam.seed_target(tgt.dataset(5, seed=2))
        _, y_cameo = cam.run(tgt, budget)

        cello = Cello(tgt.space, seed=0)
        # constraint handling for the baseline: wrap the env
        class _ConstrainedEnv:
            space = tgt.space

            def intervene(self, cfg):
                counters, y = tgt.intervene(cfg)
                metrics = dict(counters)
                metrics["step_time"] = y if objective == "step_time" else \
                    counters["compute_s"] + counters["memory_s"] + counters["collective_s"]
                val = metrics[c_metric]
                if val >= c_val:
                    return counters, float("inf")
                return counters, y

        _, y_cello = cello.run(_ConstrainedEnv(), budget)
        print(f"\n== Table 16: minimize {objective} s.t. {c_metric} < "
              f"{c_val:.3g} ==")
        print(f"  cameo  best={y_cameo:.4g}")
        print(f"  cello  best={y_cello:.4g}")
        results.append((objective, y_cameo, y_cello))
    us = (time.perf_counter() - t0) * 1e6
    summary = ",".join(f"{o}:cameo={c:.3g}/cello={l:.3g}"
                       for o, c, l in results)
    return [("table16_constrained", us, summary)]


if __name__ == "__main__":
    main(fast=False)
