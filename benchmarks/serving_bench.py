"""Serving-workload transfer benchmark CLI -> BENCH_serving.json.

Sweeps (served-model cell x target workload trace x method) over the full
serving stack — scheduler knobs + kernel launch geometry — with the
environment change being a workload swap: a calm Poisson source trace vs a
bursty / heavy-tailed / diurnal target (see ``repro.tuner.bench.
run_serving_bench`` and the ``repro.workloads`` registry).

    PYTHONPATH=src python benchmarks/serving_bench.py --smoke
    PYTHONPATH=src python benchmarks/serving_bench.py \
        --targets "bursty:rate=3000,burst=8;heavy_tail:rate=2000" \
        --methods cameo,random,smac --budget 20

(``--targets`` is ``;``-separated — workload specs use commas for their own
parameters.)

``--smoke`` is the CI configuration: small budget, the default target
traces, cameo vs random, exits non-zero when the gate fails (CAMEO's mean
final regret worse than random search).  See ``benchmarks/README.md`` for
the JSON layout.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import trace as obs_trace
from repro.tuner.bench import (
    DEFAULT_METHODS, DEFAULT_SERVING_CELLS, DEFAULT_TARGET_TRACES,
    run_serving_bench, serving_cell_by_name)
from repro.workloads import workload_kinds


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small-budget CI sweep; non-zero exit on gate fail")
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--n-source", type=int, default=None)
    ap.add_argument("--n-target-init", type=int, default=None)
    ap.add_argument("--pool", type=int, default=None,
                    help="ground-truth pool size per (cell, target)")
    ap.add_argument("--seeds", default=None, help="comma-separated ints")
    ap.add_argument("--cells", default=None,
                    help=f"comma-separated subset of "
                         f"{[c.name for c in DEFAULT_SERVING_CELLS]}")
    ap.add_argument("--targets", default=None,
                    help=f"semicolon-separated workload specs — specs use "
                         f"commas for parameters (registered kinds: "
                         f"{list(workload_kinds())})")
    ap.add_argument("--methods", default=None,
                    help="comma-separated tuner names (cameo, random, smac, "
                         "restune, restune-w/o-ml, cello, unicorn)")
    ap.add_argument("--query-batch", type=int, default=1,
                    help="measurements per ask/tell round (1 = the "
                         "historical sequential loop)")
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="tune the paged-KV surface (pages.* + "
                         "paged_attention launch knobs) alongside serving.*")
    ap.add_argument("--trace-out", default=None,
                    help="export a Chrome trace-event JSON of the sweep "
                         "(simulated request lifecycle, tuner rounds) — "
                         "inspect with `python -m repro.obs.report PATH`")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    if args.smoke:
        budget, n_source, n_target_init = 8, 40, 4
        pool, seeds = 128, (0, 1, 2)
        targets, methods = DEFAULT_TARGET_TRACES, DEFAULT_METHODS
    else:
        budget, n_source, n_target_init = 20, 96, 4
        pool, seeds = 256, (0, 1, 2, 3)
        targets = DEFAULT_TARGET_TRACES
        methods = ("cameo", "random", "smac", "restune")
    cells = DEFAULT_SERVING_CELLS
    if args.budget is not None:
        budget = args.budget
    if args.n_source is not None:
        n_source = args.n_source
    if args.n_target_init is not None:
        n_target_init = args.n_target_init
    if args.pool is not None:
        pool = args.pool
    if args.seeds:
        seeds = tuple(int(s) for s in args.seeds.split(","))
    if args.cells:
        cells = tuple(serving_cell_by_name(n) for n in args.cells.split(","))
    if args.targets:
        targets = tuple(filter(None, (s.strip()
                                      for s in args.targets.split(";"))))
    if args.methods:
        methods = tuple(args.methods.split(","))

    if args.trace_out:
        with obs_trace.trace_to(args.trace_out):
            doc = run_serving_bench(cells=cells, targets=targets,
                                    methods=methods, budget=budget,
                                    n_source=n_source,
                                    n_target_init=n_target_init, seeds=seeds,
                                    pool=pool, query_batch=args.query_batch,
                                    paged=args.paged)
        print(f"[serving_bench] wrote trace {args.trace_out}")
    else:
        doc = run_serving_bench(cells=cells, targets=targets, methods=methods,
                                budget=budget, n_source=n_source,
                                n_target_init=n_target_init, seeds=seeds,
                                pool=pool, query_batch=args.query_batch,
                                paged=args.paged)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)

    for cell in doc["cells"]:
        dflt = cell["y_default"]
        dflt_s = f"{dflt:.0f}" if dflt is not None else "infeasible"
        print(f"\n== {cell['cell']} / {cell['source']} -> {cell['target']} "
              f"(y_opt={cell['y_opt']:.0f} us, default={dflt_s}) ==")
        ranked = sorted(cell["methods"].items(),
                        key=lambda kv: kv[1]["mean_final_regret"])
        for method, stats in ranked:
            print(f"  {method:16s} mean final regret = "
                  f"{stats['mean_final_regret']*100:7.2f}%")
            best = min(stats["runs"], key=lambda r: r["final_regret"])
            cfg = best.get("best_config") or {}
            paged_knobs = {k: v for k, v in cfg.items()
                           if k.startswith(("pages.", "paged_attention."))}
            if paged_knobs:
                knobs = ", ".join(f"{k.split('.', 1)[1]}={v}"
                                  for k, v in sorted(paged_knobs.items()))
                print(f"  {'':16s} best paged config: {knobs}")
    gate = doc["gate"]
    print(f"\n[serving_bench] wrote {args.out} "
          f"({doc['meta']['wall_s']:.1f}s)")
    if gate["checked"]:
        print(f"[serving_bench] gate: {gate['champion']}="
              f"{gate['champion_mean_final_regret']*100:.2f}% vs "
              f"{gate['reference']}="
              f"{gate['reference_mean_final_regret']*100:.2f}% -> "
              f"{'PASS' if gate['passed'] else 'FAIL'}")
    if args.smoke and not gate["passed"]:
        print("[serving_bench] FAIL: champion regret exceeds reference",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
