"""Table 2: ML regressors (GPR, RFR) vs the causal regressor (CGPR) under
environment shift — prediction error in the target after training on the
source, plus the KL divergence between the environments' objective
distributions."""

from __future__ import annotations

import time

import numpy as np

from repro.core.cgp import CausalGP
from repro.core.discovery import fci_lite
from repro.core.ace import rank_by_ace
from repro.core.forest import RandomForest
from repro.core.gp import fit_gp, gp_predict
from repro.core.markov_blanket import top_k_blanket
from repro.envs.sandbox import make_sandbox_pair


def _kl(p_samples, q_samples, bins=24):
    lo = min(p_samples.min(), q_samples.min())
    hi = max(p_samples.max(), q_samples.max())
    p, _ = np.histogram(p_samples, bins=bins, range=(lo, hi), density=False)
    q, _ = np.histogram(q_samples, bins=bins, range=(lo, hi), density=False)
    p = (p + 1e-6) / p.sum()
    q = (q + 1e-6) / q.sum()
    return float(np.sum(p * np.log(p / q)))


def _mape(pred, y):
    return float(np.mean(np.abs(pred - y) / np.maximum(np.abs(y), 1e-9))) * 100


def main(fast: bool = True):
    t0 = time.perf_counter()
    src, tgt = make_sandbox_pair(0)
    n = 300 if fast else 1000
    d_s = src.dataset(n, seed=1)
    d_t = tgt.dataset(n // 2, seed=2)

    # ML regressors see configs AND system events (the paper's setting) —
    # this is where the spurious IPC feature poisons them across the shift
    def feats(env, d):
        x = np.stack([env.space.encode(c) for c in d.configs])
        c = np.asarray([[cnt[n] for n in env.counter_names]
                        for cnt in d.counters])
        c = (c - c.mean(0)) / (c.std(0) + 1e-9)
        return np.concatenate([x, c], axis=1)

    xs, ys = feats(src, d_s), np.asarray(d_s.ys)
    xt, yt = feats(tgt, d_t), np.asarray(d_t.ys)

    # plain GP + RF trained on source, tested on target
    gp = fit_gp(xs, ys)
    mu_gp, _ = gp_predict(gp, xt)
    rf = RandomForest(seed=0).fit(xs, ys)
    mu_rf, _ = rf.predict(xt)

    # CGPR: causal-feature-restricted GP (the invariant mechanism)
    data, names = d_s.matrix(src.space, src.counter_names)
    g = fci_lite(data, names)
    ranked = [(nm, v) for nm, v in rank_by_ace(data, names, "__objective__", g)
              if nm in src.space.by_name]
    mb = top_k_blanket(g, ranked, 2, "__objective__", data=data, names=names)
    feats = [nm for nm in src.space.names if nm in mb] or \
        [nm for nm, _ in ranked[:2]]
    cgp = CausalGP(src.space, feats).fit(d_s.configs, ys)
    mu_cgp, _ = cgp.predict(d_t.configs)

    kl = _kl(ys, yt)
    rows = [("GPR", _mape(np.asarray(mu_gp), yt)),
            ("RFR", _mape(mu_rf, yt)),
            ("CGPR", _mape(mu_cgp, yt))]
    print("\n== Table 2: source->target generalization error ==")
    print(f"  KL(source || target objective) = {kl:.1f}")
    for name, err in rows:
        print(f"  {name:5s} prediction error = {err:6.2f}%")
    errs = dict(rows)
    assert errs["CGPR"] <= min(errs["GPR"], errs["RFR"]) * 1.05, \
        "causal regressor should generalize at least as well"
    us = (time.perf_counter() - t0) * 1e6
    return [("table2_generalization", us,
             f"cgpr={errs['CGPR']:.1f}%,gpr={errs['GPR']:.1f}%,"
             f"rfr={errs['RFR']:.1f}%,kl={kl:.1f}")]


if __name__ == "__main__":
    main(fast=False)
