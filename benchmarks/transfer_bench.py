"""Environment-shift transfer benchmark CLI -> BENCH_transfer.json.

Sweeps (workload cell x shift kind x method) under a fixed intervention
budget against shifted analytic targets (see ``repro.tuner.bench``) and
writes regret-vs-round trajectories plus the CI gate verdict.

    PYTHONPATH=src python benchmarks/transfer_bench.py --smoke
    PYTHONPATH=src python benchmarks/transfer_bench.py \
        --shifts hardware,severe --methods cameo,random,smac --budget 30

``--smoke`` is the CI configuration: small budget, 3 shift kinds, cameo vs
random, exits non-zero when the gate fails (CAMEO's mean final regret worse
than random search).  See ``benchmarks/README.md`` for the JSON layout.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.envs.measure import shift_kinds
from repro.tuner.bench import (
    DEFAULT_CELLS, DEFAULT_METHODS, DEFAULT_SHIFTS, cell_by_name,
    run_transfer_bench)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small-budget CI sweep; non-zero exit on gate fail")
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--n-source", type=int, default=None)
    ap.add_argument("--n-target-init", type=int, default=None)
    ap.add_argument("--pool", type=int, default=None,
                    help="ground-truth pool size per (cell, shift)")
    ap.add_argument("--seeds", default=None, help="comma-separated ints")
    ap.add_argument("--cells", default=None,
                    help=f"comma-separated subset of "
                         f"{[c.name for c in DEFAULT_CELLS]}")
    ap.add_argument("--shifts", default=None,
                    help=f"comma-separated subset of {list(shift_kinds())}")
    ap.add_argument("--methods", default=None,
                    help="comma-separated tuner names (cameo, random, smac, "
                         "restune, restune-w/o-ml, cello, unicorn)")
    ap.add_argument("--query-batch", type=int, default=1,
                    help="measurements per ask/tell round (1 = the "
                         "historical sequential loop)")
    ap.add_argument("--out", default="BENCH_transfer.json")
    args = ap.parse_args(argv)

    if args.smoke:
        budget, n_source, n_target_init = 8, 48, 3
        pool, seeds = 128, (0, 1)
        cells = DEFAULT_CELLS[:1]
        shifts, methods = DEFAULT_SHIFTS, DEFAULT_METHODS
    else:
        budget, n_source, n_target_init = 25, 128, 4
        pool, seeds = 512, (0, 1, 2)
        cells = DEFAULT_CELLS
        shifts, methods = tuple(shift_kinds()), ("cameo", "random", "smac",
                                                 "restune")
    if args.budget is not None:
        budget = args.budget
    if args.n_source is not None:
        n_source = args.n_source
    if args.n_target_init is not None:
        n_target_init = args.n_target_init
    if args.pool is not None:
        pool = args.pool
    if args.seeds:
        seeds = tuple(int(s) for s in args.seeds.split(","))
    if args.cells:
        cells = tuple(cell_by_name(n) for n in args.cells.split(","))
    if args.shifts:
        shifts = tuple(args.shifts.split(","))
    if args.methods:
        methods = tuple(args.methods.split(","))

    doc = run_transfer_bench(cells=cells, shifts=shifts, methods=methods,
                             budget=budget, n_source=n_source,
                             n_target_init=n_target_init, seeds=seeds,
                             pool=pool, query_batch=args.query_batch)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)

    for cell in doc["cells"]:
        print(f"\n== {cell['cell']} / {cell['shift']} "
              f"(y_opt={cell['y_opt']:.1f} us) ==")
        ranked = sorted(cell["methods"].items(),
                        key=lambda kv: kv[1]["mean_final_regret"])
        for method, stats in ranked:
            print(f"  {method:16s} mean final regret = "
                  f"{stats['mean_final_regret']*100:7.2f}%")
    gate = doc["gate"]
    print(f"\n[transfer_bench] wrote {args.out} "
          f"({doc['meta']['wall_s']:.1f}s)")
    if gate["checked"]:
        print(f"[transfer_bench] gate: {gate['champion']}="
              f"{gate['champion_mean_final_regret']*100:.2f}% vs "
              f"{gate['reference']}="
              f"{gate['reference_mean_final_regret']*100:.2f}% -> "
              f"{'PASS' if gate['passed'] else 'FAIL'}")
    if args.smoke and not gate["passed"]:
        print("[transfer_bench] FAIL: champion regret exceeds reference",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
