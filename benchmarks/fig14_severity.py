"""Fig 14: effectiveness under increasing severity of environmental change
(low = hardware only, medium = hardware+topology, high = everything),
CAMEO vs ResTune (the strongest baseline)."""

from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import ground_truth, print_table, sweep
from repro.envs.analytic import AnalyticTPUEnv, PaddedAnalyticEnv, TPUEnvSpec


def _pair(severity: str, seed=0):
    base = TPUEnvSpec()
    if severity == "low":       # hardware only
        tgt = replace(base, hardware="tpu_v4_like")
    elif severity == "medium":  # hardware + topology
        tgt = replace(base, hardware="tpu_v4_like", chips=512, cross_pod=True)
    else:                       # high: hardware + topology + workload + arch
        tgt = replace(base, arch="command-r-35b", hardware="tpu_v4_like",
                      seq_len=32768, global_batch=64, chips=512,
                      cross_pod=True)
    return (PaddedAnalyticEnv(base, 16, seed=seed),
            PaddedAnalyticEnv(tgt, 16, seed=seed + 1))


def _dataset_kl(src, tgt, n=300):
    ys = np.asarray([y for y in src.dataset(n, seed=5).ys if np.isfinite(y)])
    yt = np.asarray([y for y in tgt.dataset(n, seed=6).ys if np.isfinite(y)])
    lo, hi = min(ys.min(), yt.min()), max(ys.max(), yt.max())
    p, _ = np.histogram(ys, bins=20, range=(lo, hi))
    q, _ = np.histogram(yt, bins=20, range=(lo, hi))
    p = (p + 1e-6) / p.sum()
    q = (q + 1e-6) / q.sum()
    return float(np.sum(p * np.log(p / q)))


def main(fast: bool = True):
    t0 = time.perf_counter()
    budget = 20 if fast else 60
    seeds = [0, 1, 2]
    gains = {}
    for severity in ["low", "medium", "high"]:
        src, tgt = _pair(severity)
        kl = _dataset_kl(src, tgt)
        rows = sweep(["restune", "cameo"], src, tgt, budget=budget,
                     n_source=300 if fast else 500, seeds=seeds)
        print_table(f"Fig 14: severity={severity} (KL={kl:.1f})", rows)
        gains[severity] = (rows["restune"]["re_mean"] /
                           max(rows["cameo"]["re_mean"], 1e-9))
    us = (time.perf_counter() - t0) * 1e6
    return [("fig14_severity", us,
             ",".join(f"{k}={v:.2f}x" for k, v in gains.items()))]


if __name__ == "__main__":
    main(fast=False)
