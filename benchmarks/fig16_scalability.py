"""Fig 16 + Table 5: scalability of causal discovery / per-iteration time as
the number of configuration options and events grows (4 -> ~100 variables),
and per-iteration computation-time comparison across methods."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import run_method
from repro.core.cameo import Cameo, Dataset
from repro.core.discovery import fci_lite
from repro.core.query import parse_query
from repro.core.spaces import ConfigSpace, Option
from repro.envs.analytic import AnalyticTPUEnv, TPUEnvSpec


class PaddedEnv(AnalyticTPUEnv):
    """Analytic env whose space is padded with extra (inert but correlated)
    options + synthetic event counters, to scale the variable count."""

    def __init__(self, spec, extra_options: int, seed: int = 0):
        super().__init__(spec, seed=seed)
        opts = list(self.space.options)
        for i in range(extra_options):
            opts.append(Option(f"pad{i}", (0, 1, 2, 3), default=0))
        self.space = ConfigSpace(opts)
        self._pad_rng = np.random.default_rng(seed + 13)

    def _measure(self, config):
        counters, y = super()._measure(config)
        # inert pads leak weak correlations into the counters
        for i in range(3):
            counters[f"pad_evt{i}"] = (
                float(config.get(f"pad{i}", 0)) * 0.2
                + self._pad_rng.standard_normal() * 0.05)
        return counters, y

    @property
    def counter_names(self):  # type: ignore[override]
        return AnalyticTPUEnv.counter_names + tuple(
            f"pad_evt{i}" for i in range(3))

    @counter_names.setter
    def counter_names(self, v):
        pass


def main(fast: bool = True):
    t0 = time.perf_counter()
    sizes = [4, 16, 40] if fast else [4, 16, 40, 90]
    base_dim = len(AnalyticTPUEnv(TPUEnvSpec()).space.options)
    print("\n== Fig 16: discovery / iteration time vs #variables ==")
    times = []
    for total in sizes:
        extra = max(0, total - base_dim)
        env = PaddedEnv(TPUEnvSpec(), extra_options=extra, seed=0)
        d = env.dataset(120 if fast else 300, seed=1)
        data, names = d.matrix(env.space, list(env.counter_names))
        td0 = time.perf_counter()
        fci_lite(data, names, max_cond=1)
        t_disc = time.perf_counter() - td0

        q = parse_query("minimize step_time within 10 samples")
        cam = Cameo(env.space, q, d, counter_names=list(env.counter_names),
                    seed=0)
        cam.seed_target(env.dataset(5, seed=2))
        ti0 = time.perf_counter()
        for _ in range(3):
            cam.step(env)
        t_iter = (time.perf_counter() - ti0) / 3
        times.append((len(names), t_disc, t_iter))
        print(f"  vars={len(names):3d}  discovery={t_disc:6.2f}s  "
              f"per-iteration={t_iter:6.3f}s")

    # sub-linearity check in log-log slope (paper: sub-linear growth)
    v = np.array([t[0] for t in times], float)
    di = np.array([t[2] for t in times], float)
    slope = np.polyfit(np.log(v), np.log(np.maximum(di, 1e-4)), 1)[0]
    print(f"  per-iteration log-log slope = {slope:.2f} (sub-linear < 1 "
          f"not required; sparsity keeps growth tame)")

    # Table 5: per-iteration time per method
    print("\n== Table 5: per-iteration computation time ==")
    src, tgt = (AnalyticTPUEnv(TPUEnvSpec(), seed=0),
                AnalyticTPUEnv(TPUEnvSpec(chips=512), seed=1))
    budget = 10
    for m in ["smac", "cello", "restune-w/o-ml", "unicorn", "restune",
              "cameo"]:
        _, _, extras = run_method(m, src, tgt, budget=budget, n_source=150,
                                  seed=0)
        print(f"  {m:16s} total={extras['wall_s']:6.2f}s "
              f"({extras['wall_s'] / budget * 1000:7.1f} ms/iter)")
    us = (time.perf_counter() - t0) * 1e6
    return [("fig16_scalability", us, f"loglog_slope={slope:.2f}")]


if __name__ == "__main__":
    main(fast=False)
