"""§Roofline deliverable: the per-(arch x shape) three-term roofline table
from the dry-run artifacts (single-pod mesh, per the task spec)."""

from __future__ import annotations

import time

from repro.launch.roofline import format_table, load_records, roofline_from_record


def main(fast: bool = True):
    t0 = time.perf_counter()
    recs = load_records("*__pod.json")
    if not recs:
        print("  (no dry-run artifacts; run `python -m repro.launch.dryrun "
              "--all` first)")
        return [("roofline", 0.0, "no-artifacts")]
    rows = [roofline_from_record(r) for r in recs]
    rows.sort(key=lambda r: (r.arch, r.shape))
    print("\n== Roofline (single-pod 16x16, per-chip terms, TPU v5e) ==")
    print(format_table(rows))
    dominant = {}
    for r in rows:
        dominant[r.dominant] = dominant.get(r.dominant, 0) + 1
    us = (time.perf_counter() - t0) * 1e6
    return [("roofline", us,
             "cells=" + str(len(rows)) + ","
             + ",".join(f"{k}-bound={v}" for k, v in sorted(dominant.items())))]


if __name__ == "__main__":
    main(fast=False)
