"""Per-kernel microbenchmarks: oracle wall time on CPU (ref path, jitted)
plus the modeled TPU kernel time from the analytic VMEM-roofline of each
BlockSpec tiling.  One row per (kernel x shape) cell.

This is the kernels/ companion to the system-level roofline: it sanity-
checks that the chosen block shapes keep each kernel's working set inside
VMEM (<= ~128 MiB per core) and reports the compute/memory balance of the
tile the Pallas kernel executes.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.measure import timeit
from repro.kernels import ops
from repro.utils.hardware import TPU_V5E

VMEM = TPU_V5E.vmem_bytes


def _time(fn, *args, iters=3) -> float:
    # the shared timing harness: warmup + block_until_ready + median-of-k
    return timeit(lambda: fn(*args), warmup=1, repeats=iters).median_us


def _attn_row(b, s, hq, hkv, d, q_block, kv_block):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    fn = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=True, q_block=q_block, kv_block=kv_block))
    us = _time(fn, q, k, v)
    # per-tile VMEM: q block + kv block + acc + stats (f32)
    tile = (q_block * d + 2 * kv_block * d + q_block * d + 2 * q_block * 128) * 4
    flops = 4.0 * b * hq * s * s * d / 2  # causal half
    t_tpu = max(flops / TPU_V5E.peak_flops_bf16,
                (q.nbytes + k.nbytes + v.nbytes) * (s // q_block)
                / TPU_V5E.hbm_bandwidth)
    return (f"flash_attention/s{s}_qb{q_block}_kb{kv_block}", us,
            f"tile_vmem={tile/2**20:.1f}MiB<=128,fits={tile<=VMEM},"
            f"tpu_model_us={t_tpu*1e6:.0f}")


def _scan_row(b, l, c, n, chunk):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, l, c)).astype(np.float32))
    dt = jnp.abs(x) * 0.05
    A = -jnp.abs(jnp.asarray(rng.normal(size=(c, n)).astype(np.float32)))
    B = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))
    D = jnp.ones((c,), jnp.float32)
    fn = jax.jit(lambda *a: ops.selective_scan(*a, chunk=chunk))
    us = _time(fn, x, dt, A, B, C, D)
    tile = (chunk * 512 * 2 + 512 * n + 2 * chunk * n) * 4
    return (f"selective_scan/l{l}_chunk{chunk}", us,
            f"tile_vmem={tile/2**20:.2f}MiB,fits={tile<=VMEM}")


def _ssd_row(b, l, h, p, n, chunk):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.abs(jnp.asarray(rng.normal(size=(b, l, h)).astype(np.float32))) * 0.05
    A = -jnp.abs(jnp.asarray(rng.normal(size=(h,)).astype(np.float32)))
    B = jnp.asarray(rng.normal(size=(b, l, 1, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, l, 1, n)).astype(np.float32))
    D = jnp.ones((h,), jnp.float32)
    fn = jax.jit(lambda *a: ops.ssd(*a, chunk=chunk))
    us = _time(fn, x, dt, A, B, C, D)
    tile = (chunk * p + 2 * chunk * n + chunk * chunk + n * p) * 4
    return (f"ssd/l{l}_chunk{chunk}", us,
            f"tile_vmem={tile/2**20:.2f}MiB,fits={tile<=VMEM}")


def main(fast: bool = True) -> List[Tuple[str, float, str]]:
    rows = []
    attn_shapes = [(1, 256, 8, 2, 64, 128, 128), (1, 512, 8, 2, 64, 256, 256)]
    if not fast:
        attn_shapes.append((1, 2048, 16, 4, 64, 512, 1024))
    for shp in attn_shapes:
        rows.append(_attn_row(*shp))
    for shp in ([(1, 512, 64, 16, 128)] if fast
                else [(1, 512, 64, 16, 128), (2, 2048, 256, 16, 256)]):
        rows.append(_scan_row(*shp))
    for shp in ([(1, 256, 4, 32, 32, 64)] if fast
                else [(1, 256, 4, 32, 32, 64), (2, 1024, 8, 64, 64, 128)]):
        rows.append(_ssd_row(*shp))
    print("\n== kernel microbenchmarks (CPU oracle time + TPU tile model) ==")
    for name, us, derived in rows:
        print(f"  {name:42s} {us:9.0f} us  {derived}")
    return rows


if __name__ == "__main__":
    main(fast=False)
