"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV at the end (plus the human-readable
tables as it goes).  ``REPRO_BENCH_FULL=1`` runs paper-scale budgets/seeds.
"""

from __future__ import annotations

import os
import sys
import traceback

FAST = os.environ.get("REPRO_BENCH_FULL", "") != "1"

MODULES = [
    "benchmarks.table2_generalization",
    "benchmarks.table3_effectiveness",
    "benchmarks.table4_config_recovery",
    "benchmarks.fig5_mb_pruning",
    "benchmarks.fig14_severity",
    "benchmarks.fig15_sensitivity",
    "benchmarks.fig16_scalability",
    "benchmarks.table16_constrained",
    "benchmarks.kernels_bench",
    "benchmarks.roofline",
]


def main() -> int:
    import importlib

    rows = []
    failures = []
    for name in MODULES:
        print(f"\n######## {name} ########")
        try:
            mod = importlib.import_module(name)
            rows.extend(mod.main(fast=FAST))
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"{r[0]},{r[1]:.0f},{r[2]}")
    if failures:
        print("\nFAILURES:", failures, file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
