"""Sim-to-real serving benchmark CLI -> BENCH_sim2real.json.

Sweeps (cell x method) over the full serving stack with the environment
change being the *sim-to-real gap itself*: the source is the deterministic
continuous-batching simulator, the target is the real ``ContinuousBatcher``
replaying the identical trace realization through actual jitted
prefill/decode steps (see ``repro.envs.replay_env.make_sim2real_pair`` and
``repro.tuner.bench.run_sim2real_bench``).  Regret is measured in the
REPLAY environment (wall-clock ms), so the gate asserts causal transfer
survives deployment, not just a second simulator.

    PYTHONPATH=src python benchmarks/sim2real_bench.py --smoke
    PYTHONPATH=src python benchmarks/sim2real_bench.py \
        --workloads "poisson:rate=1500,horizon=0.004;bursty:rate=1500" \
        --methods cameo,random --budget 8

(``--workloads`` is ``;``-separated — workload specs use commas for their
own parameters; each spec becomes one cell named ``w<i>``.)

``--smoke`` is the CI configuration: small budget and pool (every target
measurement is a real replay), cameo vs random, exits non-zero when the
gate fails.  CI runs it under ``REPRO_KERNEL_MODE=pallas_interpret`` so the
replayed kernels are the real Pallas bodies.  See ``benchmarks/README.md``
for the JSON layout.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import trace as obs_trace
from repro.tuner.bench import (
    DEFAULT_METHODS, DEFAULT_SIM2REAL_CELLS, Sim2RealCell,
    run_sim2real_bench, sim2real_cell_by_name)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small-budget CI sweep; non-zero exit on gate fail")
    ap.add_argument("--gate", action="store_true",
                    help="exit non-zero on gate fail even without --smoke "
                         "(the non-smoke CI configuration)")
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--n-source", type=int, default=None)
    ap.add_argument("--n-target-init", type=int, default=None)
    ap.add_argument("--pool", type=int, default=None,
                    help="ground-truth pool size per cell (each entry is a "
                         "real replay — keep it small)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="median-of-k replays per target measurement")
    ap.add_argument("--seeds", default=None, help="comma-separated ints")
    ap.add_argument("--cells", default=None,
                    help=f"comma-separated subset of "
                         f"{[c.name for c in DEFAULT_SIM2REAL_CELLS]}")
    ap.add_argument("--workloads", default=None,
                    help="semicolon-separated workload specs replacing the "
                         "default cells (specs use commas for parameters)")
    ap.add_argument("--methods", default=None,
                    help="comma-separated tuner names (cameo, random, smac, "
                         "restune, restune-w/o-ml, cello, unicorn)")
    ap.add_argument("--query-batch", type=int, default=1,
                    help="measurements per ask/tell round — replay targets "
                         "share one warmed deployment per compile key "
                         "within a round (1 = the historical sequential "
                         "loop)")
    ap.add_argument("--rounds-out", default=None,
                    help="also write a per-round timing artifact (one "
                         "record per cell x method x seed x round) to this "
                         "path")
    ap.add_argument("--trace-out", default=None,
                    help="export a Chrome trace-event JSON of the sweep "
                         "(request lifecycle, tuner rounds) — inspect with "
                         "`python -m repro.obs.report PATH`")
    ap.add_argument("--out", default="BENCH_sim2real.json")
    args = ap.parse_args(argv)

    if args.smoke:
        budget, n_source, n_target_init = 5, 32, 2
        pool, seeds, repeats = 10, (0,), 3
    else:
        budget, n_source, n_target_init = 10, 64, 3
        pool, seeds, repeats = 24, (0, 1), 3
    methods = DEFAULT_METHODS
    cells = DEFAULT_SIM2REAL_CELLS
    if args.budget is not None:
        budget = args.budget
    if args.n_source is not None:
        n_source = args.n_source
    if args.n_target_init is not None:
        n_target_init = args.n_target_init
    if args.pool is not None:
        pool = args.pool
    if args.repeats is not None:
        repeats = args.repeats
    if args.seeds:
        seeds = tuple(int(s) for s in args.seeds.split(","))
    if args.cells:
        cells = tuple(sim2real_cell_by_name(n)
                      for n in args.cells.split(","))
    if args.workloads:
        specs = tuple(filter(None, (s.strip()
                                    for s in args.workloads.split(";"))))
        cells = tuple(Sim2RealCell(f"w{i}", spec)
                      for i, spec in enumerate(specs))
    if args.methods:
        methods = tuple(args.methods.split(","))

    if args.trace_out:
        with obs_trace.trace_to(args.trace_out):
            doc = run_sim2real_bench(cells=cells, methods=methods,
                                     budget=budget, n_source=n_source,
                                     n_target_init=n_target_init,
                                     seeds=seeds, pool=pool, repeats=repeats,
                                     query_batch=args.query_batch)
        print(f"[sim2real_bench] wrote trace {args.trace_out}")
    else:
        doc = run_sim2real_bench(cells=cells, methods=methods, budget=budget,
                                 n_source=n_source,
                                 n_target_init=n_target_init, seeds=seeds,
                                 pool=pool, repeats=repeats,
                                 query_batch=args.query_batch)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)

    if args.rounds_out:
        rounds = [{"cell": cell["cell"], "method": method,
                   "seed": run["seed"], "round": i,
                   "size": rec["size"], "wall_s": rec["wall_s"]}
                  for cell in doc["cells"]
                  for method, stats in cell["methods"].items()
                  for run in stats["runs"]
                  for i, rec in enumerate(run.get("rounds") or [])]
        with open(args.rounds_out, "w") as f:
            json.dump({"query_batch": args.query_batch,
                       "rounds": rounds}, f, indent=2)
        print(f"[sim2real_bench] wrote {args.rounds_out} "
              f"({len(rounds)} round records)")

    for cell in doc["cells"]:
        dflt = cell["y_default"]
        dflt_s = f"{dflt:.1f}" if dflt is not None else "infeasible"
        print(f"\n== {cell['cell']} ({cell['workload']}) "
              f"(y_opt={cell['y_opt']:.1f} ms, default={dflt_s}) ==")
        ranked = sorted(cell["methods"].items(),
                        key=lambda kv: kv[1]["mean_final_regret"])
        for method, stats in ranked:
            print(f"  {method:16s} mean final regret = "
                  f"{stats['mean_final_regret']*100:7.2f}%")
    gate = doc["gate"]
    print(f"\n[sim2real_bench] wrote {args.out} "
          f"({doc['meta']['wall_s']:.1f}s)")
    if gate["checked"]:
        print(f"[sim2real_bench] gate: {gate['champion']}="
              f"{gate['champion_mean_final_regret']*100:.2f}% vs "
              f"{gate['reference']}="
              f"{gate['reference_mean_final_regret']*100:.2f}% -> "
              f"{'PASS' if gate['passed'] else 'FAIL'}")
    if (args.smoke or args.gate) and not gate["passed"]:
        print("[sim2real_bench] FAIL: champion regret exceeds reference",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
