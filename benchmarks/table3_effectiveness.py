"""Table 3 + Figs 8-11: effectiveness of CAMEO vs the five baselines across
the four environmental-change axes (hardware / workload / software /
deployment topology), for the latency-like (step_time) and energy
objectives — RE% against the 2000-sample ground-truth pool."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (FULL, METHODS, ground_truth, print_table,
                               sweep)
from repro.envs.analytic import environment_pair

CHANGES = ["hardware", "workload", "software", "topology"]


def main(fast: bool = True):
    t0 = time.perf_counter()
    budget = 20 if fast else 60
    n_source = 300 if fast else 500
    seeds = [0, 1, 2, 3, 4]
    summary = {m: [] for m in METHODS}

    for objective in (["step_time"] if fast else ["step_time", "energy"]):
        for change in CHANGES:
            src, tgt = environment_pair(change, seed=0)
            src.objective = tgt.objective = objective
            rows = sweep(METHODS, src, tgt, budget=budget,
                         n_source=n_source, seeds=seeds, objective=objective)
            print_table(f"Table 3 [{objective}] {change} change", rows)
            for m in METHODS:
                summary[m].append(rows[m]["re_mean"])

    print("\n== Table 3 summary (mean RE% over environmental changes) ==")
    order = sorted(METHODS, key=lambda m: np.mean(summary[m]))
    for m in order:
        print(f"  {m:16s} {np.mean(summary[m]):7.2f}%")
    cameo_re = float(np.mean(summary["cameo"]))
    best_baseline = min(float(np.mean(summary[m])) for m in METHODS
                        if m != "cameo")
    us = (time.perf_counter() - t0) * 1e6
    return [("table3_effectiveness", us,
             f"cameo={cameo_re:.1f}%,best_baseline={best_baseline:.1f}%,"
             f"gain={best_baseline / max(cameo_re, 1e-9):.2f}x")]


if __name__ == "__main__":
    main(fast=False)
