"""Fig 15: sensitivity of CAMEO to (i) the number of source samples and
(ii) the acquisition threshold l_alpha."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ground_truth, relative_error, run_method
from repro.envs.analytic import environment_pair


def main(fast: bool = True):
    t0 = time.perf_counter()
    budget = 25 if fast else 50
    src, tgt = environment_pair("hardware", seed=0)
    y_opt = ground_truth(tgt)

    print("\n== Fig 15 (left): sensitivity to n_source ==")
    ns = [30, 100, 300] if fast else [30, 100, 300, 1000, 3000]
    n_res = {}
    for n in ns:
        res = []
        for m in ["cameo", "restune"]:
            y, _, _ = run_method(m, src, tgt, budget=budget, n_source=n,
                                 seed=0)
            res.append((m, relative_error(y, y_opt)))
        n_res[n] = dict(res)
        print(f"  n_source={n:5d}  " +
              "  ".join(f"{m}={v:6.2f}%" for m, v in res))

    print("\n== Fig 15 (right): sensitivity to l_alpha ==")
    las = [0.02, 0.1, 0.4] if fast else [0.01, 0.05, 0.1, 0.2, 0.4, 0.8]
    la_res = {}
    for la in las:
        y, _, _ = run_method("cameo", src, tgt, budget=budget, n_source=300,
                             seed=0, l_alpha=la)
        la_res[la] = relative_error(y, y_opt)
        print(f"  l_alpha={la:4.2f}  cameo RE%={la_res[la]:6.2f}")

    best_la = min(la_res, key=la_res.get)
    us = (time.perf_counter() - t0) * 1e6
    return [("fig15_sensitivity", us,
             f"best_l_alpha={best_la},re={la_res[best_la]:.2f}%")]


if __name__ == "__main__":
    main(fast=False)
