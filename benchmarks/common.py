"""Shared benchmark machinery: the paper's RE% metric, method sweeps,
ground-truth pools, timing."""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cameo import Cameo, Dataset
from repro.core.baselines import make_baseline
from repro.core.query import parse_query

FULL = os.environ.get("REPRO_BENCH_FULL", "") == "1"

METHODS = ["smac", "cello", "restune-w/o-ml", "unicorn", "restune", "cameo"]


def ground_truth(env, n: int = 2000, seed: int = 99) -> float:
    """The paper's Y_opt: best measured value over a 2000-sample pool."""
    n = n if FULL else 600
    rng = np.random.default_rng(seed)
    best = np.inf
    for cfg in env.space.sample(rng, n):
        _, y = env.intervene(cfg)
        if np.isfinite(y) and y < best:
            best = y
    return float(best)


def relative_error(y: float, y_opt: float) -> float:
    if not np.isfinite(y):
        return 1000.0
    return abs(y - y_opt) / abs(y_opt) * 100.0


def run_method(method: str, source_env, target_env, *, budget: int,
               n_source: int, objective: str = "step_time", seed: int = 0,
               l_alpha: float = 0.1, n_target_init: int = 5
               ) -> Tuple[float, List[float], Dict]:
    """Returns (best_y, best-so-far trace, extras)."""
    d_s = source_env.dataset(n_source, seed=seed + 1)
    if method == "cameo":
        q = parse_query(f"minimize {objective} within {budget} samples")
        cam = Cameo(source_env.space, q, d_s,
                    counter_names=source_env.counter_names, seed=seed,
                    l_alpha=l_alpha)
        cam.seed_target(target_env.dataset(n_target_init, seed=seed + 2))
        t0 = time.perf_counter()
        _, y = cam.run(target_env, budget)
        wall = time.perf_counter() - t0
        return y, list(cam.trace.best_y), {
            "model_update_s": float(np.mean(cam.trace.model_update_s or [0])),
            "recommend_s": float(np.mean(cam.trace.recommend_s or [0])),
            "wall_s": wall, "k": cam.k}
    tuner = make_baseline(method, target_env.space, d_s,
                          counter_names=source_env.counter_names, seed=seed)
    t0 = time.perf_counter()
    _, y = tuner.run(target_env, budget)
    wall = time.perf_counter() - t0
    return y, list(tuner.trace.best_y), {"wall_s": wall}


def sweep(methods: Sequence[str], source_env, target_env, *, budget: int,
          n_source: int, seeds: Sequence[int], objective: str = "step_time",
          y_opt: Optional[float] = None) -> Dict[str, Dict]:
    """Fairness contract: every (method, seed) run gets a FRESH copy of both
    environments with an identical measurement-noise stream — the analytic
    env's noise RNG is stateful, so sharing one instance across methods
    makes results depend on run order."""
    import copy

    if y_opt is None:
        y_opt = ground_truth(copy.deepcopy(target_env))
    out = {}
    for m in methods:
        res, walls = [], []
        for s in seeds:
            src = copy.deepcopy(source_env)
            tgt = copy.deepcopy(target_env)
            for env, off in ((src, 0), (tgt, 1)):
                env._rng = np.random.default_rng(7919 * s + off)
                env._pool_rng = np.random.default_rng(104729 * s + off)
                env._pool = []
            y, _, extras = run_method(m, src, tgt,
                                      budget=budget, n_source=n_source,
                                      objective=objective, seed=s)
            res.append(relative_error(y, y_opt))
            walls.append(extras["wall_s"])
        out[m] = {"re_mean": float(np.mean(res)),
                  "re_std": float(np.std(res)),
                  "wall_s": float(np.mean(walls))}
    return out


def print_table(title: str, rows: Dict[str, Dict], key: str = "re_mean"):
    print(f"\n== {title} ==")
    for m, r in sorted(rows.items(), key=lambda kv: kv[1][key]):
        print(f"  {m:16s} RE%={r['re_mean']:7.2f} ± {r['re_std']:5.2f}  "
              f"({r['wall_s']:.1f}s)")
