"""Shared benchmark machinery: the paper's RE% metric, method sweeps,
ground-truth pools, timing."""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "") == "1"

METHODS = ["smac", "cello", "restune-w/o-ml", "unicorn", "restune", "cameo"]


def ground_truth(env, n: int = 2000, seed: int = 99) -> float:
    """The paper's Y_opt: best measured value over a 2000-sample pool."""
    n = n if FULL else 600
    rng = np.random.default_rng(seed)
    best = np.inf
    for cfg in env.space.sample(rng, n):
        _, y = env.intervene(cfg)
        if np.isfinite(y) and y < best:
            best = y
    return float(best)


def relative_error(y: float, y_opt: float) -> float:
    if not np.isfinite(y):
        return 1000.0
    return abs(y - y_opt) / abs(y_opt) * 100.0


def run_method(method: str, source_env, target_env, *, budget: int,
               n_source: int, objective: str = "step_time", seed: int = 0,
               l_alpha: float = 0.1, n_target_init: int = 5
               ) -> Tuple[float, List[float], Dict]:
    """Returns (best_y, best-so-far trace, extras).  Thin wrapper over the
    production ``transfer_tune`` so the benchmarks measure exactly the
    comparison protocol the tuner ships (identical free initial target
    dataset per method, same budget accounting)."""
    from repro.tuner.runner import transfer_tune

    res = transfer_tune(
        method, source_env, target_env, budget=budget, n_source=n_source,
        n_target_init=n_target_init, l_alpha=l_alpha, seed=seed,
        query_text=f"minimize {objective} within {{budget}} samples")
    extras = dict(res.extras)
    extras["wall_s"] = res.wall_s
    return res.best_y, res.trace_best_y, extras


def sweep(methods: Sequence[str], source_env, target_env, *, budget: int,
          n_source: int, seeds: Sequence[int], objective: str = "step_time",
          y_opt: Optional[float] = None) -> Dict[str, Dict]:
    """Fairness contract: every (method, seed) run gets a FRESH copy of both
    environments with an identical measurement-noise stream — the analytic
    env's noise RNG is stateful, so sharing one instance across methods
    makes results depend on run order."""
    import copy

    if y_opt is None:
        y_opt = ground_truth(copy.deepcopy(target_env))
    out = {}
    for m in methods:
        res, walls = [], []
        for s in seeds:
            src = copy.deepcopy(source_env)
            tgt = copy.deepcopy(target_env)
            for env, off in ((src, 0), (tgt, 1)):
                env._rng = np.random.default_rng(7919 * s + off)
                env._pool_rng = np.random.default_rng(104729 * s + off)
                env._pool = []
            y, _, extras = run_method(m, src, tgt,
                                      budget=budget, n_source=n_source,
                                      objective=objective, seed=s)
            res.append(relative_error(y, y_opt))
            walls.append(extras["wall_s"])
        out[m] = {"re_mean": float(np.mean(res)),
                  "re_std": float(np.std(res)),
                  "wall_s": float(np.mean(walls))}
    return out


def print_table(title: str, rows: Dict[str, Dict], key: str = "re_mean"):
    print(f"\n== {title} ==")
    for m, r in sorted(rows.items(), key=lambda kv: kv[1][key]):
        print(f"  {m:16s} RE%={r['re_mean']:7.2f} ± {r['re_std']:5.2f}  "
              f"({r['wall_s']:.1f}s)")
