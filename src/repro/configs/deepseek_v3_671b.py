"""deepseek-v3-671b — MLA + 1 shared + 256 routed top-8 MoE + MTP
[arXiv:2412.19437; hf].

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280; MLA ranks
q=1536 / kv=512, rope head 64, nope head 128, v head 128; sigmoid router.
Pure full attention -> ``long_500k`` skipped.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    moe_num_experts=256,
    moe_top_k=8,
    moe_d_ff=2048,
    moe_num_shared=1,
    moe_router="sigmoid",
    moe_capacity_factor=1.25,
    mtp_depth=1,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="deepseek-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=64, vocab_size=128, q_lora_rank=32, kv_lora_rank=16,
    qk_rope_head_dim=8, qk_nope_head_dim=8, v_head_dim=8,
    moe_num_experts=8, moe_top_k=2, moe_d_ff=64, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=16, remat="dots", microbatch=1,
                              moe_expert_axis="model")
    return ParallelConfig(fsdp=2, tp=16, moe_expert_axis="model")
