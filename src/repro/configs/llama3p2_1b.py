"""llama3.2-1b — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256, tied embeddings,
head_dim 64, rope theta 500k. Pure full attention -> ``long_500k`` skipped.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    mlp_type="swiglu",
    rope_theta=500000.0,
    tie_embeddings=True,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="llama3.2-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=8, remat="dots")
    return ParallelConfig(fsdp=2, tp=8)
