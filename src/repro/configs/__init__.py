from repro.configs.registry import (  # noqa: F401
    all_cells,
    arch_shapes,
    default_parallel,
    default_train_config,
    get_model_config,
    get_smoke_config,
    input_specs,
    list_archs,
    make_run,
    runnable_cells,
)
from repro.configs.shapes import SHAPES, SMOKE_SHAPES  # noqa: F401
