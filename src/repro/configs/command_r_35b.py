"""command-r-35b — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
Pure full attention -> ``long_500k`` skipped.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    mlp_type="swiglu",
    rope_theta=8000000.0,
    tie_embeddings=True,  # command-r ties input/output embeddings
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="command-r-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=192, vocab_size=128, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=16, remat="dots")
    return ParallelConfig(fsdp=2, tp=16)
