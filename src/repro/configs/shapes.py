"""Assigned input-shape cells (same 4-shape set for all 10 LM archs).

``train_*`` lowers train_step; ``prefill_*`` lowers the prefill serve step;
``decode_*`` / ``long_*`` lower one decode step against a cache of seq_len.
``long_500k`` requires sub-quadratic context handling — it runs for
SSM / hybrid / sliding-window archs and is a documented skip for pure
full-attention archs (see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import Dict

from repro.utils.config import ShapeConfig

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}

# reduced shapes used by smoke tests / examples
SMOKE_SHAPES: Dict[str, ShapeConfig] = {
    "train_smoke": ShapeConfig("train_smoke", seq_len=64, global_batch=4, kind="train"),
    "prefill_smoke": ShapeConfig("prefill_smoke", seq_len=64, global_batch=2, kind="prefill"),
    "decode_smoke": ShapeConfig("decode_smoke", seq_len=64, global_batch=2, kind="decode"),
}


def shape_runs_for(sub_quadratic: bool) -> Dict[str, ShapeConfig]:
    """The shape cells that actually compile for an arch family."""
    out = dict(SHAPES)
    if not sub_quadratic:
        out.pop("long_500k")
    return out
