"""llama-3.2-vision-11b — dense backbone with gated cross-attention image
layers every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  The vision
frontend is a STUB per the task spec: ``input_specs()`` provides precomputed
patch embeddings (B, 1600, 4096).  Pure full attention -> ``long_500k``
skipped.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    mlp_type="swiglu",
    rope_theta=500000.0,
    cross_attn_period=5,  # 8 cross-attention layers
    vision_seq=1600,      # patches after the (stubbed) projector
    vision_dim=4096,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="llama-vision-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=128, cross_attn_period=2,
    vision_seq=8, vision_dim=64, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=16, remat="dots")
    return ParallelConfig(fsdp=2, tp=16)
