"""whisper-large-v3 — encoder-decoder backbone; conv/mel frontend stubbed
[arXiv:2212.04356; unverified].

32L (decoder) + 32L (encoder) d_model=1280 20H (kv=20) d_ff=5120
vocab=51866; ``input_specs()`` provides precomputed frame embeddings
(B, 1500, 1280).  Decoder exists -> decode shapes run; full attention ->
``long_500k`` skipped.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_type="gelu",
    encoder_layers=32,
    encoder_seq=1500,
    tie_embeddings=True,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="whisper-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=128, encoder_layers=2,
    encoder_seq=16, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=4, remat="dots")
    return ParallelConfig(fsdp=2, tp=4)
