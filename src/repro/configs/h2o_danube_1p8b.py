"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, window 4096.
The ring KV cache is bounded by the window -> ``long_500k`` runs.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    mlp_type="swiglu",
    attn_type="swa",
    sliding_window=4096,
    rope_theta=10000.0,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="h2o-danube-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=128, sliding_window=16,
    dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=8, remat="dots",
                              attn_kv_block=512)
    return ParallelConfig(fsdp=2, tp=8)
