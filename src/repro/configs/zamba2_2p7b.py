"""zamba2-2.7b — Mamba-2 backbone with a shared attention+MLP block
[arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64.
Shared block applied every 6th layer with one set of weights (zamba2-style);
d_inner = 5120, mamba2 head_dim 64 -> 80 ssm heads. ``long_500k`` runs.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_num_heads=80,  # d_inner 5120 / head_dim 64
    ssm_chunk=256,
    hybrid_attn_period=6,  # 54 = 9 superblocks x (5 mamba2 + 1 shared-attn)
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke", num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=128, ssm_state=8, ssm_num_heads=4, ssm_chunk=16,
    hybrid_attn_period=2, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=16, remat="dots")
    return ParallelConfig(fsdp=2, tp=16)
