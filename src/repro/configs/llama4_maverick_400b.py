"""llama4-maverick-400b-a17b — interleaved dense/MoE, 128e top-1, shared
expert, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
Pure full attention -> ``long_500k`` skipped.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    mlp_type="swiglu",
    moe_num_experts=128,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_num_shared=1,
    moe_layer_period=2,  # alternating dense / MoE layers
    moe_router="softmax",
    rope_theta=500000.0,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="llama4-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=128, moe_num_experts=8,
    moe_top_k=1, moe_d_ff=128, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=16, remat="dots",
                              moe_expert_axis="model")
    return ParallelConfig(fsdp=2, tp=16, moe_expert_axis="model")
