"""falcon-mamba-7b — attention-free Mamba-1 LM [arXiv:2410.05355; unverified].

64L d_model=4096 d_ff=0 vocab=65024 ssm_state=16; d_inner = 2*4096 = 8192.
``long_500k`` runs: the recurrent state is O(1) in sequence length.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    attn_type="none",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="falcon-mamba-smoke", num_layers=2, d_model=64, vocab_size=128,
    ssm_state=4, ssm_chunk=16, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=16, remat="dots", microbatch=1,
                              scan_layers=True)
    return ParallelConfig(fsdp=2, tp=16)
