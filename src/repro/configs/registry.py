"""Architecture registry: the 10 assigned archs, shape cells, run assembly,
and ShapeDtypeStruct input factories for the dry-run.

``--arch <id>`` everywhere resolves through ``get_model_config``.
"""

from __future__ import annotations

import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, SMOKE_SHAPES, shape_runs_for
from repro.utils.config import (
    MeshConfig, ModelConfig, ParallelConfig, RunConfig, ShapeConfig,
    TrainConfig)

_ARCH_MODULES: Dict[str, str] = {
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "llama3.2-1b": "repro.configs.llama3p2_1b",
    "command-r-35b": "repro.configs.command_r_35b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1p8b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "llama-3.2-vision-11b": "repro.configs.llama3p2_vision_11b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}

# archs whose second-moment state must be factored to fit HBM at 512 chips
_ADAFACTOR_ARCHS = {"deepseek-v3-671b", "llama4-maverick-400b-a17b",
                    "command-r-35b", "nemotron-4-15b"}


def list_archs():
    return list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch])


def get_model_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def default_parallel(arch: str, kind: str) -> ParallelConfig:
    return _module(arch).default_parallel(kind)


def arch_shapes(arch: str) -> Dict[str, ShapeConfig]:
    return shape_runs_for(get_model_config(arch).sub_quadratic)


def default_train_config(arch: str) -> TrainConfig:
    opt = "adafactor" if arch in _ADAFACTOR_ARCHS else "adamw"
    return TrainConfig(optimizer=opt)


def make_run(arch: str, shape: str, *, multi_pod: bool = False,
             parallel: Optional[ParallelConfig] = None,
             train: Optional[TrainConfig] = None,
             smoke: bool = False) -> RunConfig:
    shapes = SMOKE_SHAPES if smoke else SHAPES
    if shape not in shapes:
        raise KeyError(f"unknown shape {shape!r}; known: {list(shapes)}")
    shape_cfg = shapes[shape]
    model = get_smoke_config(arch) if smoke else get_model_config(arch)
    if shape_cfg.name == "long_500k" and not model.sub_quadratic:
        raise ValueError(
            f"{arch} is pure full-attention; long_500k is a documented skip")
    mesh = (MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))
            if multi_pod else MeshConfig(shape=(16, 16), axes=("data", "model")))
    if smoke:
        mesh = MeshConfig(shape=(1,), axes=("data",))
        parallel = parallel or ParallelConfig()
    par = parallel or default_parallel(arch, shape_cfg.kind)
    tc = train or default_train_config(arch)
    run = RunConfig(model=model, shape=shape_cfg, mesh=mesh, parallel=par, train=tc)
    run.validate()
    return run


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------

def _i32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _modal_extras(cfg: ModelConfig, b: int) -> Dict[str, jax.ShapeDtypeStruct]:
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        out["vision_embeds"] = _f((b, cfg.vision_seq, cfg.vision_dim), cfg.dtype)
    if cfg.family == "audio":
        out["frames"] = _f((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return out


def input_specs(run: RunConfig) -> Dict[str, Any]:
    """Abstract inputs for the step function this shape cell lowers.

    train  -> {"batch": {inputs, targets, [modal]}}
    prefill-> {"batch": {tokens, [modal]}}
    decode -> {"state": ServeState, "tokens": (B, 1)}
    """
    cfg, shape = run.model, run.shape
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"inputs": _i32(b, s), "targets": _i32(b, s)}
        batch.update(_modal_extras(cfg, b))
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _i32(b, s)}
        batch.update(_modal_extras(cfg, b))
        return {"batch": batch}
    assert shape.kind == "decode", shape.kind
    from repro.models.model import build_model
    from repro.train.serve_step import ServeState

    model = build_model(cfg, run.parallel)
    caches = jax.eval_shape(lambda: model.init_decode_state(b, s))
    extras: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = _f((b, cfg.vision_seq, cfg.vision_dim), cfg.dtype)
    if cfg.family == "audio":
        extras["enc_out"] = _f((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    state = ServeState(caches=caches, lengths=_i32(b), extras=extras)
    return {"state": state, "tokens": _i32(b, 1)}


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """All 40 assigned (arch, shape) cells, including documented skips."""
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            cells.append((arch, shape))
    return tuple(cells)


def runnable_cells() -> Tuple[Tuple[str, str], ...]:
    """Cells that compile (excludes full-attention long_500k skips)."""
    out = []
    for arch in list_archs():
        for shape in arch_shapes(arch):
            out.append((arch, shape))
    return tuple(out)
