"""nemotron-4-15b — dense GQA with squared-ReLU MLP [arXiv:2402.16819; unverified].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
Pure full attention -> ``long_500k`` skipped.
"""

from repro.utils.config import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="relu2",
    rope_theta=10000.0,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    name="nemotron-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=256, vocab_size=128, dtype="float32",
)


def default_parallel(kind: str) -> ParallelConfig:
    if kind == "train":
        return ParallelConfig(fsdp=2, tp=16, remat="dots")
    return ParallelConfig(fsdp=2, tp=16)
