"""CAMEO — Algorithm 1 of the paper.

Knowledge extraction (offline):
  1. learn causal performance models G_s (from the source dataset D_s) and
     G_t (from m initial target samples);
  2. rank nodes by ACE on the objective in G_s; pick k at the ACE elbow;
  3. transfer the union Markov blanket of the top-k nodes -> the reduced
     space the warm CGP operates on.

Knowledge update (online active loop):
  4. CGP_warm on the reduced space (source data), CGP_cold on the full
     space (target data);
  5. each round: ε-greedy observation-vs-intervention (eq. 8); for
     interventions pick argmax of the λ-combined EI (eqs. 5-7), measure,
     apply constraint handling (infeasible -> ∞), update D_t, periodically
     refresh G_t and the CGPs.

The environment contract is ``repro.envs.base.PerfEnv``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.ace import choose_k, rank_by_ace
from repro.core.acquisition import combined_acquisition, expected_improvement
from repro.core.cgp import CausalGP
from repro.core.discovery import CausalGraph, fci_lite
from repro.core.epsilon import observation_epsilon
from repro.core.markov_blanket import top_k_blanket
from repro.core.query import Query
from repro.core.spaces import ConfigSpace
from repro.obs import trace as obs_trace


@dataclass
class Dataset:
    """Aligned configs / system-event counters / objective values."""
    configs: List[Dict[str, Any]] = field(default_factory=list)
    counters: List[Dict[str, float]] = field(default_factory=list)
    ys: List[float] = field(default_factory=list)

    def add(self, config, counters, y):
        self.configs.append(dict(config))
        self.counters.append(dict(counters or {}))
        self.ys.append(float(y))

    def __len__(self):
        return len(self.ys)

    def matrix(self, space: ConfigSpace, counter_names: Sequence[str],
               *, maximize: bool = False) -> Tuple[np.ndarray, List[str]]:
        """[options..., counters..., objective] matrix + column names.

        Infeasible measurements (±inf from constraint handling / invalid
        configurations) are clamped to a pessimistic finite value so the CI
        tests and regressions stay well-posed.  "Pessimistic" is
        direction-aware: constraint handling stores ``inf * sign``, so for a
        ``maximize`` objective the sentinel is ``-inf`` and the clamp must
        land *below* every feasible value — clamping high would turn an
        infeasible configuration into the best-looking row and poison
        discovery and the ACE ranking.
        """
        rows = []
        for cfg, cnt, y in zip(self.configs, self.counters, self.ys):
            x = space.encode(cfg)
            c = [float(cnt.get(n, 0.0)) for n in counter_names]
            rows.append(np.concatenate([x, c, [y]]))
        names = list(space.names) + list(counter_names) + ["__objective__"]
        m = np.asarray(rows, np.float64)
        obj_col = m.shape[1] - 1
        for col in range(m.shape[1]):
            v = m[:, col]
            bad = ~np.isfinite(v)
            if bad.any():
                good = v[~bad]
                margin = (2.0 * (good.max() - good.min() + 1.0)
                          if len(good) else 0.0)
                hi = good.max() + margin if len(good) else 0.0
                lo = good.min() - margin if len(good) else 0.0
                worst = lo if (maximize and col == obj_col) else hi
                m[bad, col] = worst
        return m, names


@dataclass
class CameoTrace:
    best_y: List[float] = field(default_factory=list)
    action: List[str] = field(default_factory=list)
    lam_fraction: List[float] = field(default_factory=list)
    model_update_s: List[float] = field(default_factory=list)
    recommend_s: List[float] = field(default_factory=list)
    g_t_edges: List[int] = field(default_factory=list)


@dataclass(frozen=True)
class Proposal:
    """One slot of a q-batch round.

    ``kind`` is ``"observe"`` (resolve against the environment's
    observational pool) or ``"intervene"`` (measure ``config``).  Observe
    proposals carry no config — the pool draw happens at resolution time so
    the tuner's RNG stream stays identical to the sequential loop's.
    """

    kind: str
    config: Optional[Dict[str, Any]] = None


class Cameo:
    """Causal multi-environment optimizer (Algorithm 1)."""

    def __init__(
        self,
        space: ConfigSpace,
        query: Query,
        source_data: Dataset,
        *,
        counter_names: Sequence[str] = (),
        l_alpha: float = 0.1,
        k: Optional[int] = None,
        n_max_obs: int = 50,
        candidates_per_round: int = 256,
        rediscover_every: int = 10,
        ci_alpha: float = 0.05,
        seed: int = 0,
    ):
        self.space = space
        self.query = query
        self.counter_names = list(counter_names)
        self.l_alpha = l_alpha
        self.n_max_obs = n_max_obs
        self.cand_n = candidates_per_round
        self.rediscover_every = rediscover_every
        self.ci_alpha = ci_alpha
        self.rng = np.random.default_rng(seed)
        self.trace = CameoTrace()

        self.d_s = source_data
        self.d_t = Dataset()
        self._sign = -1.0 if query.maximize else 1.0  # internal: minimize

        # -- knowledge extraction phase (offline, lines 1-3) ---------------
        # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
        t0 = time.perf_counter()
        data_s, names_s = self.d_s.matrix(space, self.counter_names,
                                          maximize=query.maximize)
        self.g_s = fci_lite(data_s, names_s, alpha=ci_alpha)
        ranked = rank_by_ace(data_s, names_s, "__objective__", self.g_s)
        # only configuration options can be intervened on
        ranked_opts = [(n, v) for n, v in ranked if n in space.by_name]
        self.k = k if k is not None else choose_k(ranked_opts)
        self.ranked = ranked_opts
        mb = top_k_blanket(self.g_s, ranked_opts, self.k, "__objective__",
                           data=data_s, names=names_s)
        self.reduced_names = [n for n in space.names
                              if n in mb or n in {x for x, _ in ranked_opts[:self.k]}]
        if not self.reduced_names:
            self.reduced_names = [n for n, _ in ranked_opts[:max(self.k, 2)]]
        self.g_t: Optional[CausalGraph] = None
        # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
        self.extraction_s = time.perf_counter() - t0

        self._warm: Optional[CausalGP] = None
        self._cold: Optional[CausalGP] = None
        self._fitted_at = -1
        self._round_idx = 0  # ask/tell rounds so far (introspection only)

    # ------------------------------------------------------------------ API

    @property
    def best(self) -> Tuple[Optional[Dict], float]:
        if not self.d_t.ys:
            return None, float("inf")
        ys = np.asarray(self.d_t.ys)
        feas = [i for i in range(len(ys))
                if np.isfinite(ys[i])]
        if not feas:
            return None, float("inf")
        i = feas[int(np.argmin(ys[feas] * self._sign))] \
            if self.query.maximize else feas[int(np.argmin(ys[feas]))]
        return self.d_t.configs[i], float(ys[i])

    def seed_target(self, dataset: Dataset) -> None:
        """Initial m target samples (D_t) — counted against nothing."""
        for c, cnt, y in zip(dataset.configs, dataset.counters, dataset.ys):
            self.d_t.add(c, cnt, y)
        self._refresh_graph_t()

    def run(self, env, budget: int, query_batch: int = 1,
            round_log: Optional[List[Dict[str, Any]]] = None
            ) -> Tuple[Dict, float]:
        """The active loop (lines 5-21). env: repro.envs.base.PerfEnv.

        ``query_batch`` restructures the budget as rounds of up to k
        measurements each: one ``ask(k)`` proposal, one (batched)
        measurement, one ``tell``.  ``query_batch=1`` reproduces the
        sequential loop exactly — same RNG stream, same trajectory.
        ``round_log``, when given, receives one ``{"size", "actions",
        "wall_s"}`` record per round."""
        share_dims = getattr(env, "batch_share_dims", None)
        spent = 0
        while spent < budget:
            k = min(max(int(query_batch), 1), budget - spent)
            # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
            t0 = time.perf_counter()
            actions = self._round(env, k, share_dims=share_dims)
            if round_log is not None:
                round_log.append({"size": len(actions),
                                  "actions": list(actions),
                                  # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
                                  "wall_s": round(time.perf_counter() - t0,
                                                  4)})
            spent += len(actions)
        cfg, y = self.best
        return cfg or self.space.default_config(), y

    # ------------------------------------------------------------ internals

    def _ys_internal(self) -> np.ndarray:
        return np.asarray(self.d_t.ys) * self._sign

    def _refresh_graph_t(self) -> None:
        if len(self.d_t) >= 8:
            data_t, names_t = self.d_t.matrix(self.space, self.counter_names,
                                              maximize=self.query.maximize)
            keep = data_t.std(axis=0) > 1e-12
            # the objective column must survive: early target rounds can have
            # identical ys (constant column), and a g_t missing its
            # __objective__ node breaks the later ACE re-ranking against it
            keep[names_t.index("__objective__")] = True
            cols = np.where(keep)[0]
            self.g_t = fci_lite(data_t[:, cols],
                                [names_t[i] for i in cols],
                                alpha=self.ci_alpha, max_cond=1)
            self.trace.g_t_edges.append(self.g_t.num_edges())

    def _fit_surrogates(self) -> None:
        ys_s = np.asarray(self.d_s.ys) * self._sign
        ys_t = self._ys_internal()
        finite_t = np.isfinite(ys_t)
        if finite_t.any():
            good = ys_t[finite_t]
            worst = float(good.max() + 0.5 * (np.ptp(good) + 1e-3))
        else:
            worst = 1.0
        ys_t = np.where(finite_t, ys_t, worst)
        self._warm = CausalGP(self.space, self.reduced_names).fit(
            self.d_s.configs, ys_s)
        # cold operates on the full space with a constant interventional
        # mean: a multivariate adjustment is unsupported at the few-sample
        # target regime and extrapolates disastrously
        self._cold = CausalGP(self.space, self.space.names,
                              mean_mode="constant").fit(
            self.d_t.configs, ys_t)
        self._fitted_at = len(self.d_t)

    def step(self, env) -> str:
        """One sequential round (one measurement); returns the action taken
        ('observe' | 'intervene').  Implemented as an ``ask(1)``/``tell``
        round — bit-identical to the historical sequential loop."""
        return self._round(env, 1)[0]

    # --------------------------------------------------------- ask / tell

    def ask(self, k: int = 1, *, allow_observe: bool = True,
            share_dims: Optional[Sequence[str]] = None) -> List[Proposal]:
        """Propose a q-batch of ``k`` slots (lines 6-16, batched).

        Per-slot ε-greedy mixing decides observe-vs-intervene for each slot
        (eq. 8, one ``u`` draw per slot); all intervene slots are then
        filled from ONE scored candidate set: the first pick is the
        acquisition argmax (identical to the sequential loop, so ``k=1``
        reproduces it exactly), later picks maximize acquisition × a
        repulsion penalty in the reduced causal subspace while holding the
        non-reduced dims at the anchor's values — dims outside the reduced
        space carry no causal effect under the transferred model, so pinning
        them costs nothing in expectation and lets batched environments
        share expensive measurement infrastructure (one compiled deployment
        serves the whole round).  ``share_dims`` (usually the environment's
        ``batch_share_dims``) additionally discounts candidates that would
        open another expensive measurement group within the round.
        """
        k = max(int(k), 1)
        self._round_idx += 1
        if len(self.d_t) < 2:
            # cold start: must intervene to have any target signal
            props = [Proposal("intervene", c)
                     for c in self.space.sample(self.rng, k)]
            obs_trace.tuner_event("ask", tuner="cameo",
                                  round=self._round_idx, k=k,
                                  cold_start=True)
            return props

        # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
        t0 = time.perf_counter()
        if self._warm is None or self._fitted_at != len(self.d_t):
            self._fit_surrogates()
        # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
        self.trace.model_update_s.append(time.perf_counter() - t0)

        # -- ε-greedy observation / intervention (eq. 8), per slot ----------
        x_t = np.stack([self.space.encode(c) for c in self.d_t.configs])
        eps = observation_epsilon(x_t, len(self.d_t), self.n_max_obs)
        kinds = []
        eps_draws = []
        for _ in range(k):
            u = float(self.rng.random())
            eps_draws.append(u)
            kinds.append("observe" if (eps > u and allow_observe)
                         else "intervene")
        n_int = sum(1 for kd in kinds if kd == "intervene")
        if n_int == 0:
            obs_trace.tuner_event("ask", tuner="cameo",
                                  round=self._round_idx, k=k, eps=eps,
                                  eps_draws=eps_draws, kinds=kinds,
                                  n_candidates=0)
            return [Proposal("observe") for _ in kinds]

        # -- intervention via the λ-combined acquisition -------------------
        # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
        t1 = time.perf_counter()
        cands = self.space.sample(self.rng, self.cand_n)
        best_cfg, _ = self.best
        if best_cfg is not None:
            cands.extend(self.space.neighbors(best_cfg, self.rng, 16))
        # source incumbents: the warm model's strongest transfer signal
        ys_s = np.asarray(self.d_s.ys) * self._sign
        for i in np.argsort(np.where(np.isfinite(ys_s), ys_s, np.inf))[:5]:
            cands.append({k2: v for k2, v in self.d_s.configs[int(i)].items()
                          if k2 in self.space.by_name})
            cands.extend(self.space.neighbors(cands[-1], self.rng, 3))
        # never re-intervene on a configuration already measured infeasible
        infeasible = {self._key(c) for c, y in zip(self.d_t.configs,
                                                   self.d_t.ys)
                      if not np.isfinite(y)}
        measured = {self._key(c) for c in self.d_t.configs}
        filtered = [c for c in cands
                    if self._key(c) not in infeasible
                    and self._key(c) not in measured]
        if filtered:
            cands = filtered
        alpha, lam = self._score(cands)
        self.trace.lam_fraction.append(float(lam.mean()))
        picks = self._select_batch(cands, alpha, n_int,
                                   measured | infeasible, share_dims)
        # repro: ignore[wall-clock] -- tuner-phase wall_s telemetry only; never feeds seeded decisions
        self.trace.recommend_s.append(time.perf_counter() - t1)

        # introspection only: reads already-computed state, draws no RNG —
        # the traced and untraced trajectories are identical
        if obs_trace.enabled():
            obs_trace.tuner_event(
                "ask", tuner="cameo", round=self._round_idx, k=k, eps=eps,
                eps_draws=eps_draws, kinds=kinds, n_candidates=len(cands),
                acq_max=float(np.max(alpha)), acq_mean=float(np.mean(alpha)),
                lam_mean=float(lam.mean()),
                reduced_names=list(self.reduced_names),
                picks=[{n: v for n, v in p.items()} for p in picks])

        out: List[Proposal] = []
        it = iter(picks)
        for kd in kinds:
            out.append(Proposal("observe") if kd == "observe"
                       else Proposal("intervene", next(it)))
        return out

    def tell(self, configs: Sequence[Dict], counters: Sequence[Dict],
             ys: Sequence[float], actions: Optional[Sequence[str]] = None,
             *, record: bool = True) -> None:
        """Ingest one round of measurements: constraint handling per point,
        trace bookkeeping per point, and ONE causal-graph / reduced-space
        refresh per round — fired iff the round crossed a
        ``rediscover_every`` boundary, which at ``k=1`` is exactly the
        sequential per-point schedule.  (Surrogates refresh lazily on the
        next ``ask``, also once per round.)  ``record=False`` skips trace
        and rediscovery bookkeeping — the cold-start convention of the
        sequential loop."""
        actions = (list(actions) if actions is not None
                   else ["intervene"] * len(configs))
        n0 = len(self.d_t)
        for cfg, cnt, y, act in zip(configs, counters, ys, actions):
            self.d_t.add(cfg, cnt, self._maybe_constrain(cnt, y))
            if record:
                self.trace.action.append(act)
                _, best_y = self.best
                self.trace.best_y.append(best_y)
        refreshed = record and (len(self.d_t) // self.rediscover_every
                                > n0 // self.rediscover_every)
        if refreshed:
            self._refresh_graph_t()
            # refresh the reduced space with target evidence: union of the
            # source blanket and any new strong target-side effects
            if self.g_t is not None:
                data_t, names_t = self.d_t.matrix(
                    self.space, self.counter_names,
                    maximize=self.query.maximize)
                ranked_t = rank_by_ace(data_t, names_t, "__objective__",
                                       self.g_t)
                extra = [n for n, v in ranked_t[:self.k]
                         if n in self.space.by_name
                         and n not in self.reduced_names]
                self.reduced_names.extend(extra)
        if obs_trace.enabled():
            _, best_y = self.best
            finite = [float(y) for y in ys if np.isfinite(y)]
            obs_trace.tuner_event(
                "tell", tuner="cameo", round=self._round_idx,
                told=len(list(configs)), actions=list(actions),
                best_y=best_y,
                round_best=(min(finite) if finite else None),
                graph_refreshed=bool(refreshed),
                g_t_edges=(self.trace.g_t_edges[-1]
                           if self.trace.g_t_edges else None),
                n_reduced=len(self.reduced_names),
                reduced_names=list(self.reduced_names))

    def _round(self, env, k: int,
               share_dims: Optional[Sequence[str]] = None) -> List[str]:
        """One ask → measure → tell round; returns the actions taken."""
        cold = len(self.d_t) < 2
        props = self.ask(k, allow_observe=hasattr(env, "observe"),
                         share_dims=share_dims)
        configs: List[Dict] = []
        counters: List[Dict] = []
        ys: List[float] = []
        actions: List[str] = []
        pending: List[Dict] = []
        for p in props:
            if p.kind == "observe":
                cfg, cnt, y = env.observe(self.rng)
                configs.append(cfg)
                counters.append(cnt)
                ys.append(y)
                actions.append("observe")
            else:
                pending.append(p.config)
        if pending:
            if len(pending) > 1 and hasattr(env, "intervene_batch"):
                results = env.intervene_batch(pending)
            else:
                results = [env.intervene(c) for c in pending]
            for cfg, (cnt, y) in zip(pending, results):
                configs.append(cfg)
                counters.append(cnt)
                ys.append(y)
                actions.append("intervene")
        self.tell(configs, counters, ys, actions, record=not cold)
        return actions

    # ---------------------------------------------- acquisition / selection

    def _score(self, cands: Sequence[Dict]) -> Tuple[np.ndarray, np.ndarray]:
        """λ-combined acquisition over ``cands`` (eqs. 5-7); deterministic —
        consumes no RNG, so re-scoring projected pools is parity-safe."""
        mu_w, sd_w = self._warm.predict(cands)
        mu_c, sd_c = self._cold.predict(cands)
        finite = self._ys_internal()[np.isfinite(self._ys_internal())]
        best_internal = float(np.min(finite)) if len(finite) else 0.0
        ei_w = expected_improvement(mu_w, sd_w, self._warm.best_observed)
        ei_c = expected_improvement(mu_c, sd_c, best_internal)
        return combined_acquisition(ei_w, ei_c, self.l_alpha)

    #: repulsion lengthscale in the normalized reduced subspace, and the
    #: acquisition discount for opening another expensive measurement group
    #: (``share_dims``) within one round
    batch_repulsion_ell = 0.25
    batch_new_group_discount = 0.25

    def _select_batch(self, cands: Sequence[Dict], alpha: np.ndarray,
                      n: int, taken_keys: Set[tuple],
                      share_dims: Optional[Sequence[str]] = None
                      ) -> List[Dict]:
        """Diverse top-``n``: anchor = argmax acquisition (the sequential
        pick), then greedy repulsion-penalized picks over the candidate set
        PROJECTED onto the anchor's non-reduced dims."""
        first = int(np.argmax(alpha))
        anchor = {nm: cands[first].get(nm, self.space.by_name[nm].default)
                  for nm in self.space.names}
        picked = [anchor]
        if n == 1:
            return picked

        reduced = [nm for nm in self.space.names if nm in self.reduced_names]
        if not reduced:
            reduced = list(self.space.names)
        other = [nm for nm in self.space.names if nm not in reduced]
        seen = set(taken_keys)
        seen.add(self._key(anchor))
        pool: List[Dict] = []
        for c in cands:
            pc = {nm: c.get(nm, self.space.by_name[nm].default)
                  for nm in self.space.names}
            for nm in other:
                pc[nm] = anchor[nm]
            key = self._key(pc)
            if key in seen:
                continue
            seen.add(key)
            pool.append(pc)
        if not pool:
            return picked

        alpha_p, _ = self._score(pool)
        alpha_p = np.maximum(np.asarray(alpha_p, np.float64), 1e-300)
        idx = [self.space.names.index(nm) for nm in reduced]
        xr = np.stack([self.space.encode(c) for c in pool])[:, idx]
        picked_x = [self.space.encode(anchor)[idx]]

        share = [nm for nm in (share_dims or ()) if nm in self.space.by_name]

        def group_key(cfg: Dict) -> tuple:
            return tuple(cfg[nm] for nm in share)

        open_groups = {group_key(anchor)} if share else set()
        alive = np.ones(len(pool), bool)
        ell2 = 2.0 * self.batch_repulsion_ell ** 2
        for _ in range(n - 1):
            if not alive.any():
                break
            pen = np.ones(len(pool))
            for px in picked_x:
                d2 = ((xr - px) ** 2).mean(axis=1)
                pen *= 1.0 - np.exp(-d2 / ell2)
            score = alpha_p * np.maximum(pen, 1e-12)
            if share:
                fresh = np.asarray([group_key(c) not in open_groups
                                    for c in pool])
                score = score * np.where(fresh,
                                         self.batch_new_group_discount, 1.0)
            score = np.where(alive, score, -np.inf)
            j = int(np.argmax(score))
            picked.append(pool[j])
            picked_x.append(xr[j])
            alive[j] = False
            if share:
                open_groups.add(group_key(pool[j]))
        return picked

    def _key(self, cfg: Dict) -> tuple:
        return tuple(cfg.get(n, self.space.by_name[n].default)
                     for n in self.space.names)

    def _maybe_constrain(self, counters: Dict[str, float], y: float) -> float:
        """Constraint handling (lines 17-19): infeasible -> ∞ (internal)."""
        metrics = dict(counters or {})
        metrics[self.query.objective] = y
        if not self.query.satisfies(metrics):
            return float("inf") * (self._sign)
        return y
