"""Average causal effect (ACE) estimation via backdoor adjustment.

For a node X with parents Pa(X) in the causal performance model, the
interventional mean is identified by adjustment:

    E[Y | do(X=x)] = E_Z [ E[Y | X=x, Z=Pa(X)] ]

We estimate the inner regression with ridge least squares on the adjustment
set (standard linear backdoor estimator — systems objectives are locally
smooth in the recommended-value ranges, and the estimator must stay sane at
the paper's n≈10..2000 sample sizes), and report

    ACE(X) = | d/dx  E[Y | do(X=x)] |  (the absolute adjusted coefficient)

Nodes connected to Y only through bidirected (possibly-confounded) edges get
their effect attenuated by ``confound_discount`` — the conservative
treatment of latent confounding.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.discovery import BIDIRECTED, CausalGraph


def _standardize(x: np.ndarray) -> np.ndarray:
    mu = x.mean(axis=0, keepdims=True)
    sd = x.std(axis=0, keepdims=True)
    sd = np.where(sd < 1e-12, 1.0, sd)
    return (x - mu) / sd


def adjusted_effect(data: np.ndarray, names: Sequence[str], x_name: str,
                    y_name: str, graph: CausalGraph,
                    ridge: float = 1e-3) -> float:
    """|coefficient of X| in ridge(Y ~ X + Pa(X)), standardized data."""
    idx = {n: i for i, n in enumerate(names)}
    if x_name not in idx or y_name not in idx:
        return 0.0
    adj = [p for p in graph.parents(x_name) if p in idx and p != y_name]
    cols = [idx[x_name]] + [idx[p] for p in adj]
    X = _standardize(data[:, cols].astype(np.float64))
    y = _standardize(data[:, [idx[y_name]]].astype(np.float64))[:, 0]
    Xb = np.column_stack([X, np.ones(len(X))])
    A = Xb.T @ Xb + ridge * np.eye(Xb.shape[1])
    b = Xb.T @ y
    coef = np.linalg.solve(A, b)
    return float(abs(coef[0]))


def rank_by_ace(data: np.ndarray, names: Sequence[str], y_name: str,
                graph: CausalGraph, confound_discount: float = 0.5
                ) -> List[Tuple[str, float]]:
    """All non-objective nodes ranked by ACE on the objective, descending."""
    out = []
    for n in names:
        if n == y_name:
            continue
        eff = adjusted_effect(data, names, n, y_name, graph)
        if graph.edge_kind(n, y_name) == BIDIRECTED:
            eff *= confound_discount
        out.append((n, eff))
    out.sort(key=lambda t: -t[1])
    return out


def choose_k(ranked: Sequence[Tuple[str, float]], k_min: int = 2,
             k_max: Optional[int] = None) -> int:
    """Pick k at the sharpest drop of the sorted ACE curve (elbow — the
    Hamerly–Elkan 'learning k' criterion applied to the 1-D effect sizes)."""
    vals = np.array([v for _, v in ranked], np.float64)
    if len(vals) <= k_min:
        return len(vals)
    k_max = k_max or max(k_min, int(np.ceil(len(vals) * 0.6)))
    drops = vals[:-1] - vals[1:]
    lo, hi = k_min - 1, min(k_max, len(drops))
    if lo >= hi:
        return min(k_min, len(vals))
    k = int(np.argmax(drops[lo:hi])) + lo + 1
    return k
