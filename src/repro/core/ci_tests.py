"""Conditional-independence tests for causal structure discovery.

- ``fisher_z``: partial-correlation test for continuous / ordinal-encoded
  variables (the paper's choice for continuous data).
- ``mutual_info``: binned conditional mutual information with a permutation
  threshold for small discrete domains (the paper's choice for discrete
  data).

Both return (statistic, independent?) at significance ``alpha``.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np


def _norm_sf(z: float) -> float:
    """Survival function of the standard normal (no scipy dependency)."""
    return 0.5 * math.erfc(z / math.sqrt(2.0))


def partial_correlation(data: np.ndarray, i: int, j: int,
                        cond: Sequence[int]) -> float:
    """Partial correlation of columns i, j given columns `cond`.

    Computed by regressing out the conditioning set (linear least squares) —
    equivalent to the inverse-covariance formulation but stable for small n.
    """
    x = data[:, i].astype(np.float64)
    y = data[:, j].astype(np.float64)
    if cond:
        z = data[:, list(cond)].astype(np.float64)
        z = np.column_stack([z, np.ones(len(z))])
        bx, *_ = np.linalg.lstsq(z, x, rcond=None)
        by, *_ = np.linalg.lstsq(z, y, rcond=None)
        x = x - z @ bx
        y = y - z @ by
    sx, sy = x.std(), y.std()
    if sx < 1e-12 or sy < 1e-12:
        return 0.0
    r = float(np.clip(np.mean((x - x.mean()) * (y - y.mean())) / (sx * sy),
                      -0.999999, 0.999999))
    return r


def fisher_z(data: np.ndarray, i: int, j: int, cond: Sequence[int],
             alpha: float = 0.05) -> Tuple[float, bool]:
    """Fisher z-test. Returns (p_value, independent?)."""
    n = data.shape[0]
    k = len(cond)
    if n - k - 3 <= 0:
        return 1.0, True
    r = partial_correlation(data, i, j, cond)
    z = 0.5 * math.log((1 + r) / (1 - r)) * math.sqrt(n - k - 3)
    p = 2.0 * _norm_sf(abs(z))
    return p, p > alpha


def _discretize(col: np.ndarray, bins: int = 4) -> np.ndarray:
    uniq = np.unique(col)
    if len(uniq) <= bins:
        return np.searchsorted(uniq, col)
    qs = np.quantile(col, np.linspace(0, 1, bins + 1)[1:-1])
    return np.digitize(col, qs)


def mutual_info(data: np.ndarray, i: int, j: int, cond: Sequence[int],
                alpha: float = 0.05, bins: int = 4,
                rng: Optional[np.random.Generator] = None) -> Tuple[float, bool]:
    """Conditional mutual information I(i; j | cond) with a permutation null.

    Returns (cmi, independent?).  Independence is declared when the observed
    CMI is below the 1-alpha quantile of a small permutation null.
    """
    rng = rng or np.random.default_rng(0)
    xi = _discretize(data[:, i], bins)
    xj = _discretize(data[:, j], bins)
    if cond:
        zi = np.zeros(len(xi), np.int64)
        for c in cond:
            zi = zi * bins + _discretize(data[:, c], bins)
    else:
        zi = np.zeros(len(xi), np.int64)

    def cmi(a, b, z):
        total = 0.0
        n = len(a)
        for zv in np.unique(z):
            m = z == zv
            nz = m.sum()
            if nz < 4:
                continue
            az, bz = a[m], b[m]
            pj = np.zeros((az.max() + 1, bz.max() + 1))
            np.add.at(pj, (az, bz), 1.0)
            pj /= nz
            pa = pj.sum(1, keepdims=True)
            pb = pj.sum(0, keepdims=True)
            nzmask = pj > 0
            total += (nz / n) * float(
                np.sum(pj[nzmask] * np.log(pj[nzmask]
                                           / (pa @ pb)[nzmask])))
        return total

    obs = cmi(xi, xj, zi)
    null = []
    for _ in range(19):  # 19 perms -> 5% one-sided threshold at the max
        null.append(cmi(rng.permutation(xi), xj, zi))
    thresh = max(null) if null else 0.0
    return obs, obs <= max(thresh, 1e-3)
