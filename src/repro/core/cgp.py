"""Causal Gaussian Process (the CBO-style surrogate, eqs. 2-4 of the paper).

A CGP differs from a plain GP in two ways:

  mean   m(o) = Ê[Y | do(o)]  — the do-calculus interventional estimate from
         the causal performance model + observational data (backdoor
         adjustment over the causal parents of the objective);
  kernel k(o, o') = k_RBF(o, o') + σ(o) σ(o')  with
         σ(o) = sqrt(V̂[Y | do(o)]) — the interventional variance, so the
         posterior uncertainty widens exactly where the causal estimate is
         poorly supported by data.

Implementation: the interventional mean is a ridge regression on the
*causal feature subset* (the Markov-blanket variables the graph exposes);
its local residual variance (k-NN over causal features) gives σ(o).  The GP
is then fit on the residual y - m(o) with the σ-augmented kernel, which is
algebraically the paper's kernel with the mean folded out.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.gp import GPFit, fit_gp, gp_predict
from repro.core.spaces import ConfigSpace


class InterventionalEstimator:
    """Ê[Y|do(o)] and V̂[Y|do(o)] over a causal feature subset.

    ``feature_idx=None`` -> intercept-only mean (the cold model's safe prior
    when too few target samples exist to support a multivariate adjustment);
    the k-NN variance still localizes over the full encoding.
    """

    def __init__(self, feature_idx: Optional[Sequence[int]], ridge: float = 1e-2,
                 knn: int = 8):
        self.feature_idx = None if feature_idx is None else list(feature_idx)
        self.ridge = ridge
        self.knn = knn
        self._coef: Optional[np.ndarray] = None
        self._xf: Optional[np.ndarray] = None
        self._resid2: Optional[np.ndarray] = None
        self._var_floor = 1e-6

    def _features(self, x: np.ndarray) -> np.ndarray:
        if self.feature_idx is None:
            return np.zeros((len(x), 0))
        return x[:, self.feature_idx]

    def fit(self, x: np.ndarray, y: np.ndarray) -> "InterventionalEstimator":
        xf = self._features(x)
        xb = np.column_stack([xf, np.ones(len(x))])
        A = xb.T @ xb + self.ridge * np.eye(xb.shape[1])
        self._coef = np.linalg.solve(A, xb.T @ y)
        pred = xb @ self._coef
        self._xall = x
        self._xf = xf
        self._resid2 = (y - pred) ** 2
        self._var_floor = float(np.median(self._resid2) + 1e-9)
        # cap σ(o): constraint-clamped (was-infeasible) observations create
        # huge local residuals; unbounded σ makes EI *seek* infeasible
        # regions ("high uncertainty"), the classic constrained-BO trap
        self._var_cap = float(np.var(y) + self._var_floor)
        return self

    def mean(self, xq: np.ndarray) -> np.ndarray:
        xb = np.column_stack([self._features(xq), np.ones(len(xq))])
        return xb @ self._coef

    def std(self, xq: np.ndarray) -> np.ndarray:
        """sqrt of local (k-NN) residual variance — V̂[Y|do(o)]."""
        ref = self._xf if self._xf.shape[1] else self._xall
        q = self._features(xq) if self._xf.shape[1] else xq
        d2 = ((q[:, None, :] - ref[None, :, :]) ** 2).sum(-1)
        k = min(self.knn, ref.shape[0])
        idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
        local = np.take_along_axis(
            np.broadcast_to(self._resid2, d2.shape), idx, axis=1)
        var = local.mean(axis=1) + self._var_floor * 0.1
        return np.sqrt(np.minimum(var, self._var_cap))


class CausalGP:
    """Warm/cold surrogate: interventional mean + GP on the residual with a
    σ(o)-augmented kernel.

    ``mean_mode="causal"`` (warm): ridge backdoor mean over the causal
    feature subset, GP over those features — the reduced-space surrogate.
    ``mean_mode="constant"`` (cold): intercept-only interventional mean, GP
    over the full encoding — safe at the handful-of-samples regime the
    target starts in.
    """

    def __init__(self, space: ConfigSpace, feature_names: Sequence[str],
                 mean_mode: str = "causal"):
        self.space = space
        self.mean_mode = mean_mode
        self.feature_names = [n for n in feature_names if n in space.by_name]
        name_to_idx = {n: i for i, n in enumerate(space.names)}
        self.feature_idx = [name_to_idx[n] for n in self.feature_names]
        self.est: Optional[InterventionalEstimator] = None
        self.fit_: Optional[GPFit] = None
        self._x: Optional[np.ndarray] = None
        self._y: Optional[np.ndarray] = None

    def _gp_idx(self):
        return self.feature_idx or list(range(self.space.dim))

    def fit(self, configs: Sequence[Dict], ys: Sequence[float]) -> "CausalGP":
        x = np.stack([self.space.encode(c) for c in configs])
        y = np.asarray(ys, np.float64)
        if not np.isfinite(y).all():  # clamp infeasible to pessimistic finite
            good = y[np.isfinite(y)]
            worst = (good.max() + 0.5 * (np.ptp(good) + 1e-3)
                     if len(good) else 1.0)
            y = np.where(np.isfinite(y), y, worst)
        self._x, self._y = x, y
        mean_idx = (None if self.mean_mode == "constant"
                    else (self.feature_idx or None))
        self.est = InterventionalEstimator(mean_idx).fit(x, y)
        resid = y - self.est.mean(x)
        sigma = self.est.std(x)
        # σ(o)σ(o') kernel term contributes σ(o)^2 on the diagonal; folding
        # it into heteroscedastic noise keeps the GP exact and PSD
        self.fit_ = fit_gp(x[:, self._gp_idx()], resid, extra_var=sigma ** 2)
        return self

    def predict(self, configs: Sequence[Dict]) -> Tuple[np.ndarray, np.ndarray]:
        xq = np.stack([self.space.encode(c) for c in configs])
        mu_do = self.est.mean(xq)
        sig_do = self.est.std(xq)
        mu_gp, sd_gp = gp_predict(self.fit_, xq[:, self._gp_idx()])
        mu = mu_do + np.asarray(mu_gp)
        sd = np.sqrt(np.asarray(sd_gp) ** 2 + sig_do ** 2)
        return mu, sd

    @property
    def best_observed(self) -> float:
        return float(np.min(self._y)) if self._y is not None else np.inf
