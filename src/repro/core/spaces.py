"""Configuration spaces: named options with mixed-type domains.

The space is the paper's ``O = Dom(O_1) x ... x Dom(O_d)``.  Options carry
explicit finite domains (systems knobs are recommended-value lists — Tables
7–12 of the paper); encoding maps a configuration to a float vector for the
GP/CI machinery (categoricals -> domain index, numerics -> value) with
per-dimension normalization to [0, 1].
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Option:
    name: str
    values: Tuple[Any, ...]          # finite ordered domain
    default: Any = None
    kind: str = "numeric"            # numeric | categorical | boolean

    def __post_init__(self):
        if self.default is None:
            object.__setattr__(self, "default", self.values[0])

    @property
    def cardinality(self) -> int:
        return len(self.values)

    def index_of(self, v: Any) -> int:
        """Index of v, snapping to the nearest valid value when v comes from
        a different environment's domain (cross-space transfer)."""
        if v in self.values:
            return self.values.index(v)
        if self.kind == "numeric":
            try:
                fv = float(v)
                return min(range(len(self.values)),
                           key=lambda i: abs(float(self.values[i]) - fv))
            except (TypeError, ValueError):
                pass
        return self.values.index(self.default)


class ConfigSpace:
    def __init__(self, options: Sequence[Option]):
        self.options = list(options)
        self.by_name = {o.name: o for o in self.options}
        if len(self.by_name) != len(self.options):
            raise ValueError("duplicate option names")

    @property
    def names(self) -> List[str]:
        return [o.name for o in self.options]

    @property
    def dim(self) -> int:
        return len(self.options)

    def size(self) -> int:
        n = 1
        for o in self.options:
            n *= o.cardinality
        return n

    def default_config(self) -> Dict[str, Any]:
        return {o.name: o.default for o in self.options}

    # -- encoding ------------------------------------------------------------

    def encode(self, config: Dict[str, Any]) -> np.ndarray:
        """Config -> normalized float vector in [0, 1]^d."""
        x = np.empty(self.dim, np.float64)
        for i, o in enumerate(self.options):
            v = config.get(o.name, o.default)
            if o.kind == "numeric":
                lo = float(min(o.values))
                hi = float(max(o.values))
                x[i] = 0.5 if hi == lo else (float(v) - lo) / (hi - lo)
            else:
                x[i] = o.index_of(v) / max(o.cardinality - 1, 1)
        return x

    def decode(self, x: np.ndarray) -> Dict[str, Any]:
        """Nearest valid configuration for a [0,1]^d vector."""
        cfg = {}
        for i, o in enumerate(self.options):
            if o.kind == "numeric":
                lo = float(min(o.values))
                hi = float(max(o.values))
                target = lo + float(np.clip(x[i], 0, 1)) * (hi - lo)
                cfg[o.name] = min(o.values, key=lambda v: abs(float(v) - target))
            else:
                idx = int(round(float(np.clip(x[i], 0, 1)) * (o.cardinality - 1)))
                cfg[o.name] = o.values[idx]
        return cfg

    # -- sampling / enumeration ----------------------------------------------

    def sample(self, rng: np.random.Generator, n: int = 1) -> List[Dict[str, Any]]:
        out = []
        for _ in range(n):
            out.append({o.name: o.values[int(rng.integers(o.cardinality))]
                        for o in self.options})
        return out

    def neighbors(self, config: Dict[str, Any], rng: np.random.Generator,
                  n: int = 8) -> List[Dict[str, Any]]:
        """Local moves: change one option to an adjacent / random value."""
        out = []
        for _ in range(n):
            o = self.options[int(rng.integers(self.dim))]
            c = dict(config)
            cur = o.index_of(c.get(o.name, o.default))
            if o.kind == "numeric" and o.cardinality > 2 and rng.random() < 0.7:
                step = int(rng.integers(1, 3)) * (1 if rng.random() < 0.5 else -1)
                idx = int(np.clip(cur + step, 0, o.cardinality - 1))
            else:
                idx = int(rng.integers(o.cardinality))
            c[o.name] = o.values[idx]
            out.append(c)
        return out

    def subspace(self, names: Iterable[str]) -> "ConfigSpace":
        keep = [self.by_name[n] for n in names if n in self.by_name]
        return ConfigSpace(keep)

    def grid(self, max_points: int = 4096,
             rng: Optional[np.random.Generator] = None) -> List[Dict[str, Any]]:
        """Full enumeration if small, else a random subset."""
        if self.size() <= max_points:
            configs = [{}]
            for o in self.options:
                configs = [dict(c, **{o.name: v}) for c in configs
                           for v in o.values]
            return configs
        rng = rng or np.random.default_rng(0)
        return self.sample(rng, max_points)
