"""Markov-blanket extraction for multiple targets (IAMB-S style).

The paper transfers the union of the Markov blankets of the top-k
highest-ACE nodes (plus the objective's own blanket) — this is the reduced
variable set the warm CGP operates on, and it is what deletes
source-specific spurious edges (Sec. 2.2, Fig. 4-5).

``top_k_blanket`` takes the graph-derived blankets and verifies each member
with a shrink phase of conditional-independence tests (IAMB's backward
step, the additivity check of Liu & Liu 2018): a member is dropped if it is
independent of the target given the rest of the blanket.
"""

from __future__ import annotations

from typing import List, Sequence, Set, Tuple

import numpy as np

from repro.core.ci_tests import fisher_z
from repro.core.discovery import CausalGraph


def shrink_blanket(data: np.ndarray, names: Sequence[str], target: str,
                   blanket: Set[str], alpha: float = 0.05,
                   max_cond: int = 3) -> Set[str]:
    idx = {n: i for i, n in enumerate(names)}
    if target not in idx:
        return blanket
    members = [m for m in blanket if m in idx]
    keep = set(members)
    for m in list(members):
        rest = [idx[r] for r in keep if r != m][:max_cond]
        _, independent = fisher_z(data, idx[m], idx[target], rest, alpha=alpha)
        if independent:
            keep.discard(m)
    return keep


def top_k_blanket(
    graph: CausalGraph,
    ranked: Sequence[Tuple[str, float]],
    k: int,
    y_name: str,
    data: np.ndarray = None,
    names: Sequence[str] = None,
    shrink: bool = True,
) -> Set[str]:
    """Union of Markov blankets of the top-k nodes and the objective."""
    top = [n for n, _ in ranked[:k]]
    mb: Set[str] = set(top)
    mb |= graph.markov_blanket(y_name)
    for n in top:
        mb |= graph.markov_blanket(n)
    mb.discard(y_name)
    if shrink and data is not None and names is not None:
        mb = shrink_blanket(data, names, y_name, mb) | set(top)
    return mb
