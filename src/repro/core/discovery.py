"""FCI-lite causal structure discovery + entropic edge orientation.

The paper runs FCI (Fisher-z / mutual-information CI tests) to get a PAG and
resolves the remaining partially-directed edges with entropic causal
discovery (LatentSearch, Kocaoglu et al.).  This implementation keeps the
same three stages on the same test machinery, with the full PAG calculus
replaced by the PC skeleton + v-structures + Meek rules ("FCI-lite", see
DESIGN.md §8):

  1. skeleton: start complete, remove edges independent given conditioning
     sets up to ``max_cond`` drawn from current neighborhoods;
  2. orient v-structures (i - k - j with i,j nonadjacent and k not in
     sepset(i,j)) then apply Meek rules R1-R3;
  3. orient whatever is left by the entropic criterion: prefer the direction
     whose residual (effect given cause) has lower entropy; edges whose
     entropy gap is negligible keep a bidirected mark (possible latent
     confounder), which downstream ACE treats conservatively.

Graphs are small (tens of nodes), so adjacency sets + dict edge marks are
plenty.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.ci_tests import _discretize, fisher_z, mutual_info

DIRECTED = "-->"
BIDIRECTED = "<->"
UNDIRECTED = "---"


@dataclass
class CausalGraph:
    nodes: List[str]
    # edges keyed by ordered pair for DIRECTED (a->b); unordered stored both ways
    edges: Dict[Tuple[str, str], str] = field(default_factory=dict)
    sepsets: Dict[FrozenSet[str], Set[str]] = field(default_factory=dict)

    # -- mutation ----------------------------------------------------------

    def add_edge(self, a: str, b: str, kind: str = UNDIRECTED) -> None:
        if kind == DIRECTED:
            self.edges.pop((b, a), None)
            self.edges[(a, b)] = DIRECTED
        else:
            self.edges[(a, b)] = kind
            self.edges[(b, a)] = kind

    def remove_edge(self, a: str, b: str) -> None:
        self.edges.pop((a, b), None)
        self.edges.pop((b, a), None)

    # -- queries -------------------------------------------------------------

    def has_edge(self, a: str, b: str) -> bool:
        return (a, b) in self.edges or (b, a) in self.edges

    def edge_kind(self, a: str, b: str) -> Optional[str]:
        if (a, b) in self.edges:
            return self.edges[(a, b)]
        if (b, a) in self.edges:
            k = self.edges[(b, a)]
            return DIRECTED + "_rev" if k == DIRECTED else k

    def neighbors(self, a: str) -> Set[str]:
        out = set()
        for (x, y) in self.edges:
            if x == a:
                out.add(y)
            elif y == a:
                out.add(x)
        return out

    def parents(self, a: str) -> Set[str]:
        return {x for (x, y), k in self.edges.items()
                if y == a and k == DIRECTED}

    def children(self, a: str) -> Set[str]:
        return {y for (x, y), k in self.edges.items()
                if x == a and k == DIRECTED}

    def undirected_neighbors(self, a: str) -> Set[str]:
        out = set()
        for (x, y), k in self.edges.items():
            if k in (UNDIRECTED, BIDIRECTED):
                if x == a:
                    out.add(y)
        return out

    def markov_blanket(self, a: str) -> Set[str]:
        """Parents, children, children's other parents (+ undirected nbrs)."""
        mb = set(self.parents(a)) | set(self.children(a))
        for c in self.children(a):
            mb |= self.parents(c)
        mb |= self.undirected_neighbors(a)
        mb.discard(a)
        return mb

    def edge_list(self) -> List[Tuple[str, str, str]]:
        seen = set()
        out = []
        for (a, b), k in sorted(self.edges.items()):
            key = frozenset((a, b))
            if k == DIRECTED:
                out.append((a, b, k))
            elif key not in seen:
                out.append((a, b, k))
                seen.add(key)
        return out

    def num_edges(self) -> int:
        return len(self.edge_list())

    def copy(self) -> "CausalGraph":
        g = CausalGraph(list(self.nodes))
        g.edges = dict(self.edges)
        g.sepsets = {k: set(v) for k, v in self.sepsets.items()}
        return g

    # -- comparison (Fig. 3 / Fig. 12 of the paper) ---------------------------

    def shd(self, other: "CausalGraph") -> int:
        """Structural Hamming distance over the shared node set."""
        nodes = [n for n in self.nodes if n in set(other.nodes)]
        d = 0
        for a, b in itertools.combinations(nodes, 2):
            ka = self.edge_kind(a, b)
            kb = other.edge_kind(a, b)
            if (ka is None) != (kb is None):
                d += 1
            elif ka is not None and ka != kb:
                d += 1
        return d


def fci_lite(
    data: np.ndarray,
    names: Sequence[str],
    *,
    alpha: float = 0.05,
    max_cond: int = 2,
    discrete_cols: Optional[Set[int]] = None,
    entropic_orient: bool = True,
    entropy_gap: float = 0.02,
) -> CausalGraph:
    """Discover a causal graph from observational data (rows x variables)."""
    n_vars = data.shape[1]
    assert len(names) == n_vars
    discrete_cols = discrete_cols or set()
    g = CausalGraph(list(names))
    for i, j in itertools.combinations(range(n_vars), 2):
        g.add_edge(names[i], names[j], UNDIRECTED)

    def indep(i, j, cond):
        if i in discrete_cols and j in discrete_cols and len(cond) <= 1:
            _, ind = mutual_info(data, i, j, cond, alpha=alpha)
            return ind
        _, ind = fisher_z(data, i, j, cond, alpha=alpha)
        return ind

    idx = {nm: k for k, nm in enumerate(names)}

    # stage 1: skeleton
    for level in range(max_cond + 1):
        for i, j in itertools.combinations(range(n_vars), 2):
            a, b = names[i], names[j]
            if not g.has_edge(a, b):
                continue
            nbrs = (g.neighbors(a) | g.neighbors(b)) - {a, b}
            nbr_idx = [idx[x] for x in nbrs]
            removed = False
            for cond in itertools.combinations(nbr_idx, level):
                if indep(i, j, list(cond)):
                    g.remove_edge(a, b)
                    g.sepsets[frozenset((a, b))] = {names[c] for c in cond}
                    removed = True
                    break
            if removed:
                continue

    # stage 2: v-structures + Meek rules
    for a, b in itertools.combinations(g.nodes, 2):
        if g.has_edge(a, b):
            continue
        sep = g.sepsets.get(frozenset((a, b)), set())
        for c in g.neighbors(a) & g.neighbors(b):
            if c not in sep and g.edge_kind(a, c) == UNDIRECTED \
                    and g.edge_kind(b, c) == UNDIRECTED:
                g.remove_edge(a, c)
                g.add_edge(a, c, DIRECTED)
                g.remove_edge(b, c)
                g.add_edge(b, c, DIRECTED)
    _meek(g)

    # stage 3: entropic orientation of the residual undirected edges
    if entropic_orient:
        for a, b, k in list(g.edge_list()):
            if k != UNDIRECTED:
                continue
            gap = _entropy_direction(data, idx[a], idx[b])
            g.remove_edge(a, b)
            if abs(gap) < entropy_gap:
                g.add_edge(a, b, BIDIRECTED)  # possible latent confounder
            elif gap < 0:
                g.add_edge(a, b, DIRECTED)
            else:
                g.add_edge(b, a, DIRECTED)
        _meek(g)
    return g


def _meek(g: CausalGraph) -> None:
    """Meek rules R1-R3 to closure."""
    changed = True
    while changed:
        changed = False
        for a, b, k in list(g.edge_list()):
            if k != UNDIRECTED:
                continue
            # R1: c -> a, c not adjacent b  =>  a -> b
            for c in g.parents(a):
                if not g.has_edge(c, b):
                    g.remove_edge(a, b)
                    g.add_edge(a, b, DIRECTED)
                    changed = True
                    break
            if changed:
                continue
            # R2: a -> c -> b  =>  a -> b
            if g.children(a) & g.parents(b):
                g.remove_edge(a, b)
                g.add_edge(a, b, DIRECTED)
                changed = True
                continue
            # R3: a - c -> b and a - d -> b, c,d nonadjacent => a -> b
            cands = [c for c in g.undirected_neighbors(a) if b in g.children(c)]
            if any(not g.has_edge(c, d)
                   for c, d in itertools.combinations(cands, 2)):
                g.remove_edge(a, b)
                g.add_edge(a, b, DIRECTED)
                changed = True


def _entropy_direction(data: np.ndarray, i: int, j: int, bins: int = 6) -> float:
    """Entropic criterion: H(j | i) - H(i | j) on binned data.

    Negative -> i causes j (residual of j given i is simpler), per the
    minimum-entropy exogenous-variable principle of entropic causal
    inference.
    """
    xi = _discretize(data[:, i], bins)
    xj = _discretize(data[:, j], bins)

    def cond_entropy(a, b):  # H(a | b)
        h = 0.0
        n = len(a)
        for bv in np.unique(b):
            m = b == bv
            pa = np.bincount(a[m]) / m.sum()
            pa = pa[pa > 0]
            h += (m.sum() / n) * float(-(pa * np.log(pa)).sum())
        return h

    return cond_entropy(xj, xi) - cond_entropy(xi, xj)
