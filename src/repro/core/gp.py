"""Pure-JAX Gaussian process regression (the surrogate substrate).

Exact GP with an RBF kernel + heteroscedastic diagonal noise, Cholesky
solves, and a small log-marginal-likelihood grid fit for (lengthscale,
signal, noise).  Everything jit-compiled; n is the tuning-budget scale
(<= a few hundred points), so exact inference is the right tool.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class GPFit(NamedTuple):
    x: jax.Array          # (n, d) training inputs
    alpha: jax.Array      # (n,) K^-1 (y - mean)
    chol: jax.Array       # (n, n) cholesky of K + noise
    lengthscale: jax.Array
    signal: jax.Array
    noise: jax.Array
    y_mean: jax.Array
    y_std: jax.Array


def rbf(x1: jax.Array, x2: jax.Array, lengthscale, signal) -> jax.Array:
    d2 = jnp.sum((x1[:, None, :] - x2[None, :, :]) ** 2, axis=-1)
    return signal * jnp.exp(-0.5 * d2 / (lengthscale ** 2))


@functools.partial(jax.jit, static_argnames=())
def _fit_given(x, y, lengthscale, signal, noise, extra_var):
    n = x.shape[0]
    K = rbf(x, x, lengthscale, signal)
    K = K + jnp.diag(noise + extra_var)
    chol = jnp.linalg.cholesky(K + 1e-8 * jnp.eye(n))
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    # log marginal likelihood
    lml = (-0.5 * jnp.dot(y, alpha)
           - jnp.sum(jnp.log(jnp.diagonal(chol)))
           - 0.5 * n * jnp.log(2 * jnp.pi))
    return chol, alpha, lml


def fit_gp(x: np.ndarray, y: np.ndarray,
           extra_var: Optional[np.ndarray] = None,
           lengthscales=(0.1, 0.2, 0.4, 0.8, 1.6),
           noises=(1e-4, 1e-2, 1e-1)) -> GPFit:
    """Fit on standardized targets; hyperparameters by LML grid search."""
    x = jnp.asarray(x, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    y_raw = np.asarray(y, np.float64)
    y_mean, y_std = float(y_raw.mean()), float(y_raw.std() + 1e-9)
    yn = jnp.asarray((y_raw - y_mean) / y_std, x.dtype)
    ev = (jnp.zeros(len(y_raw), x.dtype) if extra_var is None
          else jnp.asarray(extra_var / (y_std ** 2), x.dtype))

    best = None
    for ls in lengthscales:
        for nz in noises:
            chol, alpha, lml = _fit_given(x, yn, ls, 1.0, nz, ev)
            if not bool(jnp.isfinite(lml)):
                continue
            if best is None or float(lml) > best[0]:
                best = (float(lml), ls, nz, chol, alpha)
    if best is None:  # degenerate data; fall back to widest kernel
        ls, nz = lengthscales[-1], noises[-1]
        chol, alpha, _ = _fit_given(x, yn, ls, 1.0, nz, ev)
        best = (0.0, ls, nz, chol, alpha)
    _, ls, nz, chol, alpha = best
    return GPFit(x=x, alpha=alpha, chol=chol,
                 lengthscale=jnp.asarray(ls, x.dtype),
                 signal=jnp.asarray(1.0, x.dtype),
                 noise=jnp.asarray(nz, x.dtype),
                 y_mean=jnp.asarray(y_mean, x.dtype),
                 y_std=jnp.asarray(y_std, x.dtype))


@jax.jit
def gp_predict(fit: GPFit, xq: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Posterior mean/std at query points (unstandardized). xq: (m, d)."""
    Ks = rbf(xq, fit.x, fit.lengthscale, fit.signal)    # (m, n)
    mu = Ks @ fit.alpha
    v = jax.scipy.linalg.solve_triangular(fit.chol, Ks.T, lower=True)
    var = jnp.clip(fit.signal - jnp.sum(v * v, axis=0), 1e-10, None)
    return (mu * fit.y_std + fit.y_mean,
            jnp.sqrt(var) * fit.y_std)
