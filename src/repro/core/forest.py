"""Minimal extra-trees random-forest regressor (SMAC's surrogate family).

Numpy-only: each tree subsamples rows (bagging) and picks random split
(feature, threshold) pairs, taking the best of a small random set per node
(extra-trees).  Predictive mean/std across trees drives EI in SMAC.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    value: float = 0.0


def _build(x, y, rng, depth, max_depth, min_leaf, n_trials):
    node = _Node(value=float(y.mean()))
    if depth >= max_depth or len(y) < 2 * min_leaf or y.std() < 1e-12:
        return node
    best = None
    for _ in range(n_trials):
        f = int(rng.integers(x.shape[1]))
        lo, hi = x[:, f].min(), x[:, f].max()
        if hi - lo < 1e-12:
            continue
        t = float(rng.uniform(lo, hi))
        mask = x[:, f] <= t
        nl = int(mask.sum())
        if nl < min_leaf or len(y) - nl < min_leaf:
            continue
        yl, yr = y[mask], y[~mask]
        score = nl * yl.var() + (len(y) - nl) * yr.var()
        if best is None or score < best[0]:
            best = (score, f, t, mask)
    if best is None:
        return node
    _, f, t, mask = best
    node.feature, node.thresh = f, t
    node.left = _build(x[mask], y[mask], rng, depth + 1, max_depth,
                       min_leaf, n_trials)
    node.right = _build(x[~mask], y[~mask], rng, depth + 1, max_depth,
                        min_leaf, n_trials)
    return node


def _predict_one(node: _Node, row: np.ndarray) -> float:
    while node.feature >= 0:
        node = node.left if row[node.feature] <= node.thresh else node.right
    return node.value


class RandomForest:
    def __init__(self, n_trees: int = 24, max_depth: int = 8,
                 min_leaf: int = 2, n_trials: int = 12, seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.n_trials = n_trials
        self.seed = seed
        self._trees: List[_Node] = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RandomForest":
        rng = np.random.default_rng(self.seed)
        self._trees = []
        n = len(y)
        for _ in range(self.n_trees):
            idx = rng.integers(0, n, size=n)  # bootstrap
            self._trees.append(_build(x[idx], y[idx], rng, 0, self.max_depth,
                                      self.min_leaf, self.n_trials))
        return self

    def predict(self, xq: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        preds = np.stack([[_predict_one(t, row) for row in xq]
                          for t in self._trees])
        return preds.mean(axis=0), preds.std(axis=0) + 1e-9
