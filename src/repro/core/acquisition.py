"""Acquisition machinery: expected improvement + the λ-gated warm/cold
combination (eqs. 5-7 of the paper).

    EI(o)  = E[max(y* - y, 0)]                       (minimization)
    λ(o)   = 1( EI*_warm - EI_warm(o) <= l_α )        (l_α = 0.1, normalized)
    α(o)   = λ(o) · EI_cold(o) + (1 - λ(o)) · EI_warm(o)

λ gates per configuration: near the warm optimum (within l_α of the best
warm score after [0,1] normalization) the target model decides; elsewhere
the source knowledge drives.  EI scores are normalized before the gate so
l_α is scale-free across objectives.
"""

from __future__ import annotations

import math
from typing import Tuple

import numpy as np

_SQRT2 = math.sqrt(2.0)


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / _SQRT2))


def expected_improvement(mu: np.ndarray, sd: np.ndarray, best: float,
                         xi: float = 0.0) -> np.ndarray:
    """EI for minimization."""
    sd = np.maximum(sd, 1e-12)
    z = (best - xi - mu) / sd
    return (best - xi - mu) * _norm_cdf(z) + sd * _norm_pdf(z)


def _normalize(a: np.ndarray) -> np.ndarray:
    lo, hi = float(a.min()), float(a.max())
    if hi - lo < 1e-15:
        return np.zeros_like(a)
    return (a - lo) / (hi - lo)


def combined_acquisition(ei_warm: np.ndarray, ei_cold: np.ndarray,
                         l_alpha: float = 0.1
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (α, λ) over the candidate set."""
    w = _normalize(ei_warm)
    c = _normalize(ei_cold)
    lam = (w.max() - w <= l_alpha).astype(np.float64)
    alpha = lam * c + (1.0 - lam) * w
    return alpha, lam
