"""CAMEO — Causal Multi-Environment Optimization (the paper's contribution).

Pipeline (Fig. 6 of the paper):

  knowledge extraction   discovery.fci_lite -> ace.rank_by_ace ->
                         markov_blanket.top_k_blanket (reduced space)
  knowledge update       cgp.CausalGP (warm on reduced space, cold on full)
                         acquisition.combined_acquisition (λ-gated EI)
                         epsilon.observation_epsilon (obs/intervene trade-off)
  Algorithm 1            cameo.Cameo

Baselines (SMAC / CELLO / Unicorn / ResTune / ResTune-w/o-ML) share the
tuner interface in ``baselines.py``; environments live in ``repro.envs``.
"""

from repro.core.spaces import ConfigSpace, Option  # noqa: F401
from repro.core.discovery import CausalGraph, fci_lite  # noqa: F401
from repro.core.ace import rank_by_ace, choose_k  # noqa: F401
from repro.core.markov_blanket import top_k_blanket  # noqa: F401
from repro.core.cameo import Cameo, Dataset  # noqa: F401
from repro.core.query import parse_query, Query  # noqa: F401
from repro.core.baselines import make_baseline  # noqa: F401
