"""Observation / intervention trade-off (eq. 8 of the paper, from CBO).

    ε = Vol(H(D_v)) / Vol(domain)  ×  N / N_max

When the observational data covers little of the domain (small hull) or we
still have observation budget, observing is cheap and informative; once the
hull saturates, interventions take over.

Hull volume: exact convex hulls are exponential in dimension and the paper's
spaces are 10-30 dimensional with a few hundred points — we use the standard
axis-aligned product bound Vol(H) ≈ Π_d (max_d - min_d), normalized per
dimension so the domain volume is 1.  (Documented approximation; monotone in
coverage, which is the property ε needs.)
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def hull_volume_fraction(x_unit: np.ndarray) -> float:
    """x_unit: (n, d) points already normalized to the unit cube.

    Bounding-box product damped by the expected hull-to-box ratio of n
    points in d dimensions (~(1 - d/n)^d): the convex hull of few points in
    many dimensions is a vanishing fraction of their bounding box, and the
    box alone saturates to 1 almost immediately for d >= 8.
    """
    if len(x_unit) < 2:
        return 0.0
    n, d = x_unit.shape
    rng = x_unit.max(axis=0) - x_unit.min(axis=0)
    box = float(np.prod(np.clip(rng, 0.0, 1.0)))
    shrink = max(0.0, 1.0 - d / n) ** d
    return box * shrink


def observation_epsilon(x_unit: np.ndarray, n_obs: int, n_max: int) -> float:
    if n_max <= 0:
        return 0.0
    vol = hull_volume_fraction(x_unit)
    return float(np.clip(vol * (n_obs / n_max), 0.0, 1.0))
