"""User-query translation (the paper's query engine).

Accepts requests like
    "How to improve latency within 1 hour or 50 samples"
    "find the configuration with minimum energy for which latency is less
     than 20 seconds within 45 minutes"
and extracts (objective, budget, constraints) with fixed guided keyword
directives, exactly as described in Sec. 3.3.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_OBJECTIVES = ("latency", "energy", "throughput", "step_time", "cost")
_MAXIMIZE = {"throughput"}

_TIME_UNITS = {
    "second": 1.0, "seconds": 1.0, "sec": 1.0, "s": 1.0,
    "minute": 60.0, "minutes": 60.0, "min": 60.0,
    "hour": 3600.0, "hours": 3600.0, "hr": 3600.0, "h": 3600.0,
}


@dataclass
class Query:
    objective: str
    maximize: bool = False
    budget_samples: Optional[int] = None
    budget_seconds: Optional[float] = None
    constraints: List[Tuple[str, str, float]] = field(default_factory=list)
    # (metric, op in {"<", ">"}, value)

    def satisfies(self, metrics: Dict[str, float]) -> bool:
        for metric, op, val in self.constraints:
            got = metrics.get(metric)
            if got is None:
                return False
            if op == "<" and not got < val:
                return False
            if op == ">" and not got > val:
                return False
        return True


def parse_query(text: str) -> Query:
    t = text.lower()

    # objective: first objective keyword not inside a constraint clause
    constraint_spans = []
    constraints: List[Tuple[str, str, float]] = []
    for m in re.finditer(
            r"(\w+)\s+(?:is\s+)?(less|greater|lower|higher|below|above)"
            r"(?:\s+than)?\s+([0-9.]+)", t):
        metric, rel, val = m.group(1), m.group(2), float(m.group(3))
        if metric in _OBJECTIVES:
            op = "<" if rel in ("less", "lower", "below") else ">"
            constraints.append((metric, op, val))
            constraint_spans.append(m.span())

    objective = None
    for m in re.finditer("|".join(_OBJECTIVES), t):
        if any(a <= m.start() < b for a, b in constraint_spans):
            continue
        objective = m.group(0)
        break
    if objective is None:
        raise ValueError(f"no objective keyword found in query: {text!r}")

    q = Query(objective=objective, maximize=objective in _MAXIMIZE,
              constraints=constraints)

    # budget clauses must not match inside constraint clauses ("less than
    # 20 seconds" is a latency bound, not a time budget)
    budget_text = list(t)
    for a, b in constraint_spans:
        b = min(len(t), b + 16)  # swallow the trailing unit too
        for i in range(a, b):
            budget_text[i] = " "
    budget_text = "".join(budget_text)

    m = re.search(r"(\d+)\s*(?:samples|configurations|configs|evaluations|iterations)",
                  budget_text)
    if m:
        q.budget_samples = int(m.group(1))
    for m in re.finditer(r"([0-9.]+)\s*(hours?|hrs?|h\b|minutes?|min\b|seconds?|secs?|s\b)",
                         budget_text):
        unit = m.group(2).strip()
        for k, mult in _TIME_UNITS.items():
            if unit.startswith(k[:3]):
                q.budget_seconds = float(m.group(1)) * mult
                break
        break
    return q
