"""Baseline tuners: SMAC, CELLO, Unicorn, ResTune, ResTune-w/o-ML (+ random
search).

Faithful algorithmic re-implementations at the level the paper compares on
(surrogate + acquisition + transfer mechanism), sharing one ``run(env,
budget)`` interface with CAMEO:

- SMAC            — sequential model-based optimization: random-forest
                    surrogate + EI, interleaved random configs.
- ResTune-w/o-ML  — GP-BO learned from scratch in the target.
- ResTune         — meta-learning ensemble: source GP + target GP combined
                    with ranking-accuracy weights on target observations.
- CELLO           — GP-BO with predictive early termination (censored
                    observations at reduced budget cost).
- Unicorn         — transfers the source causal model *directly* (no
                    Markov-blanket pruning) and fits its surrogate on pooled
                    source+target data, updating actively; the source bias
                    must be unlearned, which is the contrast CAMEO's
                    two-model design removes.

All baselines treat infeasible measurements as +inf (constraint handling is
shared through the environment/query, as in the paper).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.acquisition import expected_improvement
from repro.core.cameo import Dataset
from repro.core.cgp import CausalGP
from repro.core.discovery import fci_lite
from repro.core.forest import RandomForest
from repro.core.gp import fit_gp, gp_predict
from repro.core.markov_blanket import top_k_blanket
from repro.core.ace import rank_by_ace
from repro.core.spaces import ConfigSpace
from repro.obs import trace as obs_trace


@dataclass
class Trace:
    best_y: List[float] = field(default_factory=list)
    spent: List[float] = field(default_factory=list)


def _finite_best(ys: np.ndarray) -> float:
    f = ys[np.isfinite(ys)]
    return float(f.min()) if len(f) else math.inf


def _clean(ys: np.ndarray) -> np.ndarray:
    """Replace inf (infeasible) with a pessimistic finite value for fitting."""
    f = ys[np.isfinite(ys)]
    worst = float(f.max()) if len(f) else 1.0
    return np.where(np.isfinite(ys), ys, worst + abs(worst) + 1.0)


class BaseTuner:
    name = "base"

    def __init__(self, space: ConfigSpace, seed: int = 0,
                 candidates: int = 256, init_random: int = 5):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.cand_n = candidates
        self.init_random = init_random
        self.xs: List[Dict] = []
        self.ys: List[float] = []
        self.trace = Trace()
        self._round_idx = 0  # ask/tell rounds so far (introspection only)

    # -- subclass hooks ---------------------------------------------------

    def _fit(self, x: np.ndarray, y: np.ndarray) -> None:
        raise NotImplementedError

    def _score(self, xq: np.ndarray, best: float) -> np.ndarray:
        raise NotImplementedError

    # -- shared ask/tell loop ---------------------------------------------

    def _config_key(self, config: Dict) -> tuple:
        return tuple(config.get(o.name, o.default)
                     for o in self.space.options)

    def ask(self, k: int = 1) -> List[Dict]:
        """Propose a q-batch of ``k`` configurations for one round.

        ``ask(1)`` is the historical :meth:`propose` exactly (same RNG
        stream, same argmax winner).  For ``k > 1`` the surrogate is fit
        ONCE and the candidate pool scored ONCE; the round is the top-k
        distinct candidates by acquisition — the measurements are where
        baselines pay, not proposal diversity, so a simple truncated
        ranking is the faithful batched analogue of their greedy argmax.
        """
        self._round_idx += 1
        if len(self.ys) < self.init_random:
            picks = self.space.sample(self.rng, k)
            obs_trace.tuner_event("ask", tuner=self.name,
                                  round=self._round_idx, k=k,
                                  cold_start=True)
            return picks
        x = np.stack([self.space.encode(c) for c in self.xs])
        y = _clean(np.asarray(self.ys))
        self._fit(x, y)
        cands = self.space.sample(self.rng, self.cand_n)
        if np.isfinite(_finite_best(np.asarray(self.ys))):
            i = int(np.argmin(_clean(np.asarray(self.ys))))
            cands.extend(self.space.neighbors(self.xs[i], self.rng, 16))
        xq = np.stack([self.space.encode(c) for c in cands])
        scores = np.asarray(
            self._score(xq, _finite_best(np.asarray(self.ys))))
        # stable descending sort: the top-1 is np.argmax's first-max winner,
        # preserving k=1 parity with the historical propose()
        order = np.argsort(-scores, kind="stable")
        picks: List[Dict] = []
        seen = set()
        for idx in order:
            key = self._config_key(cands[int(idx)])
            if key in seen:
                continue
            seen.add(key)
            picks.append(cands[int(idx)])
            if len(picks) >= k:
                break
        if obs_trace.enabled():
            obs_trace.tuner_event(
                "ask", tuner=self.name, round=self._round_idx, k=k,
                n_candidates=len(cands),
                acq_max=float(np.max(scores)),
                acq_mean=float(np.mean(scores)),
                picks=[dict(p) for p in picks])
        return picks

    def propose(self) -> Dict:
        return self.ask(1)[0]

    def update(self, config: Dict, counters: Dict, y: float) -> None:
        self.xs.append(dict(config))
        self.ys.append(float(y))

    def tell(self, configs: Sequence[Dict], counters: Sequence[Dict],
             ys: Sequence[float]) -> None:
        """Absorb one round of measurements (the batched dual of ask)."""
        for cfg, cnt, y in zip(configs, counters, ys):
            self.update(cfg, cnt, y)
        if obs_trace.enabled():
            finite = [float(y) for y in ys if np.isfinite(y)]
            obs_trace.tuner_event(
                "tell", tuner=self.name, round=self._round_idx,
                told=len(list(configs)),
                best_y=_finite_best(np.asarray(self.ys)),
                round_best=(min(finite) if finite else None))

    def run(self, env, budget: float, query_batch: int = 1,
            round_log: Optional[List[Dict[str, Any]]] = None
            ) -> Tuple[Dict, float]:
        spent = 0.0
        while spent < budget:
            k = min(max(int(query_batch), 1),
                    max(int(math.ceil(budget - spent)), 1))
            # repro: ignore[wall-clock] -- per-round wall_s telemetry only; never feeds seeded decisions
            t0 = time.perf_counter()
            cfgs = self.ask(k)
            if len(cfgs) > 1 and hasattr(env, "intervene_batch"):
                results = env.intervene_batch(cfgs)
            else:
                results = [env.intervene(c) for c in cfgs]
            for cfg, (counters, y) in zip(cfgs, results):
                self.update(cfg, counters, y)
                spent += 1.0
                self.trace.best_y.append(_finite_best(np.asarray(self.ys)))
                self.trace.spent.append(spent)
            if round_log is not None:
                round_log.append({
                    "size": len(cfgs),
                    "actions": ["intervene"] * len(cfgs),
                    # repro: ignore[wall-clock] -- per-round wall_s telemetry only; never feeds seeded decisions
                    "wall_s": round(time.perf_counter() - t0, 4)})
        return self.best

    @property
    def best(self) -> Tuple[Optional[Dict], float]:
        ys = np.asarray(self.ys)
        if not len(ys) or not np.isfinite(ys).any():
            return None, math.inf
        i = int(np.argmin(_clean(ys)))
        return self.xs[i], float(ys[i])


class RandomSearch(BaseTuner):
    name = "random"

    def ask(self, k: int = 1) -> List[Dict]:
        self._round_idx += 1
        picks = self.space.sample(self.rng, k)
        obs_trace.tuner_event("ask", tuner=self.name, round=self._round_idx,
                              k=k, n_candidates=k)
        return picks


class SMAC(BaseTuner):
    """Random-forest surrogate + EI (Hutter et al. 2011)."""
    name = "smac"

    def _fit(self, x, y):
        self._rf = RandomForest(seed=int(self.rng.integers(1 << 31))).fit(x, y)

    def _score(self, xq, best):
        mu, sd = self._rf.predict(xq)
        return expected_improvement(mu, sd, best)


class ResTuneWoML(BaseTuner):
    """GP-BO from scratch in the target (ResTune without meta-learning)."""
    name = "restune-w/o-ml"

    def _fit(self, x, y):
        self._gp = fit_gp(x, y)

    def _score(self, xq, best):
        mu, sd = gp_predict(self._gp, xq)
        return expected_improvement(np.asarray(mu), np.asarray(sd), best)


class ResTune(ResTuneWoML):
    """Meta-learning ensemble (Zhang et al. 2021): source GP + target GP,
    weighted by ranking accuracy on the target observations."""
    name = "restune"

    def __init__(self, space: ConfigSpace, source_data: Dataset,
                 seed: int = 0, **kw):
        super().__init__(space, seed=seed, **kw)
        xs = np.stack([space.encode(c) for c in source_data.configs])
        ys = _clean(np.asarray(source_data.ys, np.float64))
        self._src_gp = fit_gp(xs, ys)

    def _rank_weight(self, x, y) -> float:
        """Fraction of correctly-ordered pairs by the source model."""
        mu, _ = gp_predict(self._src_gp, x)
        mu = np.asarray(mu)
        n = len(y)
        if n < 2:
            return 0.5
        correct = total = 0
        for i in range(n):
            for j in range(i + 1, n):
                if abs(y[i] - y[j]) < 1e-12:
                    continue
                total += 1
                if (mu[i] < mu[j]) == (y[i] < y[j]):
                    correct += 1
        return correct / total if total else 0.5

    def _fit(self, x, y):
        super()._fit(x, y)
        self._w_src = max(0.0, 2.0 * self._rank_weight(x, y) - 1.0)

    def _score(self, xq, best):
        mu_t, sd_t = gp_predict(self._gp, xq)
        mu_s, sd_s = gp_predict(self._src_gp, xq)
        w = self._w_src
        mu = (1 - w) * np.asarray(mu_t) + w * np.asarray(mu_s)
        sd = np.sqrt((1 - w) * np.asarray(sd_t) ** 2 + w * np.asarray(sd_s) ** 2)
        return expected_improvement(mu, sd, best)


class Cello(ResTuneWoML):
    """GP-BO with predictive early termination (Ding et al. 2022): when the
    surrogate is confident a running measurement is worse than the
    incumbent, terminate it early — a censored observation at reduced
    budget cost."""
    name = "cello"

    def __init__(self, space: ConfigSpace, seed: int = 0,
                 terminate_z: float = 1.0, partial_cost: float = 0.5, **kw):
        super().__init__(space, seed=seed, **kw)
        self.terminate_z = terminate_z
        self.partial_cost = partial_cost

    def run(self, env, budget: float, query_batch: int = 1,
            round_log: Optional[List[Dict[str, Any]]] = None
            ) -> Tuple[Dict, float]:
        if query_batch > 1:
            # early termination is a per-measurement (sequential) mechanism:
            # the surrogate must see each result before pricing the next.
            # Batched rounds fall back to plain GP-BO at full cost.
            return super().run(env, budget, query_batch, round_log)
        spent = 0.0
        while spent < budget:
            # repro: ignore[wall-clock] -- per-round wall_s telemetry only; never feeds seeded decisions
            t0 = time.perf_counter()
            cfg = self.propose()
            cost = 1.0
            if len(self.ys) >= self.init_random:
                x = np.stack([self.space.encode(c) for c in self.xs])
                y = _clean(np.asarray(self.ys))
                self._fit(x, y)
                mu, sd = gp_predict(self._gp,
                                    self.space.encode(cfg)[None, :])
                best = _finite_best(np.asarray(self.ys))
                if float(mu[0]) - self.terminate_z * float(sd[0]) > best:
                    # early-terminate: censored lower-bound observation
                    counters, yy = env.intervene(cfg)
                    censored = max(yy if np.isfinite(yy) else best * 2,
                                   best * 1.02)
                    self.update(cfg, counters, censored)
                    spent += self.partial_cost
                    self.trace.best_y.append(_finite_best(np.asarray(self.ys)))
                    self.trace.spent.append(spent)
                    if round_log is not None:
                        round_log.append({
                            "size": 1, "actions": ["intervene"],
                            # repro: ignore[wall-clock] -- per-round wall_s telemetry only; never feeds seeded decisions
                            "wall_s": round(time.perf_counter() - t0, 4)})
                    continue
            counters, yy = env.intervene(cfg)
            self.update(cfg, counters, yy)
            spent += cost
            self.trace.best_y.append(_finite_best(np.asarray(self.ys)))
            self.trace.spent.append(spent)
            if round_log is not None:
                round_log.append({
                    "size": 1, "actions": ["intervene"],
                    # repro: ignore[wall-clock] -- per-round wall_s telemetry only; never feeds seeded decisions
                    "wall_s": round(time.perf_counter() - t0, 4)})
        return self.best


class Unicorn(BaseTuner):
    """Causal-model transfer without blanket pruning (Iqbal et al. 2022):
    the source graph is reused wholesale; the surrogate is a CausalGP over
    the source graph's full objective-blanket, fit on pooled source+target
    data (the bias CAMEO's warm/cold split avoids)."""
    name = "unicorn"

    def __init__(self, space: ConfigSpace, source_data: Dataset,
                 counter_names: Sequence[str] = (), seed: int = 0, **kw):
        super().__init__(space, seed=seed, **kw)
        self.src = source_data
        data_s, names_s = source_data.matrix(space, list(counter_names))
        self.g_s = fci_lite(data_s, names_s)
        mb = self.g_s.markov_blanket("__objective__")
        ranked = rank_by_ace(data_s, names_s, "__objective__", self.g_s)
        feats = [n for n in space.names if n in mb]
        if not feats:
            feats = [n for n, _ in ranked if n in space.by_name][:4]
        self.features = feats

    def _fit(self, x, y):
        # pooled source+target (source bias included by design)
        xs = np.stack([self.space.encode(c) for c in self.src.configs])
        ys = _clean(np.asarray(self.src.ys, np.float64))
        cfgs = self.src.configs + self.xs
        yall = np.concatenate([ys, y])
        self._cgp = CausalGP(self.space, self.features).fit(cfgs, yall)

    def _score(self, xq, best):
        cands = [self.space.decode(row) for row in xq]
        mu, sd = self._cgp.predict(cands)
        return expected_improvement(mu, sd, best)


def make_baseline(name: str, space: ConfigSpace, source_data: Dataset,
                  counter_names: Sequence[str] = (), seed: int = 0):
    if name == "smac":
        return SMAC(space, seed=seed)
    if name == "cello":
        return Cello(space, seed=seed)
    if name == "restune-w/o-ml":
        return ResTuneWoML(space, seed=seed)
    if name == "restune":
        return ResTune(space, source_data, seed=seed)
    if name == "unicorn":
        return Unicorn(space, source_data, counter_names=counter_names,
                       seed=seed)
    if name == "random":
        return RandomSearch(space, seed=seed)
    raise ValueError(name)
