"""repro — a production-grade JAX training/serving framework with CAMEO
(causal transfer-learning performance optimization) as a first-class
feature."""

from repro.models.model import build_model, count_params_analytic  # noqa: F401
from repro.train.optimizer import Optimizer, make_optimizer  # noqa: F401
from repro.train.train_step import (  # noqa: F401
    TrainState, init_train_state, make_train_step)
from repro.train.serve_step import (  # noqa: F401
    ServeState, generate, make_decode_step, make_prefill_step)
from repro.utils.config import (  # noqa: F401
    MeshConfig, ModelConfig, ParallelConfig, RunConfig, ShapeConfig,
    TrainConfig)
from repro.utils.hardware import TPU_V4_LIKE, TPU_V5E, HardwareSpec  # noqa: F401
