"""Atomic, async, keep-k checkpoint manager with cross-mesh restore.

Production properties this implements:

- **Atomicity** — a checkpoint is written into ``step_N.tmp.<pid>`` and
  renamed to ``step_N`` only after every array and the metadata manifest are
  flushed; a crash mid-save can never leave a readable-but-corrupt latest
  checkpoint (the restart scans only completed directories).
- **Async save** — ``save()`` snapshots device arrays to host (blocking only
  for the device->host copy) and hands serialization to a background thread,
  overlapping checkpoint I/O with the next training steps. ``wait()`` joins.
- **Keep-k GC** — old checkpoints are deleted only after a newer one is
  durable.
- **Cross-mesh restore (elastic scaling)** — ``restore(..., shardings=)``
  device_puts every leaf with the *target* sharding, so a checkpoint written
  on a 512-chip mesh restores onto a 256-chip mesh (or any other reshape)
  without a resharding job.
- **Integrity** — each leaf records shape/dtype in the manifest; mismatches
  fail loudly at restore instead of silently reinterpreting bytes.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.utils.trees import flatten_with_paths

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save --------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot `tree` (pytree of arrays) at `step` and persist it."""
        self.wait()  # one outstanding save at a time
        flat = flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device -> host
        meta = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "extra": extra or {},
        }

        def _write():
            tmp = os.path.join(self.directory, f"step_{step}.tmp.{os.getpid()}")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            try:
                for k, v in host.items():
                    fn = os.path.join(tmp, _leaf_file(k))
                    with open(fn, "wb") as f:
                        # numpy can't serialize ml_dtypes (bf16/fp8): store
                        # the raw bits; the manifest dtype restores the view
                        if v.dtype.kind == "V" or "bfloat16" in str(v.dtype) \
                                or "float8" in str(v.dtype):
                            np.save(f, v.view(
                                f"u{v.dtype.itemsize}" if v.dtype.itemsize in (1, 2)
                                else "u2"))
                        else:
                            np.save(f, v)
                        f.flush()
                        os.fsync(f.fileno())
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(meta, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)  # the atomic commit point
                self._gc()
            # repro: ignore[broad-except] -- async writer thread: failure is stored and re-raised on the next wait()/save()
            except BaseException as e:  # surfaced on next wait()/save()
                self._error = e
                shutil.rmtree(tmp, ignore_errors=True)

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- restore -----------------------------------------------------------

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `target` (pytree of arrays or
        ShapeDtypeStructs). `shardings`: matching pytree of Shardings (or
        None) — this is where elastic re-meshing happens."""
        self.wait()
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)

        flat_target = flatten_with_paths(target)
        flat_shardings = (flatten_with_paths(shardings)
                          if shardings is not None else {})
        out: Dict[str, Any] = {}
        for k, spec in flat_target.items():
            if k not in meta["leaves"]:
                raise KeyError(f"checkpoint {step} missing leaf {k}")
            rec = meta["leaves"][k]
            arr = np.load(os.path.join(d, _leaf_file(k)))
            if str(arr.dtype) != rec["dtype"]:
                # bit-stored ml_dtypes leaf: reinterpret via the manifest
                import ml_dtypes  # noqa: F401  (registers the dtypes)
                arr = arr.view(np.dtype(rec["dtype"]))
            if list(arr.shape) != rec["shape"] or str(arr.dtype) != rec["dtype"]:
                raise ValueError(f"leaf {k}: manifest/file mismatch")
            if tuple(arr.shape) != tuple(spec.shape):
                raise ValueError(
                    f"leaf {k}: checkpoint shape {arr.shape} != target {spec.shape}")
            sh = flat_shardings.get(k)
            out[k] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

        leaves_in_order = []
        paths = jax.tree_util.tree_flatten_with_path(target)[0]
        treedef = jax.tree_util.tree_structure(target)
        from repro.utils.trees import _path_str
        for path, _ in paths:
            key = "/".join(_path_str(p) for p in path)
            leaves_in_order.append(out[key])
        return jax.tree_util.tree_unflatten(treedef, leaves_in_order)

    def restore_extra(self, step: int) -> Dict:
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)["extra"]

    # -- gc ------------------------------------------------------------------

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)


def _leaf_file(key: str) -> str:
    return key.replace("/", "__") + ".npy"
