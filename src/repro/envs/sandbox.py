"""Ground-truth SCM sandbox — the paper's swappiness/dirty_ratio/IPC example
(Sec. 2.1, Fig. 2), as an executable structural causal model.

Mechanisms (per environment e):

    swappiness  S ~ config option        (true cause, invariant mechanism)
    dirty_ratio R ~ config option        (true cause, small invariant effect)
    IPC         I = a_e + b_e * S + P_e(R) + noise   (ENV-DEPENDENT: the
                                         direction b_e flips with memory size)
    latency     Y = c*S + d*R' + e*sched + noise     (invariant mechanism)

Latency's structural equation never changes across environments — only the
IPC mechanism does (small memory: page flushing makes IPC *fall* as
swappiness rises; large memory: IPC *rises* with it).  An ML regressor that
leans on the IPC shortcut is poisoned after the shift (Table 2); the causal
model conditions on the invariant parents of Y and is unaffected — exactly
the paper's Fig. 2 narrative.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.spaces import ConfigSpace, Option
from repro.envs.base import PooledEnv


def sandbox_space() -> ConfigSpace:
    return ConfigSpace([
        Option("swappiness", (10, 30, 50, 60, 80, 90), default=60),
        Option("dirty_ratio", (5, 10, 20, 35, 50), default=20),
        Option("vfs_cache_pressure", (1, 100, 500), default=100),  # inert
        Option("sched_latency", (6, 12, 24, 48), default=24),      # weak
    ])


class SandboxSCMEnv(PooledEnv):
    """One environment of the sandbox SCM. env_memory in {small, large}."""

    counter_names = ("ipc", "major_faults")

    def __init__(self, env_memory: str = "small", noise: float = 0.15,
                 seed: int = 0):
        super().__init__(sandbox_space(), self.counter_names, seed=seed)
        self.env_memory = env_memory
        self.noise = noise
        self._rng = np.random.default_rng(seed + 1)

    @staticmethod
    def _latency_mean(s, r, sched):
        """The INVARIANT structural equation for the objective."""
        return 6.0 + 7.0 * s + 1.4 * max(0.0, 0.5 - r) + 0.6 * sched

    def _measure(self, config) -> Tuple[Dict[str, float], float]:
        s = float(config["swappiness"]) / 100.0
        r = float(config["dirty_ratio"]) / 50.0
        sched = float(config["sched_latency"]) / 48.0
        rng = self._rng

        if self.env_memory == "small":
            # small memory: aggressive swapping busy-spins reclaim work, so
            # IPC RISES with swappiness while the app stalls (corr(I,Y) > 0)
            ipc = (0.6 + 2.2 * s + 0.9 * max(0.0, 0.5 - r)
                   + self.noise * rng.standard_normal())
            faults = 30.0 * max(0.0, 0.5 - r) + 8.0 * s \
                + 2.0 * rng.standard_normal()
        else:
            # large memory: reclaim never runs; higher swappiness just idles
            # the prefetcher -> IPC FALLS with it (corr(I,Y) < 0): the flip
            ipc = (2.6 - 2.2 * s + 0.1 * max(0.0, 0.5 - r)
                   + self.noise * rng.standard_normal())
            faults = 2.0 * max(0.0, 0.5 - r) + 1.0 * s \
                + 2.0 * rng.standard_normal()
        latency = (self._latency_mean(s, r, sched)
                   + self.noise * rng.standard_normal())
        return {"ipc": float(ipc), "major_faults": float(faults)}, float(latency)

    def optimum(self) -> float:
        """Best achievable mean latency over the grid (noise-free)."""
        best = np.inf
        for cfg in self.space.grid():
            s = float(cfg["swappiness"]) / 100.0
            r = float(cfg["dirty_ratio"]) / 50.0
            sched = float(cfg["sched_latency"]) / 48.0
            best = min(best, self._latency_mean(s, r, sched))
        return float(best)


def make_sandbox_pair(seed: int = 0) -> Tuple[SandboxSCMEnv, SandboxSCMEnv]:
    """(source=small-memory TX2-like, target=large-memory Xavier-like)."""
    return (SandboxSCMEnv("small", seed=seed),
            SandboxSCMEnv("large", seed=seed + 100))
