"""Kernel-launch tuning environment: the dispatch registry's launch space as
a CAMEO performance environment.

The configuration options are exactly the launch parameters the unified
dispatch layer (:mod:`repro.kernels.dispatch`) hands to the Pallas kernels —
block sizes and chunk lengths, prefixed ``family.param``.  Measurement is
delegated to a :class:`repro.envs.measure.MeasurementBackend`:

- ``analytic`` (default) — the launch-geometry model (grid extent, VMEM
  block footprints, streamed HBM bytes, per-step launch overhead), so the
  tradeoffs are the real ones:

  * larger blocks amortize grid/launch overhead but pad more of the sequence
    and eventually overflow the per-core VMEM budget (infeasible -> the
    tuner's constraint-handling path);
  * the SSD chunk trades quadratic intra-chunk FLOPs against the length of
    the sequential inter-chunk chain — a genuine interior optimum;
  * alignment to the 128-wide lane dimension changes MXU utilization.

- ``wallclock`` — real timed execution: each family is dispatched through
  the registry (pallas on TPU, interpret/ref on CPU per
  ``REPRO_KERNEL_MODE``) and the median of k repeats is the measurement.

- ``shifted:<kind>`` — the analytic model under a registered environment
  shift (``repro.envs.measure.SHIFT_KINDS``): the reproducible target side
  of a source→target transfer pair (see ``repro.tuner.bench``).

Select with the ``backend=`` constructor argument or the
``REPRO_MEASURE_BACKEND`` env var.  Counters play the role of the paper's
system events C.  A tuned optimum is deployable directly:
``dispatch.use_launch_config(best_config)`` routes every subsequently
dispatched kernel with the tuned launch parameters.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Tuple, Union

from repro.envs import measure as measure_mod
from repro.envs.base import PooledEnv
from repro.envs.measure import (  # noqa: F401  (re-exported for backcompat)
    BF16, F32, HBM_BYTES_PER_US, LANE, MXU_FLOPS_PER_US, VMEM_LIMIT_BYTES,
    VPU_FLOPS_PER_US, KernelWorkload, MeasurementBackend)
from repro.kernels import dispatch


class KernelLaunchEnv(PooledEnv):
    """PerfEnv over ``dispatch.launch_space()`` for a fixed workload.

    ``backend`` is a backend name (``"analytic"`` | ``"wallclock"``), an
    object satisfying :class:`~repro.envs.measure.MeasurementBackend`, or
    ``None`` (the ``REPRO_MEASURE_BACKEND`` env var, default analytic).
    ``backend_opts`` are forwarded to the backend constructor (e.g.
    ``repeats``/``clock`` for wallclock).
    """

    counter_names = measure_mod.COUNTER_NAMES

    def __init__(self, workload: Optional[KernelWorkload] = None,
                 families: Optional[Iterable[str]] = None, seed: int = 0,
                 backend: Union[None, str, MeasurementBackend] = None,
                 backend_opts: Optional[Dict[str, Any]] = None):
        self.workload = workload or KernelWorkload()
        if isinstance(backend, (str, type(None))):
            if families is None:
                # the registry is open; model only the families we have a
                # geometry model for (newly registered families need one
                # added)
                modeled = measure_mod.modeled_families()
                families = [f for f in dispatch.families() if f in modeled]
            self.families = sorted(families)
            self.backend: MeasurementBackend = measure_mod.make_backend(
                backend, self.workload, self.families, seed,
                **(backend_opts or {}))
        else:
            if backend_opts:
                raise ValueError(
                    "backend_opts only apply when the backend is built here; "
                    "pass a configured backend instance instead")
            # the instance is authoritative: its families define the tuning
            # space and its counter_names the counter schema
            self.backend = backend
            self.families = sorted(backend.families)
            if families is not None and sorted(families) != self.families:
                raise ValueError(
                    f"families {sorted(families)} conflict with the backend "
                    f"instance's {self.families}; pass one or the other")
        super().__init__(dispatch.launch_space(self.families),
                         tuple(self.backend.counter_names), seed=seed)

    def _measure(self, config: Dict[str, Any]) -> Tuple[Dict[str, float], float]:
        return self.backend.measure(config)

    def intervene_batch(self, configs):
        """Route a q-batch through the backend's ``measure_batch`` when it
        has one (vectorized noise for analytic, shared jit cache + shared
        timings for wallclock); otherwise the sequential default."""
        batch = getattr(self.backend, "measure_batch", None)
        if batch is None:
            return super().intervene_batch(configs)
        results = batch(list(configs))
        for cfg, (counters, y) in zip(configs, results):
            self._remember(cfg, counters, y)
        return results

    # -- deployment -----------------------------------------------------

    def apply(self, config: Dict[str, Any]):
        """Context manager installing ``config`` on the dispatch registry —
        the measured optimum is the deployed launch configuration."""
        return dispatch.use_launch_config(config)
