"""Kernel-launch tuning environment: the dispatch registry's launch space as
a CAMEO performance environment.

The configuration options are exactly the launch parameters the unified
dispatch layer (:mod:`repro.kernels.dispatch`) hands to the Pallas kernels —
block sizes and chunk lengths, prefixed ``family.param``.  The measurement is
an analytic launch-geometry model built from the same quantities the real
kernels derive from those parameters (grid extent, VMEM block footprints,
streamed HBM bytes, per-step launch overhead), so the tradeoffs are the real
ones:

- larger blocks amortize grid/launch overhead but pad more of the sequence
  and eventually overflow the per-core VMEM budget (infeasible -> the
  tuner's constraint-handling path);
- the SSD chunk trades quadratic intra-chunk FLOPs against the length of the
  sequential inter-chunk chain — a genuine interior optimum;
- alignment to the 128-wide lane dimension changes MXU utilization.

Counters play the role of the paper's system events C.  A tuned optimum is
deployable directly: ``dispatch.use_launch_config(best_config)`` routes every
subsequently dispatched kernel with the tuned launch parameters.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

from repro.envs.base import PooledEnv
from repro.kernels import dispatch

LANE = 128
VMEM_LIMIT_BYTES = 12 * 2 ** 20   # per-core block budget the model enforces
MXU_FLOPS_PER_US = 200e6          # ~bf16 peak of one v5e-class core
VPU_FLOPS_PER_US = 4e6
HBM_BYTES_PER_US = 0.8e6          # ~819 GB/s
F32 = 4                           # scratch accumulators
BF16 = 2                          # streamed in/out blocks


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _padded(a: int, b: int) -> int:
    return _ceil_div(a, b) * b


def _mxu_util(*block_dims: int) -> float:
    """Fraction of the MXU filled by a tile: 1.0 at lane-aligned >=128."""
    u = 1.0
    for d in block_dims:
        u *= min(d, LANE) / LANE
    return max(u, 1e-3)


@dataclass(frozen=True)
class KernelWorkload:
    """One (model shape x batch) cell the kernels run under."""

    name: str = "serve-8b"
    batch: int = 8
    seq_len: int = 4096
    heads: int = 32
    kv_heads: int = 8
    head_dim: int = 128
    d_model: int = 4096
    # mamba-1 surface
    channels: int = 8192
    scan_state: int = 16
    # mamba-2 surface
    ssm_heads: int = 64
    ssm_head_dim: int = 64
    ssm_state: int = 128
    vmem_limit: int = VMEM_LIMIT_BYTES
    launch_overhead_us: float = 1.5
    noise: float = 0.01


class KernelLaunchEnv(PooledEnv):
    """PerfEnv over ``dispatch.launch_space()`` for a fixed workload."""

    counter_names = ("grid_points", "vmem_peak_bytes", "hbm_bytes", "flops")

    def __init__(self, workload: Optional[KernelWorkload] = None,
                 families: Optional[Iterable[str]] = None, seed: int = 0):
        self.workload = workload or KernelWorkload()
        if families is None:
            # the registry is open; model only the families we have a
            # geometry model for (newly registered families need one added)
            families = [f for f in dispatch.families() if f in self._MODELS]
        self.families = sorted(families)
        unmodeled = [f for f in self.families if f not in self._MODELS]
        if unmodeled:
            raise ValueError(
                f"no launch-geometry model for families {unmodeled}; "
                f"modeled: {sorted(self._MODELS)}")
        super().__init__(dispatch.launch_space(self.families),
                         self.counter_names, seed=seed)
        self._noise_rng = np.random.default_rng(seed + 13)

    # -- launch-geometry model ------------------------------------------

    def _family_params(self, family: str, config: Dict[str, Any]
                       ) -> Dict[str, Any]:
        fam = dispatch.get_family(family)
        out = {o.name: o.default for o in fam.launch_options}
        for o in fam.launch_options:
            key = f"{family}.{o.name}"
            if key in config:
                out[o.name] = config[key]
        return out

    def _flash_attention(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        qb, kb = int(p["q_block"]), int(p["kv_block"])
        sq, sk = _padded(w.seq_len, qb), _padded(w.seq_len, kb)
        grid = w.batch * w.heads * (sq // qb) * (sk // kb)
        # causal: roughly half the kv blocks are visible
        flops = 0.5 * w.batch * w.heads * sq * sk * 4 * w.head_dim
        vmem = (BF16 * 2 * (qb + 2 * kb) * w.head_dim         # double-buffered in
                + BF16 * 2 * qb * w.head_dim                  # out
                + F32 * qb * (w.head_dim + 2 * LANE))         # acc/m/l scratch
        hbm = F32 * grid * (qb + 2 * kb) * w.head_dim / 2 + F32 * sq * w.head_dim
        t = (grid * w.launch_overhead_us
             + flops / (MXU_FLOPS_PER_US * _mxu_util(qb, kb))
             + hbm / HBM_BYTES_PER_US)
        return t, grid, vmem, flops, hbm

    def _mamba_scan(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        chunk, cb = int(p["chunk"]), int(p["c_block"])
        l = _padded(w.seq_len, chunk)
        grid = w.batch * _ceil_div(w.channels, cb) * (l // chunk)
        flops = 8.0 * w.batch * l * w.channels * w.scan_state
        vmem = (BF16 * 2 * chunk * (3 * cb + 2 * w.scan_state)  # in, dbl-buffered
                + BF16 * 2 * chunk * cb                          # out
                + F32 * cb * w.scan_state)                       # state scratch
        hbm = F32 * w.batch * l * (3 * w.channels + 2 * w.scan_state)
        # the recurrence is serial inside a chunk: VPU-bound step chain
        serial = grid * chunk * (cb * w.scan_state / VPU_FLOPS_PER_US) * 1e-3
        t = grid * w.launch_overhead_us + serial + hbm / HBM_BYTES_PER_US
        return t, grid, vmem, flops, hbm

    def _ssd(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        chunk = int(p["chunk"])
        l = _padded(w.seq_len, chunk)
        grid = w.batch * w.ssm_heads * (l // chunk)
        n, hd = w.ssm_state, w.ssm_head_dim
        # quadratic intra-chunk term + two state matmuls per chunk
        flops = grid * (2 * chunk * chunk * (n + hd) + 4 * chunk * n * hd)
        vmem = (BF16 * 2 * chunk * (hd + 2 * n) + BF16 * 2 * chunk * hd
                + F32 * (chunk * chunk + n * hd))
        hbm = F32 * w.batch * l * w.ssm_heads * (hd + 2 * n // max(w.ssm_heads // 8, 1))
        t = (grid * w.launch_overhead_us
             + flops / (MXU_FLOPS_PER_US * _mxu_util(chunk))
             + hbm / HBM_BYTES_PER_US)
        return t, grid, vmem, flops, hbm

    def _rmsnorm(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        rb = int(p["row_block"])
        rows = _padded(w.batch * w.seq_len, rb)
        grid = rows // rb
        flops = 4.0 * rows * w.d_model
        vmem = BF16 * (2 * 2 * rb * w.d_model + w.d_model)
        hbm = F32 * rows * w.d_model * 2
        t = grid * w.launch_overhead_us + hbm / HBM_BYTES_PER_US
        return t, grid, vmem, flops, hbm

    _MODELS = {"flash_attention": _flash_attention, "mamba_scan": _mamba_scan,
               "ssd": _ssd, "rmsnorm": _rmsnorm}

    def _measure(self, config: Dict[str, Any]) -> Tuple[Dict[str, float], float]:
        total_us, grid_pts, vmem_peak, flops, hbm = 0.0, 0.0, 0.0, 0.0, 0.0
        feasible = True
        for family in self.families:
            model = self._MODELS[family]
            t, grid, vmem, fl, hb = model(self, self._family_params(family, config))
            total_us += t
            grid_pts += grid
            vmem_peak = max(vmem_peak, vmem)
            flops += fl
            hbm += hb
            if vmem > self.workload.vmem_limit:
                feasible = False
        counters = {"grid_points": grid_pts, "vmem_peak_bytes": vmem_peak,
                    "hbm_bytes": hbm, "flops": flops}
        if not feasible:
            return counters, float("inf")
        y = total_us * (1.0 + self.workload.noise
                        * float(self._noise_rng.standard_normal()))
        return counters, y

    # -- deployment -----------------------------------------------------

    def apply(self, config: Dict[str, Any]):
        """Context manager installing ``config`` on the dispatch registry —
        the measured optimum is the deployed launch configuration."""
        return dispatch.use_launch_config(config)
