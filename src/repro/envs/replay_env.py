"""Replay-backed serving environment: the real ``ContinuousBatcher`` as the
*target* half of a sim-to-real transfer pair.

CAMEO's premise is that the source environment is a cheap stand-in for a
target where intervention is costly — and the paper validates against the
real deployment, not a second simulator.  :class:`ReplayServingEnv` closes
that loop for the serving stack: it exposes the SAME configuration surface
as :class:`repro.envs.serving_env.ServingEnv` (``serving.*`` scheduler knobs
joined with the ``family.param`` kernel-launch options), but each
measurement *deploys* the candidate — scheduler half via
``ServingEnv.plan_of``, launch half baked into the jitted steps through
``dispatch.use_launch_config`` inside the step factories — onto a freshly
constructed tiny-model batcher and replays the pinned trace through
:func:`repro.serving.replay.replay_trace`.  ``y`` is the replay's wall-clock
p99 latency (ms) or throughput (completed req/s), and the replay counters
(queue depth, occupancy, prefill/decode wall-time split, rejections) are the
discovery variables, name-compatible with the simulator's so a causal model
extracted from simulator observations transfers onto replay measurements.

Feasibility mirrors the simulator: a ``cache_len`` the trace does not fit
in, or a launch config whose modeled VMEM footprint overflows, measures as
``inf``/``-inf`` direction-aware *without* running the batcher (the same
"counters and the VMEM gate stay analytic" convention ``WallClockBackend``
uses for kernels).  A replay that stalls past the tick budget also measures
infeasible — a deployment that cannot drain its own trace is not a usable
configuration.

:func:`make_sim2real_pair` builds the canonical transfer pair: a
``ServingEnv`` (simulator = source) and a ``ReplayServingEnv`` (real batcher
= target) over the *identical* trace realization, with the simulator priced
at the kernel dimensions of the very model the batcher runs.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

from repro.envs import measure as measure_mod
from repro.envs.base import PooledEnv
from repro.envs.measure import HardwareSpec, KernelWorkload, LaunchGeometry
from repro.envs.serving_env import OBJECTIVES, ServingEnv
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.paging import PagedPlan
from repro.workloads.sim import (SIM_COUNTER_NAMES, FleetPlan, FleetReport,
                                 ServingPlan, serving_space)
from repro.workloads.traces import Trace, TraceWorkload, make_workload

# The replay-only rejection mediators, registered in the obs metrics
# registry as their own "replay" group; the discovery tuples below are
# derived group compositions (serving [+ replay] [+ fleet]) — the registry
# is the single source of truth, so sim and replay can never silently
# drift apart.  Objective clones stay out, exactly as in the sim groups.
obs_metrics.declare("rejected_rate", group="replay",
                    help="fraction of trace requests rejected at submit")
obs_metrics.declare("rejected_too_long", group="replay", kind="counter",
                    help="requests rejected because prompt+max_new "
                         "overflows the deployed shape")

#: the simulator's discovery counters plus the replay-only rejection signals
REPLAY_COUNTER_NAMES: Tuple[str, ...] = obs_metrics.discovery_names(
    "serving", "replay")

#: fleet-mode discovery counters: the replay set plus the router/straggler
#: mediators — objective clones stay out, exactly as in FLEET_COUNTER_NAMES
REPLAY_FLEET_COUNTER_NAMES: Tuple[str, ...] = obs_metrics.discovery_names(
    "serving", "replay", "fleet")


def default_replay_model():
    """A tiny dense ``ModelConfig`` cheap enough to replay traces through on
    CPU CI — the deployment stand-in :func:`make_sim2real_pair` uses unless
    the caller brings a real assignment."""
    from repro.utils.config import ModelConfig

    return ModelConfig(name="sim2real-tiny", vocab_size=64, d_model=32,
                       num_heads=4, num_kv_heads=2, d_ff=64, num_layers=2,
                       dtype="float32")


class _SmallLru:
    """A tiny explicit LRU (get refreshes recency, put evicts the oldest) —
    unlike ``functools.lru_cache`` the key set is inspectable and the store
    can be cleared in tests, and unlike an open dict it is BOUNDED, so long
    batched sweeps cycling through many deployments do not grow memory
    without limit."""

    def __init__(self, maxsize: int):
        self.maxsize = int(maxsize)
        self._store: "OrderedDict[Any, Any]" = OrderedDict()

    def get(self, key):
        if key not in self._store:
            return None
        self._store.move_to_end(key)
        return self._store[key]

    def put(self, key, value) -> None:
        if key in self._store:
            self._store.move_to_end(key)
        self._store[key] = value
        while len(self._store) > self.maxsize:
            self._store.popitem(last=False)

    def __contains__(self, key) -> bool:
        return key in self._store

    def __len__(self) -> int:
        return len(self._store)

    def clear(self) -> None:
        self._store.clear()


#: built (model, run, params) per (model_cfg, model_seed) — one ``Model``
#: identity keeps the ``jitted_steps`` compile cache warm across bench pairs
_MODEL_LRU = _SmallLru(maxsize=4)

#: deployments already warmed by :meth:`ReplayServingEnv.intervene_batch`,
#: keyed (model_seed, model_cfg, num_slots, cache_len, launch_key); bounded
#: with eviction — an evicted entry only costs a redundant (cheap, likely
#: jit-cache-hitting) warm pass, never correctness
_WARMED_DEPLOYMENTS = _SmallLru(maxsize=64)


def _built_model(model_cfg, model_seed: int):
    """(model, run, params) shared across every env instance with the same
    deployment — cached in a small explicit LRU (``_MODEL_LRU``) so the
    ``jitted_steps`` cache stays warm across bench pairs while long sweeps
    over many deployments still evict instead of accumulating."""
    import jax

    from repro.models.model import build_model
    from repro.utils.config import RunConfig, ShapeConfig

    key = (model_cfg, int(model_seed))
    hit = _MODEL_LRU.get(key)
    if hit is not None:
        return hit
    run = RunConfig(model=model_cfg,
                    shape=ShapeConfig("sim2real", 64, 4, "decode"))
    model = build_model(model_cfg, run.parallel)
    params = model.init(jax.random.PRNGKey(model_seed))
    built = (model, run, params)
    _MODEL_LRU.put(key, built)
    return built


class ReplayServingEnv(PooledEnv):
    """PerfEnv measuring serving configurations on the real batcher.

    ``workload`` is a spec string, bound :class:`TraceWorkload`, or
    already-generated :class:`Trace` — identical grammar to ``ServingEnv``;
    the realization is drawn once at construction (``trace_seed``, default
    ``seed``) and every measurement replays the same arrivals.  The model is
    the *deployment* and stays fixed across seeds (``model_seed``), so two
    envs differing only in ``seed`` measure the same system.

    ``ticks_per_s`` is pinned at construction against the DEFAULT plan's
    slot count: the arrival schedule is part of the environment, so it must
    not drift with the candidate configuration's ``num_slots``.
    """

    def __init__(self, workload: Union[str, TraceWorkload, Trace],
                 model_cfg=None, *, families: Optional[Iterable[str]] = None,
                 cell: Optional[KernelWorkload] = None, seed: int = 0,
                 objective: str = "latency", slo_ms: float = 1_000.0,
                 hardware: Optional[HardwareSpec] = None,
                 trace_seed: Optional[int] = None,
                 ticks_per_s: Optional[float] = None,
                 max_ticks: int = 100_000, model_seed: int = 0,
                 replay_seed: int = 0, warmup: int = 1, repeats: int = 1,
                 fleet: bool = False, num_devices: int = 8):
        from repro.launch.tune import launch_workload_for
        from repro.serving.replay import default_ticks_per_s
        from repro.tuner.space import launch_families_for

        if objective not in OBJECTIVES:
            raise ValueError(f"unknown serving objective {objective!r}; "
                             f"known: {sorted(OBJECTIVES)}")
        self.model_cfg = model_cfg or default_replay_model()
        if families is None:
            modeled = measure_mod.modeled_families()
            families = [f for f in launch_families_for(self.model_cfg)
                        if f in modeled]
        self.families = tuple(sorted(families))
        if isinstance(workload, str):
            workload = make_workload(workload)
        if isinstance(workload, Trace):
            self.trace = workload
            self.workload_spec = workload.spec
        else:
            self.trace = workload.generate(
                seed if trace_seed is None else trace_seed)
            self.workload_spec = workload.spec
        self.objective = objective
        self.maximize = objective == "throughput"
        self.slo_ms = float(slo_ms)
        # the analytic cell the VMEM feasibility gate prices with — derived
        # from the deployed model unless pinned, like launch tuning does
        self.cell = cell or launch_workload_for(self.model_cfg, batch=1,
                                                seq_len=512, kind="serve")
        self.hardware = hardware or HardwareSpec()
        self.max_ticks = int(max_ticks)
        self.ticks_per_s = ticks_per_s or default_ticks_per_s(
            self.trace, ServingPlan().num_slots)
        self._replay_seed = int(replay_seed)
        self.warmup = int(warmup)
        self.repeats = max(int(repeats), 1)
        self._model_seed = int(model_seed)
        self.model, self.run, self.params = _built_model(self.model_cfg,
                                                         model_seed)
        self.fleet = bool(fleet)
        self.num_devices = int(num_devices)
        super().__init__(serving_space(self.families, fleet=self.fleet),
                         REPLAY_FLEET_COUNTER_NAMES if self.fleet
                         else REPLAY_COUNTER_NAMES, seed=seed)
        # the compile key: members of a q-batch sharing these dims share one
        # jitted (prefill, decode) deployment — num_slots stays out (it only
        # retraces the decode step, which is cheap next to a full compile).
        # fleet.* router knobs never touch compiled shapes, so like the
        # scheduler knobs they stay out of the key — every replica of every
        # fleet plan shares the same warmed deployment.
        self.batch_share_dims = tuple(
            ["serving.cache_len"]
            + [n for n in self.space.names
               if "." in n and not n.startswith(("serving.", "fleet."))])

    # measurements are compilation + wall-clock, not noise draws: reusing a
    # prior result for a repeated configuration is pure savings
    memoize_measurements = True

    @property
    def query_text(self) -> str:
        """The query ``transfer_tune`` should run this environment under
        (``{budget}`` left for the runner to fill).  Latency binds in wall
        milliseconds — the replay's unit, not the simulator's."""
        if self.maximize:
            return (f"maximize throughput for which latency is less than "
                    f"{self.slo_ms:g} within {{budget}} samples")
        return "minimize latency within {budget} samples"

    # -- feasibility (analytic, like WallClockBackend's gate) ------------

    def infeasible_reason(self, config: Dict[str, Any]) -> str:
        """"" when deployable; otherwise why not (``cache_len``/``pages``/
        ``vmem``/``devices``), decided analytically so undeployable configs
        never reach the batcher.  The paged branch mirrors
        ``ServingSimulator.capacity_reason`` so the analytic gate and the
        real deployment agree."""
        plan = ServingPlan.from_config(config)
        paged = PagedPlan.from_config(config)
        if paged.paging:
            if (self.trace.max_context > paged.slot_capacity
                    or paged.pages_for(self.trace.max_context)
                    > paged.pool_pages):
                return "pages"
        elif self.trace.max_context > plan.cache_len:
            return "cache_len"
        if (self.fleet and FleetPlan.from_config(config).num_replicas
                > self.num_devices):
            return "devices"
        seq = paged.slot_capacity if paged.paging else plan.cache_len
        w = dataclasses.replace(self.cell, batch=plan.num_slots, seq_len=seq)
        _, _, feasible = LaunchGeometry(w, self.hardware).totals(
            self.families, config)
        return "" if feasible else "vmem"

    def _infeasible_counters(self) -> Dict[str, float]:
        n = float(len(self.trace.requests))
        c = {"queue_depth_mean": n, "queue_depth_max": n,
             "occupancy_mean": 0.0, "prefill_decode_ratio": 0.0,
             "slo_violation_rate": 1.0, "page_pool_occupancy": 0.0,
             "page_faults": 0.0, "prefill_chunks_inflight": 0.0,
             "rejected_rate": 1.0, "rejected_too_long": 0.0,
             "latency": 0.0, "throughput": 0.0}
        if self.fleet:
            c.update(routing_imbalance=1.0, replica_queue_depth_max=n,
                     straggler_flagged=0.0)
        return c

    # -- measurement ----------------------------------------------------

    def replay(self, config: Dict[str, Any]):
        """Deploy ``config`` on a FRESH batcher and replay the pinned trace;
        returns the :class:`repro.serving.replay.ReplayReport`.  The launch
        half is baked into the jitted steps (the step factories run under an
        exclusive ``dispatch.use_launch_config``); the scheduler half is the
        batcher's geometry."""
        plan = ServingPlan.from_config(config)
        paged = PagedPlan.from_config(config)
        deploy_span = obs_trace.span(
            "deployment", cat="env", track=obs_trace.TRACK_ENV,
            num_slots=plan.num_slots, cache_len=plan.cache_len,
            paging=paged.paging, members=1)
        with deploy_span:
            return self._replay_deployed(config, plan, paged)

    def _replay_deployed(self, config: Dict[str, Any], plan: ServingPlan,
                         paged: "PagedPlan"):
        from repro.serving.replay import replay_trace
        from repro.serving.scheduler import ContinuousBatcher
        from repro.tuner.space import launch_config_of

        batcher = ContinuousBatcher(
            self.model, self.run, self.params, num_slots=plan.num_slots,
            cache_len=plan.cache_len, interleave=plan.interleave,
            launch_config=launch_config_of(config), seed=self._replay_seed,
            paged=paged, on_too_long="reject")
        # warmup replays trigger every jit compile this deployment needs
        # (each distinct prompt length traces prefill once) so the measured
        # replay times execution, not compilation — the per-replay delta
        # accounting of replay_trace is what makes reuse sound here
        def one():
            return replay_trace(batcher, self.trace,
                                admit_chunk=plan.admit_chunk,
                                ticks_per_s=self.ticks_per_s,
                                seed=self._replay_seed,
                                max_ticks=self.max_ticks)

        for _ in range(self.warmup):
            one()
        # median-of-k on the objective metric, the WallClockBackend recipe
        # against wall-clock jitter; the whole median report is returned so
        # counters stay internally consistent
        reports = sorted((one() for _ in range(self.repeats)),
                         key=lambda r: (r.throughput_rps if self.maximize
                                        else r.p99_latency_ms))
        return reports[len(reports) // 2]

    def _measure(self, config: Dict[str, Any]
                 ) -> Tuple[Dict[str, float], float]:
        from repro.serving.scheduler import DrainStall

        bad = float("-inf" if self.maximize else "inf")
        if self.infeasible_reason(config):
            return self._infeasible_counters(), bad
        if self.fleet:
            plan = ServingPlan.from_config(config)
            num_slots, cache_len, paged, frozen = self._deploy_key(plan,
                                                                   config)
            batcher = self._fresh_batcher(num_slots, cache_len, paged, frozen)
            self._warm_deployment(batcher, frozen)
            batcher.interleave = plan.interleave
            try:
                return self._member_result(batcher, config, plan)
            except DrainStall:
                return self._infeasible_counters(), bad
        try:
            with obs_trace.span("measure", cat="env",
                                track=obs_trace.TRACK_ENV):
                report = self.replay(config)
        except DrainStall:
            return self._infeasible_counters(), bad
        counters = report.counters(self.slo_ms)
        y = (report.throughput_rps if self.maximize
             else report.p99_latency_ms)
        return counters, y

    # -- fleet replay (sim-planned routing, shared deployment) -----------

    def _fleet_route(self, config: Dict[str, Any], plan: ServingPlan,
                     fleet_plan: FleetPlan) -> FleetReport:
        """Route the pinned trace with the analytic fleet simulator — the
        router's decisions depend only on modeled backlogs, so the plan is
        deterministic and shared between sim-side and replay-side envs."""
        from repro.workloads.sim import FleetSimulator, FleetSpec

        sim = FleetSimulator(self.cell, self.families,
                             hardware=self.hardware,
                             max_ticks=self.max_ticks,
                             fleet=FleetSpec(num_devices=self.num_devices))
        return sim.run(self.trace, plan, fleet_plan, config)

    def _subtraces(self, assignments: Tuple[Tuple[int, ...], ...]
                   ) -> List[Optional[Trace]]:
        """Split the pinned trace into one sub-trace per replica (``None``
        for replicas the router left empty); uids and arrival times are
        preserved, so per-request latency semantics carry over."""
        reqs = self.trace.requests
        out: List[Optional[Trace]] = []
        for r, idxs in enumerate(assignments):
            if not idxs:
                out.append(None)
                continue
            out.append(Trace(kind=self.trace.kind,
                             spec=f"{self.trace.spec}#r{r}",
                             seed=self.trace.seed,
                             requests=tuple(reqs[i] for i in idxs)))
        return out

    def _pool_fleet(self, reports: List[Any], plan_report: FleetReport
                    ) -> Tuple[Dict[str, float], float]:
        """Pool per-replica :class:`ReplayReport`s into one fleet
        measurement.  Replicas run concurrently in a real fleet, so wall
        time is the max over replicas; everything request-weighted pools."""
        import numpy as np

        from repro.runtime.straggler import StragglerMonitor

        lat = [l for r in reports for l in r.latencies_ms]
        arr = np.asarray(lat, np.float64)
        completed = sum(r.completed for r in reports)
        rejected = sum(r.rejected for r in reports)
        ticks = sum(r.ticks for r in reports)
        wall = max((r.wall_s for r in reports), default=1e-9)
        prefill = sum(r.prefill_s for r in reports)
        decode = sum(r.decode_s for r in reports)
        # realized per-replica decode wall time per tick drives the monitor
        # — the REAL straggler signal, not the planned one
        monitor = StragglerMonitor(max(plan_report.num_replicas, 1))
        step_times = {i: r.decode_s / r.ticks
                      for i, r in enumerate(reports) if r.ticks > 0}
        if step_times:
            for _ in range(monitor.patience):
                monitor.report(step_times)
        p99 = float(np.percentile(arr, 99)) if arr.size else 0.0
        counters = {
            "queue_depth_mean": (sum(r.queue_depth_mean * r.ticks
                                     for r in reports) / max(ticks, 1)),
            "queue_depth_max": max((r.queue_depth_max for r in reports),
                                   default=0.0),
            "occupancy_mean": (sum(r.mean_occupancy * r.ticks
                                   for r in reports) / max(ticks, 1)),
            "prefill_decode_ratio": prefill / max(decode, 1e-9),
            "slo_violation_rate": (float((arr > self.slo_ms).mean())
                                   if arr.size else 0.0),
            "page_pool_occupancy": (sum(r.page_pool_occupancy * r.ticks
                                        for r in reports) / max(ticks, 1)),
            "page_faults": float(sum(r.page_faults for r in reports)),
            "prefill_chunks_inflight": (
                sum(r.prefill_chunks_inflight * r.ticks
                    for r in reports) / max(ticks, 1)),
            "rejected_rate": rejected / max(rejected + completed, 1),
            "rejected_too_long": float(sum(r.rejected_too_long
                                           for r in reports)),
            "latency": p99,
            "throughput": completed / max(wall, 1e-9),
            "routing_imbalance": plan_report.routing_imbalance,
            "replica_queue_depth_max": plan_report.replica_queue_depth_max,
            "straggler_flagged": float(len(monitor.flagged())),
        }
        y = counters["throughput"] if self.maximize else p99
        return counters, y

    def _member_result(self, batcher, config: Dict[str, Any],
                       plan: ServingPlan) -> Tuple[Dict[str, float], float]:
        """(counters, y) of one member measured on a warmed deployment —
        plain replay, or (fleet mode) sim-planned routing followed by one
        sub-trace replay per replica on the SAME shared batcher (all fleet
        plans share one compile key; replica batchers are identical
        deployments, so sequential replay on one instance is sound and the
        fleet wall time is the max over replicas)."""
        from repro.serving.replay import replay_trace

        if self.fleet:
            fleet_plan = FleetPlan.from_config(config)
            plan_report = self._fleet_route(config, plan, fleet_plan)
            if not plan_report.feasible:
                return (self._infeasible_counters(),
                        float("-inf" if self.maximize else "inf"))
            subtraces = self._subtraces(plan_report.assignments)
            outs = []
            for _ in range(self.repeats):
                reports = [replay_trace(batcher, st,
                                        admit_chunk=plan.admit_chunk,
                                        ticks_per_s=self.ticks_per_s,
                                        seed=self._replay_seed,
                                        max_ticks=self.max_ticks)
                           for st in subtraces if st is not None]
                outs.append(self._pool_fleet(reports, plan_report))
            outs.sort(key=lambda cy: cy[1])
            return outs[len(outs) // 2]

        reports = sorted(
            (replay_trace(batcher, self.trace, admit_chunk=plan.admit_chunk,
                          ticks_per_s=self.ticks_per_s,
                          seed=self._replay_seed, max_ticks=self.max_ticks)
             for _ in range(self.repeats)),
            key=lambda r: (r.throughput_rps if self.maximize
                           else r.p99_latency_ms))
        report = reports[len(reports) // 2]
        return (report.counters(self.slo_ms),
                (report.throughput_rps if self.maximize
                 else report.p99_latency_ms))

    # -- batched measurement --------------------------------------------

    def _deploy_key(self, plan: ServingPlan, config: Dict[str, Any]) -> tuple:
        from repro.tuner.space import launch_config_of
        from repro.train.serve_step import freeze_launch_config

        # PagedPlan is a frozen dataclass of scalars — hashable, and it
        # captures the paged compiled shape (pool, page size, table width)
        # the launch-config half does not
        return (plan.num_slots, plan.cache_len, PagedPlan.from_config(config),
                freeze_launch_config(launch_config_of(config)))

    def _fresh_batcher(self, num_slots: int, cache_len: int,
                       paged: PagedPlan, frozen: tuple):
        from repro.serving.scheduler import ContinuousBatcher

        return ContinuousBatcher(
            self.model, self.run, self.params, num_slots=num_slots,
            cache_len=cache_len, interleave="eager",
            launch_config={f: dict(p) for f, p in frozen},
            seed=self._replay_seed, paged=paged, on_too_long="reject")

    def _warm_deployment(self, batcher, frozen: tuple) -> None:
        """Trigger every jit compile this deployment's replays need, without
        replaying: one prefill per distinct fitting prompt length (each
        traces separately) plus one decode step.  Direct calls — the
        batcher's state and wall-time counters are untouched, so the
        measured replays start clean.  Recorded in a bounded LRU so repeat
        deployments skip even the warm execution."""
        import jax
        import jax.numpy as jnp

        wkey = (self._model_seed, self.model_cfg, batcher.num_slots,
                batcher.cache_len, batcher.paged, frozen)
        if wkey in _WARMED_DEPLOYMENTS:
            obs_trace.instant("warmup_cached", cat="env",
                              track=obs_trace.TRACK_ENV,
                              num_slots=batcher.num_slots,
                              cache_len=batcher.cache_len)
            return
        lens = sorted({r.prompt_len for r in self.trace.requests
                       if r.prompt_len + r.output_len <= batcher.cache_len})
        with obs_trace.span("warmup", cat="env", track=obs_trace.TRACK_ENV,
                            num_slots=batcher.num_slots,
                            cache_len=batcher.cache_len,
                            prompt_lens=len(lens)):
            for plen in lens:
                _, logits = batcher._prefill(
                    self.params, {"tokens": jnp.zeros((1, plen), jnp.int32)})
                jax.block_until_ready(logits)
            _, logits = batcher._decode(self.params, batcher.state,
                                        batcher._tokens[:, None])
            jax.block_until_ready(logits)
        _WARMED_DEPLOYMENTS.put(wkey, True)

    def intervene_batch(self, configs: List[Dict[str, Any]]
                        ) -> List[Tuple[Dict[str, float], float]]:
        """Measure a q-batch with one deployment per compile key.

        Members are grouped by ``(num_slots, cache_len, launch)``; each
        group builds ONE batcher, warms it directly (every distinct prompt
        length's prefill + the decode step), then replays every member
        against the warmed deployment — ``admit_chunk``/``interleave`` are
        per-replay knobs, and :func:`replay_trace`'s delta accounting keeps
        a reused batcher sound.  Groups differing only in ``num_slots``
        still share all prefill compiles through the ``jitted_steps``
        cache.  A :class:`DrainStall` in one member records THAT member
        infeasible and rebuilds the batcher (compiles stay cached) instead
        of aborting the round.  Results come back in input order.

        Fleet mode reuses the exact same grouping: ``fleet.*`` knobs are
        not in the compile key, so members differing only in replica count
        or routing policy share one warmed deployment and differ purely in
        how :meth:`_member_result` splits and replays the trace.
        """
        from repro.serving.scheduler import DrainStall

        bad = float("-inf" if self.maximize else "inf")
        results: List[Optional[Tuple[Dict[str, float], float]]] = \
            [None] * len(configs)
        groups: Dict[tuple, List[int]] = {}
        for i, cfg in enumerate(configs):
            if self.infeasible_reason(cfg):
                results[i] = (self._infeasible_counters(), bad)
                continue
            key = self._deploy_key(ServingPlan.from_config(cfg), cfg)
            groups.setdefault(key, []).append(i)

        for (num_slots, cache_len, paged, frozen), members in groups.items():
            with obs_trace.span("deployment", cat="env",
                                track=obs_trace.TRACK_ENV,
                                num_slots=num_slots, cache_len=cache_len,
                                paging=paged.paging, members=len(members)):
                batcher = self._fresh_batcher(num_slots, cache_len, paged,
                                              frozen)
                self._warm_deployment(batcher, frozen)
                for i in members:
                    plan = ServingPlan.from_config(configs[i])
                    batcher.interleave = plan.interleave
                    member_span = obs_trace.span(
                        "member_replay", cat="env",
                        track=obs_trace.TRACK_ENV, member=i,
                        interleave=plan.interleave,
                        admit_chunk=plan.admit_chunk)
                    with member_span:
                        try:
                            results[i] = self._member_result(
                                batcher, configs[i], plan)
                            member_span.set(y=results[i][1])
                        except DrainStall:
                            results[i] = (self._infeasible_counters(), bad)
                            member_span.set(stalled=True)
                            # a stalled replay leaves residents behind —
                            # rebuild (cheap: every compile is cached)
                            batcher = self._fresh_batcher(
                                num_slots, cache_len, paged, frozen)

        for cfg, res in zip(configs, results):
            self._remember(cfg, res[0], res[1])
        return results

    # -- deployment -----------------------------------------------------

    plan_of = staticmethod(ServingEnv.plan_of)
    apply = ServingEnv.apply


def make_sim2real_pair(workload: Union[str, TraceWorkload, Trace],
                       model_cfg=None, *,
                       families: Optional[Iterable[str]] = None,
                       seed: int = 0, trace_seed: Optional[int] = None,
                       objective: str = "latency", slo_us: float = 2_000.0,
                       slo_ms: float = 1_000.0,
                       hardware: Optional[HardwareSpec] = None,
                       fleet: bool = False, num_devices: int = 8,
                       **replay_kw: Any
                       ) -> Tuple[ServingEnv, ReplayServingEnv]:
    """(source, target) over the IDENTICAL trace realization: the simulator
    prices the trace analytically at the deployed model's kernel dimensions
    (cheap staging), the replay environment measures the real batcher (the
    deployment).  Identical configuration space; the paper's sim-to-real
    environment change with everything else held fixed.  ``fleet=True``
    gives both halves the router/replica knobs (same ``fleet.*`` surface,
    same device budget)."""
    from repro.launch.tune import launch_workload_for
    from repro.tuner.space import launch_families_for

    model_cfg = model_cfg or default_replay_model()
    if families is None:
        modeled = measure_mod.modeled_families()
        families = [f for f in launch_families_for(model_cfg)
                    if f in modeled]
    families = tuple(sorted(families))
    cell = launch_workload_for(model_cfg, batch=1, seq_len=512, kind="serve")
    if isinstance(workload, str):
        workload = make_workload(workload)
    if not isinstance(workload, Trace):
        workload = workload.generate(seed if trace_seed is None
                                     else trace_seed)
    src = ServingEnv(workload, cell, families, seed=seed + 1,
                     objective=objective, slo_us=slo_us, hardware=hardware,
                     fleet=fleet, num_devices=num_devices)
    tgt = ReplayServingEnv(workload, model_cfg, families=families, cell=cell,
                           seed=seed + 2, objective=objective, slo_ms=slo_ms,
                           hardware=hardware, fleet=fleet,
                           num_devices=num_devices, **replay_kw)
    return src, tgt
