"""Environment contract for configuration tuning.

An environment is "a combination of hardware, workload, software, and
deployment topology" (the paper's definition).  Tuners interact through:

  observe(rng)      -> (config, counters, y)   draw from the cheap
                       observational pool (staging measurements)
  intervene(config) -> (counters, y)           set the configuration and
                       measure (expensive in production)

``counters`` are the system events C (perf counters in the paper; compiled
HLO statistics in ours).
"""

from __future__ import annotations

from typing import Any, Dict, List, Protocol, Tuple

import numpy as np

from repro.core.spaces import ConfigSpace


class PerfEnv(Protocol):
    space: ConfigSpace
    counter_names: Tuple[str, ...]

    def observe(self, rng: np.random.Generator
                ) -> Tuple[Dict[str, Any], Dict[str, float], float]: ...

    def intervene(self, config: Dict[str, Any]
                  ) -> Tuple[Dict[str, float], float]: ...


class PooledEnv:
    """Base env with an observational pool drawn by random configuration."""

    def __init__(self, space: ConfigSpace, counter_names=(), seed: int = 0,
                 pool_size: int = 512):
        self.space = space
        self.counter_names = tuple(counter_names)
        self._pool_rng = np.random.default_rng(seed)
        self._pool: List[Tuple[Dict, Dict, float]] = []
        self._pool_size = pool_size

    def _measure(self, config) -> Tuple[Dict[str, float], float]:
        raise NotImplementedError

    def intervene(self, config):
        return self._measure(config)

    def observe(self, rng: np.random.Generator):
        if len(self._pool) < self._pool_size:
            cfg = self.space.sample(self._pool_rng, 1)[0]
            counters, y = self._measure(cfg)
            self._pool.append((cfg, counters, y))
            return cfg, counters, y
        i = int(rng.integers(len(self._pool)))
        return self._pool[i]

    def dataset(self, n: int, seed: int = 0):
        """Collect an observational dataset of n random measurements."""
        from repro.core.cameo import Dataset

        rng = np.random.default_rng(seed)
        d = Dataset()
        for cfg in self.space.sample(rng, n):
            counters, y = self._measure(cfg)
            d.add(cfg, counters, y)
        return d
