"""Environment contract for configuration tuning.

An environment is "a combination of hardware, workload, software, and
deployment topology" (the paper's definition).  Tuners interact through:

  observe(rng)      -> (config, counters, y)   draw from the cheap
                       observational pool (staging measurements)
  intervene(config) -> (counters, y)           set the configuration and
                       measure (expensive in production)
  intervene_batch(configs) -> [(counters, y)]  measure a q-batch round;
                       sequential by default, overridden where batching
                       actually pays (vectorized noise, shared jit caches,
                       one warmed deployment per compile key)

``counters`` are the system events C (perf counters in the paper; compiled
HLO statistics in ours).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.core.spaces import ConfigSpace


class PerfEnv(Protocol):
    space: ConfigSpace
    counter_names: Tuple[str, ...]

    def observe(self, rng: np.random.Generator
                ) -> Tuple[Dict[str, Any], Dict[str, float], float]: ...

    def intervene(self, config: Dict[str, Any]
                  ) -> Tuple[Dict[str, float], float]: ...

    def intervene_batch(self, configs: List[Dict[str, Any]]
                        ) -> List[Tuple[Dict[str, float], float]]: ...


class PooledEnv:
    """Base env with an observational pool drawn by random configuration.

    Batched-measurement hooks:

    - ``batch_share_dims`` — option names whose joint value determines the
      expensive part of a measurement (e.g. the replay environment's
      ``(cache_len, launch)`` compile key).  ``None`` (the default) means
      measurements share nothing; batched proposal/sampling paths use it to
      group round members onto one deployment.
    - ``memoize_measurements`` — when True, :meth:`dataset` and
      :meth:`observe` reuse an already-measured configuration's result
      instead of re-measuring (the observational pool and the dataset
      become one store).  Off by default: analytic backends draw noise per
      measurement from a seeded stream, and reusing results would shift
      that stream.  Replay-backed envs opt in — their cost is compilation
      and wall-clock, not a noise draw.
    """

    batch_share_dims: Optional[Tuple[str, ...]] = None
    memoize_measurements: bool = False

    def __init__(self, space: ConfigSpace, counter_names=(), seed: int = 0,
                 pool_size: int = 512):
        self.space = space
        self.counter_names = tuple(counter_names)
        self._pool_rng = np.random.default_rng(seed)
        self._pool: List[Tuple[Dict, Dict, float]] = []
        self._pool_size = pool_size
        self._measured: Dict[tuple, Tuple[Dict, Dict, float]] = {}

    def _measure(self, config) -> Tuple[Dict[str, float], float]:
        raise NotImplementedError

    def _config_key(self, config: Dict[str, Any]) -> tuple:
        return tuple(config.get(o.name, o.default) for o in self.space.options)

    def _remember(self, cfg, counters, y) -> None:
        if self.memoize_measurements:
            self._measured[self._config_key(cfg)] = (dict(cfg),
                                                     dict(counters), y)

    def intervene(self, config):
        counters, y = self._measure(config)
        self._remember(config, counters, y)
        return counters, y

    def intervene_batch(self, configs: List[Dict[str, Any]]
                        ) -> List[Tuple[Dict[str, float], float]]:
        """Measure a q-batch; sequential fallback, identical stream to
        per-config :meth:`intervene` calls."""
        return [self.intervene(c) for c in configs]

    def observe(self, rng: np.random.Generator):
        if len(self._pool) < self._pool_size:
            cfg = self.space.sample(self._pool_rng, 1)[0]
            hit = (self._measured.get(self._config_key(cfg))
                   if self.memoize_measurements else None)
            if hit is not None:
                _, counters, y = hit
            else:
                counters, y = self._measure(cfg)
                self._remember(cfg, counters, y)
            self._pool.append((cfg, counters, y))
            return cfg, counters, y
        i = int(rng.integers(len(self._pool)))
        return self._pool[i]

    def _grouped_sample(self, rng: np.random.Generator, n: int,
                        query_batch: int) -> List[Dict[str, Any]]:
        """``n`` random configurations in groups of ``query_batch`` whose
        members share the ``batch_share_dims`` values of the group's first
        member — the measurement-cost-aware sampling the batched paths use
        (one compiled deployment serves each group)."""
        cfgs = self.space.sample(rng, n)
        share = [nm for nm in (self.batch_share_dims or ())
                 if nm in self.space.by_name]
        if not share or query_batch <= 1:
            return cfgs
        for g0 in range(0, n, query_batch):
            anchor = cfgs[g0]
            for c in cfgs[g0 + 1:g0 + query_batch]:
                for nm in share:
                    c[nm] = anchor[nm]
        return cfgs

    def dataset(self, n: int, seed: int = 0, query_batch: int = 1):
        """Collect an observational dataset of n random measurements.

        ``query_batch > 1`` (on envs declaring ``batch_share_dims``) samples
        in compile-key-sharing groups and measures through
        :meth:`intervene_batch`; ``query_batch=1`` reproduces the
        historical sequential collection exactly.  Envs with
        ``memoize_measurements`` reuse prior results for repeated
        configurations (and feed the observational pool) instead of paying
        the measurement twice.
        """
        from repro.core.cameo import Dataset

        rng = np.random.default_rng(seed)
        cfgs = self._grouped_sample(rng, n, query_batch)
        d = Dataset()
        misses = [c for c in cfgs
                  if not (self.memoize_measurements
                          and self._config_key(c) in self._measured)]
        if query_batch > 1 and len(misses) > 1:
            fresh = dict(zip(map(self._config_key, misses),
                             self.intervene_batch(misses)))
        else:
            fresh = {}
        for cfg in cfgs:
            key = self._config_key(cfg)
            if self.memoize_measurements and key in self._measured:
                _, counters, y = self._measured[key]
            elif key in fresh:
                counters, y = fresh[key]
                self._remember(cfg, counters, y)
            else:
                counters, y = self._measure(cfg)
                self._remember(cfg, counters, y)
            if self.memoize_measurements and len(self._pool) < self._pool_size:
                self._pool.append((dict(cfg), dict(counters), y))
            d.add(cfg, counters, y)
        return d
