"""Measurement backends for the kernel-launch tuning environment.

CAMEO's premise is that cheap source-environment measurements transfer to a
costly target.  This module supplies both sides of that pair for the launch
space:

- :class:`AnalyticBackend` — the launch-geometry model (grid extent, VMEM
  block footprints, streamed HBM bytes, per-step launch overhead).  Fast and
  deterministic: the observational source.
- :class:`WallClockBackend` — real timed execution: every registered kernel
  family is dispatched (jit-compiled, ``block_until_ready``) under the
  candidate launch configuration and the median of k repeats is the
  measurement.  Expensive and honest: the intervention target.
- :class:`ShiftedAnalyticBackend` — the analytic model a fixed,
  reproducible distance away: composable :class:`EnvShift` perturbations
  (scaled hardware constants, workload-shape changes, heteroscedastic
  noise, tightened VMEM feasibility) build the paper's environmental-change
  target pairs on CPU CI.  Named kinds live in ``SHIFT_KINDS`` and are
  selectable as ``shifted:<kind>``.

Both satisfy the :class:`MeasurementBackend` protocol —
``measure(config) -> (counters, y)`` with latency in microseconds — so
``KernelLaunchEnv`` (and anything else speaking ``PerfEnv``) swaps them
freely.  Selection: an explicit constructor argument wins, then the
``REPRO_MEASURE_BACKEND`` env var, then ``analytic``.

The timing harness (:func:`timeit`) takes an injectable clock so tests run
against a deterministic :class:`FakeClock` instead of ``perf_counter``.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field, replace
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Protocol, Sequence, Tuple, Union, runtime_checkable)

import numpy as np

MEASURE_BACKEND_ENV = "REPRO_MEASURE_BACKEND"
ANALYTIC = "analytic"
WALLCLOCK = "wallclock"
SHIFTED_PREFIX = "shifted:"

LANE = 128
VMEM_LIMIT_BYTES = 12 * 2 ** 20   # per-core block budget the model enforces
MXU_FLOPS_PER_US = 200e6          # ~bf16 peak of one v5e-class core
VPU_FLOPS_PER_US = 4e6
HBM_BYTES_PER_US = 0.8e6          # ~819 GB/s
F32 = 4                           # scratch accumulators
BF16 = 2                          # streamed in/out blocks

COUNTER_NAMES = ("grid_points", "vmem_peak_bytes", "hbm_bytes", "flops")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _padded(a: int, b: int) -> int:
    return _ceil_div(a, b) * b


def _mxu_util(*block_dims: int) -> float:
    """Fraction of the MXU filled by a tile: 1.0 at lane-aligned >=128."""
    u = 1.0
    for d in block_dims:
        u *= min(d, LANE) / LANE
    return max(u, 1e-3)


@dataclass(frozen=True)
class HardwareSpec:
    """The hardware constants the launch-geometry model prices with.

    The defaults are the module-level v5e-class constants, so a default
    ``HardwareSpec`` reproduces the original model bit-for-bit; a shifted
    environment scales them (a different accelerator generation)."""

    mxu_flops_per_us: float = MXU_FLOPS_PER_US
    vpu_flops_per_us: float = VPU_FLOPS_PER_US
    hbm_bytes_per_us: float = HBM_BYTES_PER_US

    def scaled(self, mxu: float = 1.0, vpu: float = 1.0,
               hbm: float = 1.0) -> "HardwareSpec":
        if mxu == vpu == hbm == 1.0:
            return self
        return HardwareSpec(self.mxu_flops_per_us * mxu,
                            self.vpu_flops_per_us * vpu,
                            self.hbm_bytes_per_us * hbm)


@dataclass(frozen=True)
class KernelWorkload:
    """One (model shape x batch) cell the kernels run under."""

    name: str = "serve-8b"
    batch: int = 8
    seq_len: int = 4096
    heads: int = 32
    kv_heads: int = 8
    head_dim: int = 128
    d_model: int = 4096
    # mamba-1 surface
    channels: int = 8192
    scan_state: int = 16
    # mamba-2 surface
    ssm_heads: int = 64
    ssm_head_dim: int = 64
    ssm_state: int = 128
    vmem_limit: int = VMEM_LIMIT_BYTES
    launch_overhead_us: float = 1.5
    noise: float = 0.01


def family_params(family: str, config: Dict[str, Any]) -> Dict[str, Any]:
    """Per-family launch parameters out of a flat ``family.param`` config,
    falling back to the registry defaults for anything unspecified."""
    from repro.kernels import dispatch

    fam = dispatch.get_family(family)
    out = {o.name: o.default for o in fam.launch_options}
    for o in fam.launch_options:
        key = f"{family}.{o.name}"
        if key in config:
            out[o.name] = config[key]
    return out


# --------------------------------------------------------------------------
# launch-geometry model
# --------------------------------------------------------------------------

class LaunchGeometry:
    """Analytic cost model of one kernel launch per family.

    Each ``<family>(params)`` returns ``(t_us, grid, vmem, flops, hbm)`` —
    modeled latency, grid points, per-core VMEM footprint of the blocks,
    total FLOPs, and streamed HBM bytes — from the same quantities the real
    kernels derive from the launch parameters.  ``hardware`` supplies the
    peak rates (default: the v5e-class module constants).
    """

    def __init__(self, workload: KernelWorkload,
                 hardware: Optional[HardwareSpec] = None):
        self.workload = workload
        self.hardware = hardware or HardwareSpec()

    def flash_attention(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        qb, kb = int(p["q_block"]), int(p["kv_block"])
        sq, sk = _padded(w.seq_len, qb), _padded(w.seq_len, kb)
        grid = w.batch * w.heads * (sq // qb) * (sk // kb)
        # causal: roughly half the kv blocks are visible
        flops = 0.5 * w.batch * w.heads * sq * sk * 4 * w.head_dim
        vmem = (BF16 * 2 * (qb + 2 * kb) * w.head_dim         # double-buffered in
                + BF16 * 2 * qb * w.head_dim                  # out
                + F32 * qb * (w.head_dim + 2 * LANE))         # acc/m/l scratch
        hbm = F32 * grid * (qb + 2 * kb) * w.head_dim / 2 + F32 * sq * w.head_dim
        t = (grid * w.launch_overhead_us
             + flops / (self.hardware.mxu_flops_per_us * _mxu_util(qb, kb))
             + hbm / self.hardware.hbm_bytes_per_us)
        return t, grid, vmem, flops, hbm

    def mamba_scan(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        chunk, cb = int(p["chunk"]), int(p["c_block"])
        l = _padded(w.seq_len, chunk)
        grid = w.batch * _ceil_div(w.channels, cb) * (l // chunk)
        flops = 8.0 * w.batch * l * w.channels * w.scan_state
        vmem = (BF16 * 2 * chunk * (3 * cb + 2 * w.scan_state)  # in, dbl-buffered
                + BF16 * 2 * chunk * cb                          # out
                + F32 * cb * w.scan_state)                       # state scratch
        hbm = F32 * w.batch * l * (3 * w.channels + 2 * w.scan_state)
        # the recurrence is serial inside a chunk: VPU-bound step chain
        serial = grid * chunk * (cb * w.scan_state
                                 / self.hardware.vpu_flops_per_us) * 1e-3
        t = (grid * w.launch_overhead_us + serial
             + hbm / self.hardware.hbm_bytes_per_us)
        return t, grid, vmem, flops, hbm

    def ssd(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        chunk = int(p["chunk"])
        l = _padded(w.seq_len, chunk)
        grid = w.batch * w.ssm_heads * (l // chunk)
        n, hd = w.ssm_state, w.ssm_head_dim
        # quadratic intra-chunk term + two state matmuls per chunk
        flops = grid * (2 * chunk * chunk * (n + hd) + 4 * chunk * n * hd)
        vmem = (BF16 * 2 * chunk * (hd + 2 * n) + BF16 * 2 * chunk * hd
                + F32 * (chunk * chunk + n * hd))
        hbm = F32 * w.batch * l * w.ssm_heads * (hd + 2 * n // max(w.ssm_heads // 8, 1))
        t = (grid * w.launch_overhead_us
             + flops / (self.hardware.mxu_flops_per_us * _mxu_util(chunk))
             + hbm / self.hardware.hbm_bytes_per_us)
        return t, grid, vmem, flops, hbm

    def rmsnorm(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        rb = int(p["row_block"])
        rows = _padded(w.batch * w.seq_len, rb)
        grid = rows // rb
        flops = 4.0 * rows * w.d_model
        vmem = BF16 * (2 * 2 * rb * w.d_model + w.d_model)
        hbm = F32 * rows * w.d_model * 2
        t = grid * w.launch_overhead_us + hbm / self.hardware.hbm_bytes_per_us
        return t, grid, vmem, flops, hbm

    def paged_attention(self, p) -> Tuple[float, float, float, float, float]:
        w = self.workload
        ps = int(p["page_size"])
        n_pages = _ceil_div(w.seq_len, ps)
        grid = w.batch * w.kv_heads * n_pages
        g = max(w.heads // max(w.kv_heads, 1), 1)
        ctx = n_pages * ps
        # one new token per slot attending over the page-quantized context
        flops = w.batch * w.heads * ctx * 4 * w.head_dim
        # the paged win: VMEM holds one (page_size x head_dim) K/V page pair
        # per stream — independent of seq_len, unlike the dense decode cache
        vmem = (BF16 * 2 * 2 * ps * w.head_dim       # k/v page, dbl-buffered
                + BF16 * 2 * g * w.head_dim          # q in / out block
                + F32 * g * (w.head_dim + 2 * LANE))  # acc/m/l scratch
        hbm = (F32 * grid * 2 * ps * w.head_dim       # streamed pool pages
               + F32 * w.batch * w.heads * w.head_dim * 2  # q in, out
               + F32 * w.batch * n_pages)             # page table
        t = (grid * w.launch_overhead_us
             + flops / (self.hardware.mxu_flops_per_us * _mxu_util(ps))
             + hbm / self.hardware.hbm_bytes_per_us)
        return t, grid, vmem, flops, hbm

    MODELS = ("flash_attention", "mamba_scan", "ssd", "rmsnorm",
              "paged_attention")

    def family_cost(self, family: str, params: Dict[str, Any]
                    ) -> Tuple[float, float, float, float, float]:
        if family not in self.MODELS:
            raise KeyError(
                f"no launch-geometry model for family {family!r}; "
                f"modeled: {sorted(self.MODELS)}")
        return getattr(self, family)(params)

    def totals(self, families: Sequence[str], config: Dict[str, Any]
               ) -> Tuple[Dict[str, float], float, bool]:
        """Summed counters, total modeled latency, and VMEM feasibility over
        ``families`` (evaluated in the given order — keep it sorted for
        reproducible accumulation)."""
        total_us, grid_pts, vmem_peak, flops, hbm = 0.0, 0.0, 0.0, 0.0, 0.0
        feasible = True
        for family in families:
            t, grid, vmem, fl, hb = self.family_cost(
                family, family_params(family, config))
            total_us += t
            grid_pts += grid
            vmem_peak = max(vmem_peak, vmem)
            flops += fl
            hbm += hb
            if vmem > self.workload.vmem_limit:
                feasible = False
        counters = {"grid_points": grid_pts, "vmem_peak_bytes": vmem_peak,
                    "hbm_bytes": hbm, "flops": flops}
        return counters, total_us, feasible


def modeled_families() -> Tuple[str, ...]:
    return LaunchGeometry.MODELS


# --------------------------------------------------------------------------
# environment shifts
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class EnvShift:
    """One composable, deterministic perturbation of the analytic
    environment — the paper's environmental-change axes instantiated for the
    launch space.  A shift rewrites the (workload, hardware) pair the
    geometry model prices with:

    - hardware: scale the peak rates and per-launch overhead (a different
      accelerator generation);
    - workload: scale/override the workload shape (a different serving
      assignment);
    - feasibility: scale the per-core VMEM budget (tightened -> parts of the
      source-feasible grid become infeasible in the target);
    - noise: scale the multiplicative measurement noise and/or add a
      heteroscedastic component that grows with modeled latency;
    - fleet: scale the device count (elastic resize) and/or slow a fraction
      of devices down (stragglers).  The fleet fields are consumed by
      fleet-aware environments (``repro.envs.serving_env`` derives a
      ``FleetSpec`` from them); :meth:`apply` only rewrites the
      (workload, hardware) pair, so non-fleet backends see a shift kind's
      *aggregate* effect through the base scales.

    Shifts compose left-to-right: scales multiply, absolute
    ``workload_update`` overrides win over earlier scales.
    """

    name: str = "shift"
    mxu_scale: float = 1.0
    vpu_scale: float = 1.0
    hbm_scale: float = 1.0
    launch_overhead_scale: float = 1.0
    vmem_scale: float = 1.0
    seq_scale: float = 1.0
    batch_scale: float = 1.0
    workload_update: Mapping[str, Any] = field(default_factory=dict)
    noise_scale: float = 1.0
    hetero_noise: float = 0.0
    # fleet-disruption axes (consumed by fleet-aware serving environments)
    device_scale: float = 1.0        # elastic resize: scales the device count
    straggler_frac: float = 0.0      # fraction of devices running slow
    straggler_slowdown: float = 1.0  # how slow the straggling devices are

    def apply(self, workload: KernelWorkload, hardware: HardwareSpec
              ) -> Tuple[KernelWorkload, HardwareSpec]:
        w = workload
        if self.seq_scale != 1.0:
            w = replace(w, seq_len=max(1, int(w.seq_len * self.seq_scale)))
        if self.batch_scale != 1.0:
            w = replace(w, batch=max(1, int(w.batch * self.batch_scale)))
        if self.vmem_scale != 1.0:
            w = replace(w, vmem_limit=max(1, int(w.vmem_limit * self.vmem_scale)))
        if self.launch_overhead_scale != 1.0:
            w = replace(w, launch_overhead_us=w.launch_overhead_us
                        * self.launch_overhead_scale)
        if self.noise_scale != 1.0:
            w = replace(w, noise=w.noise * self.noise_scale)
        if self.workload_update:
            w = replace(w, **dict(self.workload_update))
        return w, hardware.scaled(self.mxu_scale, self.vpu_scale,
                                  self.hbm_scale)


_HARDWARE_SHIFT = EnvShift(name="hardware", mxu_scale=0.5, hbm_scale=0.6,
                           launch_overhead_scale=2.0)
_WORKLOAD_SHIFT = EnvShift(name="workload", seq_scale=2.0, batch_scale=0.5)
_NOISE_SHIFT = EnvShift(name="noise", noise_scale=4.0, hetero_noise=0.05)
_FEASIBILITY_SHIFT = EnvShift(name="feasibility", vmem_scale=0.5)
# stragglers: a quarter of the devices run 3x slow.  Fleet-aware envs place
# them on the device grid; the base scales model the aggregate drag (slower
# effective memory, contention-inflated launch overhead) so the kernel-grid
# backends shift too.
_STRAGGLER_SHIFT = EnvShift(name="straggler", hbm_scale=0.8,
                            launch_overhead_scale=1.5, straggler_frac=0.25,
                            straggler_slowdown=3.0)
# elastic resize: a quarter of the fleet is preempted and the surviving
# devices absorb the traffic (larger effective batch per replica)
_RESIZE_SHIFT = EnvShift(name="resize", batch_scale=1.5, device_scale=0.75)

SHIFT_KINDS: Dict[str, Tuple[EnvShift, ...]] = {
    "hardware": (_HARDWARE_SHIFT,),
    "workload": (_WORKLOAD_SHIFT,),
    "noise": (_NOISE_SHIFT,),
    "feasibility": (_FEASIBILITY_SHIFT,),
    "severe": (_HARDWARE_SHIFT, _WORKLOAD_SHIFT, _FEASIBILITY_SHIFT,
               _NOISE_SHIFT),
    "straggler": (_STRAGGLER_SHIFT,),
    "resize": (_RESIZE_SHIFT,),
}


def shift_kinds() -> Tuple[str, ...]:
    return tuple(SHIFT_KINDS)


def shifts_for(kind: str) -> Tuple[EnvShift, ...]:
    if kind not in SHIFT_KINDS:
        raise ValueError(
            f"unknown shift kind {kind!r}; known: {sorted(SHIFT_KINDS)}")
    return SHIFT_KINDS[kind]


def _check_modeled(families: Tuple[str, ...]) -> None:
    unmodeled = [f for f in families if f not in LaunchGeometry.MODELS]
    if unmodeled:
        raise ValueError(
            f"no launch-geometry model for families {unmodeled}; "
            f"modeled: {sorted(LaunchGeometry.MODELS)}")


# --------------------------------------------------------------------------
# timing harness
# --------------------------------------------------------------------------

class FakeClock:
    """Deterministic clock for tests: each call returns the previous time
    advanced by the next scripted delta (seconds), cycling when exhausted."""

    def __init__(self, deltas: Sequence[float] = (1e-3,), start: float = 0.0):
        if not deltas:
            raise ValueError("FakeClock needs at least one delta")
        self.deltas = tuple(float(d) for d in deltas)
        self.now = float(start)
        self.calls = 0

    def __call__(self) -> float:
        t = self.now
        self.now += self.deltas[self.calls % len(self.deltas)]
        self.calls += 1
        return t


@dataclass(frozen=True)
class TimingResult:
    """Samples from one timed measurement, all in microseconds."""

    samples_us: Tuple[float, ...]
    warmup_us: Tuple[float, ...] = ()

    @property
    def median_us(self) -> float:
        return float(np.median(self.samples_us))

    @property
    def best_us(self) -> float:
        return float(min(self.samples_us))

    @property
    def mean_us(self) -> float:
        return float(np.mean(self.samples_us))


def timeit(fn: Callable[[], Any], *, warmup: int = 2, repeats: int = 5,
           clock: Optional[Callable[[], float]] = None,
           block: bool = True) -> TimingResult:
    """Time ``fn`` (a thunk): ``warmup`` discarded runs, then ``repeats``
    measured ones.  Each run is bracketed by ``clock()`` and, when ``block``,
    drained with ``jax.block_until_ready`` so async dispatch does not leak
    compute into the next sample.  Returns all samples; callers take
    ``median_us`` (robust to scheduler noise)."""
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    clock = clock or time.perf_counter
    block_until_ready = None
    if block:
        import jax
        block_until_ready = jax.block_until_ready

    def one() -> float:
        t0 = clock()
        out = fn()
        if block_until_ready is not None:
            block_until_ready(out)
        return (clock() - t0) * 1e6

    warm = tuple(one() for _ in range(warmup))
    samples = tuple(one() for _ in range(repeats))
    return TimingResult(samples, warm)


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------

@runtime_checkable
class MeasurementBackend(Protocol):
    """What ``KernelLaunchEnv`` needs from a measurement source.

    ``measure`` maps a flat ``{"family.param": value}`` launch configuration
    to ``(counters, y)``: the system-event counters (the paper's C) and the
    latency objective in microseconds (``inf`` = infeasible).
    """

    counter_names: Tuple[str, ...]
    families: Tuple[str, ...]

    def measure(self, config: Dict[str, Any]
                ) -> Tuple[Dict[str, float], float]: ...

    def measure_batch(self, configs: Sequence[Dict[str, Any]]
                      ) -> List[Tuple[Dict[str, float], float]]: ...


class AnalyticBackend:
    """The launch-geometry model as a measurement backend.

    Bit-identical to the pre-backend ``KernelLaunchEnv.measure``: same
    accumulation order over sorted families, same VMEM feasibility gate, and
    the multiplicative noise draw is taken from ``default_rng(seed + 13)``
    only for feasible configurations.
    """

    counter_names = COUNTER_NAMES

    def __init__(self, workload: KernelWorkload, families: Iterable[str],
                 seed: int = 0, *, hardware: Optional[HardwareSpec] = None):
        self.workload = workload
        self.families = tuple(sorted(families))
        _check_modeled(self.families)
        self.hardware = hardware or HardwareSpec()
        self.geometry = LaunchGeometry(workload, self.hardware)
        self._noise_rng = np.random.default_rng(seed + 13)

    def _sigma(self, total_us: float) -> float:
        """Relative noise scale for one measurement (constant here; the
        shifted backend makes it latency-dependent)."""
        return self.workload.noise

    def measure(self, config: Dict[str, Any]) -> Tuple[Dict[str, float], float]:
        counters, total_us, feasible = self.geometry.totals(
            self.families, config)
        if not feasible:
            return counters, float("inf")
        y = total_us * (1.0 + self._sigma(total_us)
                        * float(self._noise_rng.standard_normal()))
        return counters, y

    def measure_batch(self, configs: Sequence[Dict[str, Any]]
                      ) -> List[Tuple[Dict[str, float], float]]:
        """Vectorized q-batch: one geometry pass per member, ONE noise draw
        for all feasible members.  ``Generator.standard_normal(n)`` fills
        arrays from the same stream as n scalar draws, so the results are
        bit-identical to sequential :meth:`measure` calls in order —
        infeasible members draw nothing, exactly like the scalar path."""
        metas = [self.geometry.totals(self.families, c) for c in configs]
        n_feasible = sum(1 for _, _, feasible in metas if feasible)
        noise = (self._noise_rng.standard_normal(n_feasible)
                 if n_feasible else np.empty(0))
        out: List[Tuple[Dict[str, float], float]] = []
        j = 0
        for counters, total_us, feasible in metas:
            if not feasible:
                out.append((counters, float("inf")))
                continue
            y = total_us * (1.0 + self._sigma(total_us) * float(noise[j]))
            j += 1
            out.append((counters, y))
        return out


class ShiftedAnalyticBackend(AnalyticBackend):
    """An analytic target environment a fixed distance from the source.

    ``shifts`` (a shift-kind name or a sequence of :class:`EnvShift`) are
    composed onto the base workload and the default :class:`HardwareSpec`,
    and the geometry model prices against the shifted pair.  Everything is
    seeded and CPU-cheap, so source→target fidelity gaps (the paper's
    environmental changes) are reproducible in CI.

    Heteroscedastic noise: a shift's ``hetero_noise`` adds a latency-
    dependent component ``hetero * t / (t + HETERO_PIVOT_US)`` to the
    relative noise — slow configurations measure noisier than fast ones, so
    the target's noise floor is configuration-dependent (unlike the source).
    """

    HETERO_PIVOT_US = 1e4

    def __init__(self, workload: KernelWorkload, families: Iterable[str],
                 seed: int = 0, *,
                 shifts: Union[str, Sequence[EnvShift]] = ()):
        if isinstance(shifts, str):
            shifts = shifts_for(shifts)
        self.shifts = tuple(shifts)
        self.base_workload = workload
        shifted, hardware = workload, HardwareSpec()
        for s in self.shifts:
            shifted, hardware = s.apply(shifted, hardware)
        super().__init__(shifted, families, seed, hardware=hardware)
        self._hetero = float(sum(s.hetero_noise for s in self.shifts))

    @property
    def shift_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.shifts)

    def _sigma(self, total_us: float) -> float:
        return (self.workload.noise + self._hetero
                * total_us / (total_us + self.HETERO_PIVOT_US))


class WallClockBackend:
    """Timed execution of the real kernels under the candidate config.

    Each family's representative workload arrays are dispatched through
    ``repro.kernels.dispatch`` (so ``REPRO_KERNEL_MODE`` picks pallas /
    interpret / ref exactly as in production), jit-compiled once per distinct
    launch-parameter tuple, and timed with warmup + ``block_until_ready`` +
    median-of-k.  Counters and the VMEM feasibility gate still come from the
    geometry model — they are exact derived quantities, and configurations
    the VMEM model rejects would fail to compile on hardware, so they return
    ``inf`` without being run.
    """

    counter_names = COUNTER_NAMES

    def __init__(self, workload: KernelWorkload, families: Iterable[str],
                 seed: int = 0, *, mode: Optional[str] = None,
                 warmup: int = 1, repeats: int = 3,
                 clock: Optional[Callable[[], float]] = None):
        self.workload = workload
        self.families = tuple(sorted(families))
        _check_modeled(self.families)
        self.geometry = LaunchGeometry(workload)
        self.mode = mode
        self.warmup = warmup
        self.repeats = repeats
        self.clock = clock
        self._input_rng = np.random.default_rng(seed)
        self._inputs: Dict[str, Tuple[Any, ...]] = {}
        self._jitted: Dict[Tuple[str, Tuple[Tuple[str, Any], ...]], Callable] = {}

    # -- representative inputs ------------------------------------------

    def _build_inputs(self, family: str) -> Tuple[Any, ...]:
        import jax.numpy as jnp

        w, rng = self.workload, self._input_rng

        def arr(*shape):
            return jnp.asarray(rng.normal(size=shape).astype(np.float32))

        if family == "flash_attention":
            return (arr(w.batch, w.seq_len, w.heads, w.head_dim),
                    arr(w.batch, w.seq_len, w.kv_heads, w.head_dim),
                    arr(w.batch, w.seq_len, w.kv_heads, w.head_dim))
        if family == "mamba_scan":
            x = arr(w.batch, w.seq_len, w.channels)
            dt = jnp.abs(arr(w.batch, w.seq_len, w.channels)) * 0.05
            A = -jnp.abs(arr(w.channels, w.scan_state))
            B = arr(w.batch, w.seq_len, w.scan_state)
            C = arr(w.batch, w.seq_len, w.scan_state)
            D = jnp.ones((w.channels,), jnp.float32)
            return (x, dt, A, B, C, D)
        if family == "ssd":
            x = arr(w.batch, w.seq_len, w.ssm_heads, w.ssm_head_dim)
            dt = jnp.abs(arr(w.batch, w.seq_len, w.ssm_heads)) * 0.05
            A = -jnp.abs(arr(w.ssm_heads))
            B = arr(w.batch, w.seq_len, 1, w.ssm_state)
            C = arr(w.batch, w.seq_len, 1, w.ssm_state)
            D = jnp.ones((w.ssm_heads,), jnp.float32)
            return (x, dt, A, B, C, D)
        if family == "rmsnorm":
            return (arr(w.batch, w.seq_len, w.d_model), arr(w.d_model))
        if family == "paged_attention":
            # the pool arrays' shapes depend on the candidate's page_size
            # launch parameter, but this backend's inputs are built once per
            # family and reused across configs — honest paged timings need
            # the replay environment (a real batcher), not this harness
            raise KeyError(
                "paged_attention has no config-independent representative "
                "inputs (the KV pool shape IS the launch config); measure "
                "it through ReplayServingEnv instead")
        raise KeyError(f"no representative workload for family {family!r}")

    def _family_inputs(self, family: str) -> Tuple[Any, ...]:
        if family not in self._inputs:
            self._inputs[family] = self._build_inputs(family)
        return self._inputs[family]

    def _jitted_for(self, family: str, params: Dict[str, Any]) -> Callable:
        import jax

        from repro.kernels import dispatch

        key = (family, tuple(sorted(params.items())))
        if key not in self._jitted:
            mode = self.mode
            frozen = dict(params)

            def call(*args):
                # exclusively install the candidate as the ACTIVE config for
                # the trace: explicit dispatch kwargs would lose to any outer
                # use_launch_config (e.g. measuring inside result.install()),
                # and the poisoned trace would be cached under this key
                with dispatch.use_launch_config({family: frozen},
                                                exclusive=True):
                    return dispatch.dispatch(family, *args, mode=mode)

            self._jitted[key] = jax.jit(call)
        return self._jitted[key]

    # -- MeasurementBackend ---------------------------------------------

    def measure(self, config: Dict[str, Any]) -> Tuple[Dict[str, float], float]:
        counters, _, feasible = self.geometry.totals(self.families, config)
        if not feasible:
            return counters, float("inf")
        total_us = 0.0
        for family in self.families:
            fn = self._jitted_for(family, family_params(family, config))
            args = self._family_inputs(family)
            res = timeit(lambda: fn(*args), warmup=self.warmup,
                         repeats=self.repeats, clock=self.clock)
            total_us += res.median_us
        return counters, total_us

    def measure_batch(self, configs: Sequence[Dict[str, Any]]
                      ) -> List[Tuple[Dict[str, float], float]]:
        """Q-batch timing that reuses the jit cache across the batch: each
        member's families compile (or hit ``self._jitted``) once per
        distinct launch-parameter tuple, and members with identical launch
        parameters share one timed measurement instead of re-timing the
        same compiled kernels."""
        out: List[Optional[Tuple[Dict[str, float], float]]] = [None] * len(configs)
        shared: Dict[tuple, Tuple[Dict[str, float], float]] = {}
        for i, config in enumerate(configs):
            key = tuple((f, tuple(sorted(family_params(f, config).items())))
                        for f in self.families)
            if key not in shared:
                shared[key] = self.measure(config)
            out[i] = shared[key]
        return list(out)


# --------------------------------------------------------------------------
# selection
# --------------------------------------------------------------------------

#: name -> backend class; :func:`register_backend` extends it.  The
#: ``shifted:<kind>`` family is prefix-routed on top of these keys.
BACKEND_FACTORIES: Dict[str, Callable[..., MeasurementBackend]] = {
    ANALYTIC: AnalyticBackend,
    WALLCLOCK: WallClockBackend,
}


def register_backend(name: str,
                     factory: Callable[..., MeasurementBackend]) -> None:
    """Register a backend class under ``name`` — it becomes selectable
    everywhere a backend name is accepted (constructor args, CLI flags, the
    ``REPRO_MEASURE_BACKEND`` env var)."""
    if name in BACKEND_FACTORIES or name.startswith(SHIFTED_PREFIX):
        raise ValueError(f"measurement backend {name!r} already registered")
    BACKEND_FACTORIES[name] = factory


def backend_names() -> Tuple[str, ...]:
    """Every valid backend spelling: registry keys plus the registered
    ``shifted:<kind>`` forms."""
    return tuple(sorted(BACKEND_FACTORIES)
                 + [SHIFTED_PREFIX + k for k in sorted(SHIFT_KINDS)])


def resolve_backend_name(explicit: Optional[str] = None) -> str:
    """Backend precedence: explicit argument > env var > analytic.

    ``shifted:<kind>`` (e.g. ``shifted:hardware``) names a
    :class:`ShiftedAnalyticBackend` with that registered shift kind, so an
    environment-shifted target is selectable through the same
    ``REPRO_MEASURE_BACKEND`` plumbing as the real backends.  Unknown names
    (including unknown shift kinds) raise ``ValueError`` carrying the full
    list of valid spellings."""
    name = explicit or os.environ.get(MEASURE_BACKEND_ENV, "") or ANALYTIC
    if name.startswith(SHIFTED_PREFIX):
        kind = name[len(SHIFTED_PREFIX):]
        if kind in SHIFT_KINDS:
            return name
    elif name in BACKEND_FACTORIES:
        return name
    source = "argument" if explicit else f"{MEASURE_BACKEND_ENV} env var"
    raise ValueError(
        f"unknown measurement backend {name!r} (from {source}); "
        f"valid: {list(backend_names())}")


def make_backend(name: Optional[str], workload: KernelWorkload,
                 families: Iterable[str], seed: int = 0,
                 **kw: Any) -> MeasurementBackend:
    """Instantiate a backend by name (``None`` -> env var -> analytic).
    Keyword arguments are forwarded to the backend constructor."""
    resolved = resolve_backend_name(name)
    if resolved.startswith(SHIFTED_PREFIX):
        return ShiftedAnalyticBackend(
            workload, families, seed,
            shifts=resolved[len(SHIFTED_PREFIX):], **kw)
    return BACKEND_FACTORIES[resolved](workload, families, seed, **kw)
