"""Analytic TPU performance environment — the benchmark workhorse.

Deterministic (plus measurement noise) roofline + contention model of one
(architecture x shape x mesh x hardware) cell of the framework, with the
parallelism plan as the configuration space.  It exists because the paper's
evaluation needs hundreds of tuning iterations x 6 methods x seeds x
environments — the compiled dry-run (``repro.tuner.compiled_env``) is the
ground-truth backend but costs ~10 s per intervention.

The model reproduces the paper's *spurious correlation mechanism*: e.g.
``collective_bytes`` correlates positively with step time in a
bandwidth-degraded environment (cross-pod or v5e links) but negatively in a
compute-bound one (higher TP adds collective bytes yet removes step time),
exactly like IPC in Fig. 2 — while ``remat``/``microbatch`` effects stay
invariant.  Configuration interactions and invalid configurations
(divisibility, HBM overflow) are first-class.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.spaces import ConfigSpace, Option
from repro.envs.base import PooledEnv
from repro.utils.hardware import HARDWARE, HardwareSpec, TPU_V5E


@dataclass(frozen=True)
class ArchDims:
    name: str
    params: float            # total parameters
    active_params: float     # = params for dense
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    moe: bool = False


ARCH_DIMS = {
    "llama3.2-1b": ArchDims("llama3.2-1b", 1.24e9, 1.24e9, 2048, 16, 32, 8),
    "nemotron-4-15b": ArchDims("nemotron-4-15b", 15.2e9, 15.2e9, 6144, 32, 48, 8),
    "command-r-35b": ArchDims("command-r-35b", 35e9, 35e9, 8192, 40, 64, 8),
    "falcon-mamba-7b": ArchDims("falcon-mamba-7b", 7.3e9, 7.3e9, 4096, 64, 0, 0),
    "deepseek-v3-671b": ArchDims("deepseek-v3-671b", 671e9, 37e9, 7168, 61, 128, 128, moe=True),
}


@dataclass(frozen=True)
class TPUEnvSpec:
    """One environment: hardware x workload x software x topology."""
    arch: str = "llama3.2-1b"
    hardware: str = "tpu_v5e"
    seq_len: int = 4096
    global_batch: int = 256
    chips: int = 256
    cross_pod: bool = False
    noise: float = 0.02


def tpu_config_space(arch: str = "llama3.2-1b") -> ConfigSpace:
    dims = ARCH_DIMS[arch]
    opts = [
        Option("tp", (1, 2, 4, 8, 16, 32), default=8),
        Option("microbatch", (1, 2, 4, 8), default=1),
        Option("remat", ("none", "dots", "full"), default="none",
               kind="categorical"),
        Option("seq_parallel", (0, 1), default=0, kind="boolean"),
        Option("grad_compression", ("none", "bf16", "int8"), default="none",
               kind="categorical"),
        Option("attn_kv_block", (256, 512, 1024, 2048), default=1024),
        Option("collective_overlap", (0, 1), default=0, kind="boolean"),
        Option("compute_dtype", ("bf16", "f32"), default="bf16",
               kind="categorical"),
    ]
    if dims.moe:
        opts.append(Option("ep", (1, 4, 16, 64), default=16))
        opts.append(Option("capacity_factor", (1.0, 1.25, 1.5, 2.0),
                           default=1.25))
    if dims.n_heads == 0:  # attention-free: scan chunk replaces attn block
        opts = [o for o in opts if o.name != "attn_kv_block"]
        opts.append(Option("scan_chunk", (64, 128, 256, 512), default=256))
    return ConfigSpace(opts)


_REMAT_FLOPS = {"none": 1.0, "dots": 1.18, "full": 1.34}
_REMAT_BYTES = {"none": 1.55, "dots": 1.0, "full": 0.62}
_COMP_BYTES = {"none": 4.0, "bf16": 2.0, "int8": 1.0}


class AnalyticTPUEnv(PooledEnv):
    counter_names = ("flops_per_chip", "hbm_bytes", "collective_bytes",
                     "peak_mem_gb", "compute_s", "memory_s", "collective_s",
                     "energy")

    #: objective selector — "step_time" (default) or "energy"
    objective: str = "step_time"

    def __init__(self, spec: TPUEnvSpec, seed: int = 0):
        self.spec = spec
        self.dims = ARCH_DIMS[spec.arch]
        self.hw = HARDWARE[spec.hardware]
        super().__init__(tpu_config_space(spec.arch), self.counter_names,
                         seed=seed)
        self._rng = np.random.default_rng(seed + 7)

    # -- the performance model ------------------------------------------

    def _step_model(self, config) -> Tuple[Dict[str, float], float, bool]:
        s, d = self.spec, self.dims
        hw = self.hw
        tp = int(config["tp"])
        micro = int(config["microbatch"])
        remat = str(config["remat"])
        sp = bool(config.get("seq_parallel", 0))
        comp = str(config.get("grad_compression", "none"))
        dtype = str(config.get("compute_dtype", "bf16"))
        kv_block = int(config.get("attn_kv_block", 1024))
        chunk = int(config.get("scan_chunk", 256))
        overlap = bool(config.get("collective_overlap", 0))
        ep = int(config.get("ep", 1))
        cap = float(config.get("capacity_factor", 1.25))

        # ---- validity -----------------------------------------------------
        valid = True
        if tp > s.chips:
            valid = False
        dp = max(s.chips // tp, 1)
        if s.global_batch % (dp * micro) != 0:
            valid = False
        if d.n_heads and tp > d.n_heads:
            valid = False
        if d.moe and ep > 256:
            valid = False

        tokens = s.global_batch * s.seq_len
        peak = hw.peak_flops_bf16 * (1.0 if dtype == "bf16" else 0.45)

        # ---- compute ------------------------------------------------------
        flops = 6.0 * d.active_params * tokens / s.chips
        flops *= _REMAT_FLOPS[remat]
        if d.moe:
            flops *= cap / 1.25  # capacity padding wastes expert compute
        if d.n_heads:
            attn_flops = (12.0 * d.n_layers * s.seq_len * s.seq_len
                          * d.d_model * s.global_batch / s.chips)
            flops += attn_flops * _REMAT_FLOPS[remat]
        # skinny-matmul MXU derate: per-chip matmul width d_ff/tp
        width = max(d.d_model * 4 // max(tp, 1), 1)
        mxu_eff = min(1.0, 0.55 + 0.45 * min(width / 1024.0, 1.0))
        compute_s = flops / (peak * mxu_eff)

        # ---- memory ---------------------------------------------------------
        bpe = 2.0 if dtype == "bf16" else 4.0
        act_bytes = (28.0 * tokens * d.d_model * bpe / s.chips
                     * _REMAT_BYTES[remat] * d.n_layers / 16.0)
        if sp:
            act_bytes /= min(tp, 4)  # sequence-sharded norms/residuals
        param_traffic = 3.0 * d.params * 2.0 / s.chips
        kv_ineff = 1.0 + (0.25 if kv_block > 1024 else 0.0) \
            + (0.15 if kv_block < 512 else 0.0)
        scan_ineff = 1.0 + (0.2 if chunk < 128 else 0.0) \
            + (0.1 if chunk > 256 else 0.0)
        hbm_bytes = (act_bytes * kv_ineff * scan_ineff + param_traffic)
        memory_s = hbm_bytes / hw.hbm_bandwidth

        # HBM capacity: optimizer + params + activations working set
        opt_state = d.params * 12.0 / s.chips
        act_resident = act_bytes / max(micro, 1)
        peak_mem = opt_state + act_resident + d.params * 2.0 / s.chips
        if peak_mem > hw.hbm_capacity:
            valid = False

        # ---- collectives ----------------------------------------------------
        link = hw.dci_bandwidth if s.cross_pod else hw.ici_bandwidth
        tp_coll = (2.0 * tokens * d.d_model * bpe / dp
                   * (tp - 1) / max(tp, 1)) / max(tp, 1)
        if sp:
            tp_coll *= 0.7  # reduce-scatter/all-gather replaces all-reduce
        dp_coll = d.params * _COMP_BYTES[comp] / s.chips * (dp - 1) / max(dp, 1)
        moe_coll = 0.0
        if d.moe:
            moe_coll = 2.0 * tokens * d.d_model * bpe / s.chips \
                * min(ep, 8) / 8.0
        coll_bytes = tp_coll + dp_coll + moe_coll
        collective_s = coll_bytes / link
        if overlap:
            collective_s = max(collective_s - 0.55 * compute_s, 0.15 * collective_s)

        # microbatching: pipeline fill bubbles on collectives, smaller working set
        collective_s *= 1.0 + 0.03 * (micro - 1)

        step = compute_s + memory_s + collective_s
        # per-step energy: busy chips draw more when MXU-utilized; f32 and
        # high capacity factors burn extra joules per useful token
        util = compute_s / max(step, 1e-12)
        watts = 160.0 + 260.0 * util + (40.0 if dtype == "f32" else 0.0)
        energy = step * watts * s.chips
        counters = {
            "flops_per_chip": flops,
            "hbm_bytes": hbm_bytes,
            "collective_bytes": coll_bytes,
            "peak_mem_gb": peak_mem / 2 ** 30,
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "energy": energy,
        }
        return counters, step, valid

    def _measure(self, config) -> Tuple[Dict[str, float], float]:
        counters, step, valid = self._step_model(config)
        if not valid:
            return counters, float("inf")
        noise = 1.0 + self.spec.noise * float(self._rng.standard_normal())
        y = counters["energy"] if self.objective == "energy" else step
        return counters, float(y * max(noise, 0.5))

    # -- ground truth for RE% -------------------------------------------

    def optimum(self, max_points: int = 4096) -> Tuple[Dict, float]:
        best, best_cfg = math.inf, None
        rng = np.random.default_rng(123)
        for cfg in self.space.grid(max_points, rng):
            counters, step, valid = self._step_model(cfg)
            y = counters["energy"] if self.objective == "energy" else step
            if valid and y < best:
                best, best_cfg = y, cfg
        return best_cfg, float(best)


class PaddedAnalyticEnv(AnalyticTPUEnv):
    """Analytic env with a long tail of weak/inert extra options (real
    configuration spaces have dozens of knobs with tiny effects — Tables
    7-12 of the paper list 28-100+). The pads perturb the objective by a
    small deterministic amount and leak weak correlations into synthetic
    event counters, so model-free optimizers must spend budget ruling them
    out while causal ranking prunes them offline."""

    N_PAD_EVENTS = 3

    def __init__(self, spec: TPUEnvSpec, extra_options: int = 0,
                 seed: int = 0):
        super().__init__(spec, seed=seed)
        self.extra_options = extra_options
        if extra_options:
            opts = list(self.space.options)
            for i in range(extra_options):
                opts.append(Option(f"pad{i}", (0, 1, 2, 3), default=0))
            self.space = ConfigSpace(opts)
        self._pad_rng = np.random.default_rng(1234)  # env-invariant weights
        self._pad_w = self._pad_rng.normal(size=max(extra_options, 1)) * 0.004
        self.counter_names = AnalyticTPUEnv.counter_names + tuple(
            f"pad_evt{i}" for i in range(self.N_PAD_EVENTS))

    def _measure(self, config):
        counters, y = super()._measure(config)
        bump = sum(self._pad_w[i] * float(config.get(f"pad{i}", 0))
                   for i in range(self.extra_options))
        import zlib
        key = zlib.crc32(repr(sorted(config.items())).encode())  # stable
        nz = np.random.default_rng(key)
        for i in range(self.N_PAD_EVENTS):
            counters[f"pad_evt{i}"] = (
                float(config.get(f"pad{i}", 0)) * 0.3
                + 0.1 * nz.standard_normal())
        if np.isfinite(y):
            y = y * (1.0 + bump)
        return counters, y

    def optimum(self, max_points: int = 4096):
        cfg, y = super().optimum(max_points)
        # pads at their best values shave at most sum(min(w*v)) off
        return cfg, y


def environment_pair(change: str, seed: int = 0, padded: int = 16
                     ) -> Tuple[AnalyticTPUEnv, AnalyticTPUEnv]:
    """The paper's four environmental-change axes, instantiated natively."""
    base = TPUEnvSpec()
    if change == "hardware":
        tgt = replace(base, hardware="tpu_v4_like")
    elif change == "workload":
        tgt = replace(base, seq_len=32768, global_batch=32)
    elif change == "software":
        tgt = replace(base, arch="nemotron-4-15b")
    elif change == "topology":
        tgt = replace(base, chips=512, cross_pod=True)
    elif change == "severe":
        tgt = replace(base, arch="command-r-35b", hardware="tpu_v4_like",
                      seq_len=32768, global_batch=32, chips=512,
                      cross_pod=True)
    else:
        raise ValueError(change)
    if padded:
        return (PaddedAnalyticEnv(base, padded, seed=seed),
                PaddedAnalyticEnv(tgt, padded, seed=seed + 1))
    return (AnalyticTPUEnv(base, seed=seed),
            AnalyticTPUEnv(tgt, seed=seed + 1))
