"""Serving-stack tuning environment: the whole serving configuration —
scheduler knobs joined with kernel launch geometry — as a CAMEO PerfEnv
whose environment axis is the request workload.

The configuration space is :func:`repro.workloads.sim.serving_space`:
``serving.*`` scheduler options (decode slots, admission chunk, cache
length, interleave policy) plus the ``family.param`` launch options of the
dispatch registry.  Measurement runs the deterministic continuous-batching
simulator (:class:`repro.workloads.sim.ServingSimulator`) over ONE fixed
trace realization per environment instance, so configurations are compared
under the identical arrival process and the paper's environment change is a
*workload swap*: two ``ServingEnv`` with different trace specs are a
source→target transfer pair (see :func:`make_serving_pair` and
``repro.tuner.bench.run_serving_bench``).

Objectives:

- ``latency`` (default): minimize the p99 request latency (modeled us);
- ``throughput``: maximize completed requests per modeled second, under the
  SLO as a constraint — ``query_text`` emits "maximize throughput for which
  latency is less than <slo_us> ...", exercising the direction-aware
  infeasibility path end-to-end.

Infeasible configurations (VMEM-overflowing launch blocks, a cache_len the
trace does not fit in) measure as ``inf`` in the minimize direction and
``-inf`` in the maximize direction.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Tuple, Union

import numpy as np

from repro.envs import measure as measure_mod
from repro.envs.base import PooledEnv
from repro.envs.measure import HardwareSpec, KernelWorkload
from repro.kernels import dispatch
from repro.workloads.sim import (SIM_COUNTER_NAMES, ServingPlan,
                                 ServingSimulator, SimReport, serving_space)
from repro.workloads.traces import Trace, TraceWorkload, make_workload

OBJECTIVES = ("latency", "throughput")


class ServingEnv(PooledEnv):
    """PerfEnv over the serving stack for one workload trace.

    ``workload`` is a spec string (``make_workload`` grammar), a bound
    :class:`TraceWorkload`, or an already-generated :class:`Trace`.  ``cell``
    fixes the served model's kernel dimensions; ``families`` the kernel
    families it dispatches (default: every modeled registered family).  The
    trace realization is drawn once at construction from ``trace_seed``
    (default ``seed``) — every measurement replays the same arrivals.
    """

    def __init__(self, workload: Union[str, TraceWorkload, Trace] = "poisson",
                 cell: Optional[KernelWorkload] = None,
                 families: Optional[Iterable[str]] = None, seed: int = 0,
                 *, objective: str = "latency", slo_us: float = 2_000.0,
                 hardware: Optional[HardwareSpec] = None,
                 trace_seed: Optional[int] = None):
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown serving objective {objective!r}; "
                             f"known: {sorted(OBJECTIVES)}")
        self.cell = cell or KernelWorkload()
        if families is None:
            modeled = measure_mod.modeled_families()
            families = [f for f in dispatch.families() if f in modeled]
        self.families = tuple(sorted(families))
        if isinstance(workload, str):
            workload = make_workload(workload)
        if isinstance(workload, Trace):
            self.trace = workload
            self.workload_spec = workload.spec
        else:
            self.trace = workload.generate(
                seed if trace_seed is None else trace_seed)
            self.workload_spec = workload.spec
        self.objective = objective
        self.maximize = objective == "throughput"
        self.slo_us = float(slo_us)
        self.sim = ServingSimulator(self.cell, self.families,
                                    hardware=hardware, slo_us=self.slo_us)
        self._noise_rng = np.random.default_rng(seed + 13)
        super().__init__(serving_space(self.families), SIM_COUNTER_NAMES,
                         seed=seed)

    @property
    def query_text(self) -> str:
        """The query ``transfer_tune`` should run this environment under
        (``{budget}`` left for the runner to fill)."""
        if self.maximize:
            return (f"maximize throughput for which latency is less than "
                    f"{self.slo_us:g} within {{budget}} samples")
        return "minimize latency within {budget} samples"

    def simulate(self, config: Dict[str, Any]) -> SimReport:
        """The raw (noise-free) simulator report for one configuration."""
        return self.sim.run(self.trace, ServingPlan.from_config(config),
                            config)

    def _measure(self, config: Dict[str, Any]
                 ) -> Tuple[Dict[str, float], float]:
        report = self.simulate(config)
        counters = report.counters()
        if not report.feasible:
            return counters, float("-inf" if self.maximize else "inf")
        y = (report.throughput_rps if self.maximize
             else report.p99_latency_us)
        y *= 1.0 + self.cell.noise * float(self._noise_rng.standard_normal())
        return counters, y

    # -- deployment -----------------------------------------------------

    @staticmethod
    def plan_of(config: Dict[str, Any]) -> ServingPlan:
        """The scheduler half of a tuned configuration — feed its fields to
        :class:`repro.serving.scheduler.ContinuousBatcher`."""
        return ServingPlan.from_config(config)

    def apply(self, config: Dict[str, Any]):
        """Context manager installing the kernel-launch half on the dispatch
        registry (the scheduler half deploys via :meth:`plan_of`)."""
        from repro.tuner.space import launch_config_of

        return dispatch.use_launch_config(launch_config_of(config))


def make_serving_pair(source: Union[str, TraceWorkload],
                      target: Union[str, TraceWorkload],
                      cell: Optional[KernelWorkload] = None,
                      families: Optional[Iterable[str]] = None,
                      seed: int = 0, **kw: Any
                      ) -> Tuple[ServingEnv, ServingEnv]:
    """(source, target) serving environments differing ONLY in workload —
    the paper's workload-fluctuation environment change.  Identical
    configuration space; independent measurement-noise streams."""
    src = ServingEnv(source, cell, families, seed=seed + 1, **kw)
    tgt = ServingEnv(target, cell, src.families, seed=seed + 2, **kw)
    return src, tgt
