"""Serving-stack tuning environment: the whole serving configuration —
scheduler knobs joined with kernel launch geometry — as a CAMEO PerfEnv
whose environment axis is the request workload.

The configuration space is :func:`repro.workloads.sim.serving_space`:
``serving.*`` scheduler options (decode slots, admission chunk, cache
length, interleave policy) plus the ``family.param`` launch options of the
dispatch registry.  Measurement runs the deterministic continuous-batching
simulator (:class:`repro.workloads.sim.ServingSimulator`) over ONE fixed
trace realization per environment instance, so configurations are compared
under the identical arrival process and the paper's environment change is a
*workload swap*: two ``ServingEnv`` with different trace specs are a
source→target transfer pair (see :func:`make_serving_pair` and
``repro.tuner.bench.run_serving_bench``).

Objectives:

- ``latency`` (default): minimize the p99 request latency (modeled us);
- ``throughput``: maximize completed requests per modeled second, under the
  SLO as a constraint — ``query_text`` emits "maximize throughput for which
  latency is less than <slo_us> ...", exercising the direction-aware
  infeasibility path end-to-end.

Infeasible configurations (VMEM-overflowing launch blocks, a cache_len the
trace does not fit in) measure as ``inf`` in the minimize direction and
``-inf`` in the maximize direction.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from repro.envs import measure as measure_mod
from repro.envs.base import PooledEnv
from repro.envs.measure import EnvShift, HardwareSpec, KernelWorkload
from repro.kernels import dispatch
from repro.workloads.sim import (FLEET_COUNTER_NAMES, SIM_COUNTER_NAMES,
                                 FleetPlan, FleetSimulator, FleetSpec,
                                 ServingPlan, ServingSimulator, SimReport,
                                 serving_space, stalled_report)
from repro.workloads.traces import Trace, TraceWorkload, make_workload

OBJECTIVES = ("latency", "throughput")

#: seed salt for the straggler placement draw — fixed so the SAME devices
#: straggle for every environment instance over the same substrate (the
#: straggler set is part of the environment, not of any env's noise stream)
_STRAGGLER_SALT = 0x57A6


def _resolve_shifts(shifts: Union[str, Sequence[EnvShift]]
                    ) -> Tuple[EnvShift, ...]:
    if isinstance(shifts, str):
        return measure_mod.shifts_for(shifts)
    return tuple(shifts)


def fleet_spec_for(shifts: Sequence[EnvShift],
                   num_devices: int = 8) -> FleetSpec:
    """The deployment substrate the composed ``shifts`` leave behind:
    ``device_scale`` resizes the fleet (elastic preemption), and
    ``straggler_frac``/``straggler_slowdown`` place slow devices.  The
    straggler set depends only on the substrate (device count, slow count),
    NOT on any environment seed — target optimum sweeps and tuning runs at
    different seeds must agree on which devices limp."""
    devices = num_devices
    frac = 0.0
    slowdown = 1.0
    for s in shifts:
        devices = max(1, int(round(devices * s.device_scale)))
        frac = max(frac, s.straggler_frac)
        slowdown *= s.straggler_slowdown
    n_slow = int(round(frac * devices))
    if n_slow == 0 or slowdown <= 1.0:
        return FleetSpec(num_devices=devices)
    rng = np.random.default_rng([devices, n_slow, _STRAGGLER_SALT])
    slow = tuple(sorted(int(d) for d in
                        rng.choice(devices, size=n_slow, replace=False)))
    return FleetSpec(num_devices=devices, slow_devices=slow,
                     slowdown=slowdown)


class ServingEnv(PooledEnv):
    """PerfEnv over the serving stack for one workload trace.

    ``workload`` is a spec string (``make_workload`` grammar), a bound
    :class:`TraceWorkload`, or an already-generated :class:`Trace`.  ``cell``
    fixes the served model's kernel dimensions; ``families`` the kernel
    families it dispatches (default: every modeled registered family).  The
    trace realization is drawn once at construction from ``trace_seed``
    (default ``seed``) — every measurement replays the same arrivals.
    """

    def __init__(self, workload: Union[str, TraceWorkload, Trace] = "poisson",
                 cell: Optional[KernelWorkload] = None,
                 families: Optional[Iterable[str]] = None, seed: int = 0,
                 *, objective: str = "latency", slo_us: float = 2_000.0,
                 hardware: Optional[HardwareSpec] = None,
                 trace_seed: Optional[int] = None, fleet: bool = False,
                 shifts: Union[str, Sequence[EnvShift]] = (),
                 num_devices: int = 8):
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown serving objective {objective!r}; "
                             f"known: {sorted(OBJECTIVES)}")
        self.cell = cell or KernelWorkload()
        if families is None:
            modeled = measure_mod.modeled_families()
            families = [f for f in dispatch.families() if f in modeled]
        self.families = tuple(sorted(families))
        if isinstance(workload, str):
            workload = make_workload(workload)
        if isinstance(workload, Trace):
            self.trace = workload
            self.workload_spec = workload.spec
        else:
            self.trace = workload.generate(
                seed if trace_seed is None else trace_seed)
            self.workload_spec = workload.spec
        self.objective = objective
        self.maximize = objective == "throughput"
        self.slo_us = float(slo_us)
        # environment shifts rewrite the substrate this env prices against:
        # the model cell + hardware (all kinds) and the fleet spec
        # (straggler/resize kinds) — the trace realization is untouched
        self.shifts = _resolve_shifts(shifts)
        shifted_hw = hardware or HardwareSpec()
        shifted_cell = self.cell
        for s in self.shifts:
            shifted_cell, shifted_hw = s.apply(shifted_cell, shifted_hw)
        self.fleet = bool(fleet)
        if self.fleet:
            self.fleet_spec = fleet_spec_for(self.shifts, num_devices)
            self.sim = FleetSimulator(
                shifted_cell, self.families, hardware=shifted_hw,
                slo_us=self.slo_us, fleet=self.fleet_spec)
        else:
            self.fleet_spec = None
            self.sim = ServingSimulator(shifted_cell, self.families,
                                        hardware=shifted_hw,
                                        slo_us=self.slo_us)
        self._noise_rng = np.random.default_rng(seed + 13)
        super().__init__(serving_space(self.families, fleet=self.fleet),
                         FLEET_COUNTER_NAMES if self.fleet
                         else SIM_COUNTER_NAMES, seed=seed)

    @property
    def query_text(self) -> str:
        """The query ``transfer_tune`` should run this environment under
        (``{budget}`` left for the runner to fill)."""
        if self.maximize:
            return (f"maximize throughput for which latency is less than "
                    f"{self.slo_us:g} within {{budget}} samples")
        return "minimize latency within {budget} samples"

    def simulate(self, config: Dict[str, Any]) -> SimReport:
        """The raw (noise-free) simulator report for one configuration."""
        plan = ServingPlan.from_config(config)
        if self.fleet:
            return self.sim.run(self.trace, plan,
                                FleetPlan.from_config(config), config)
        return self.sim.run(self.trace, plan, config)

    def _measure(self, config: Dict[str, Any]
                 ) -> Tuple[Dict[str, float], float]:
        from repro.serving.scheduler import DrainStall

        try:
            report = self.simulate(config)
        except DrainStall:
            # a deployment that cannot drain its own trace (e.g. a starved
            # page pool serializing every request) prices as infeasible
            report = stalled_report(
                len(self.trace.requests),
                FleetPlan.from_config(config) if self.fleet else None)
        counters = report.counters()
        if not report.feasible:
            return counters, float("-inf" if self.maximize else "inf")
        y = (report.throughput_rps if self.maximize
             else report.p99_latency_us)
        y *= 1.0 + self.cell.noise * float(self._noise_rng.standard_normal())
        return counters, y

    # -- deployment -----------------------------------------------------

    @staticmethod
    def plan_of(config: Dict[str, Any]) -> ServingPlan:
        """The scheduler half of a tuned configuration — feed its fields to
        :class:`repro.serving.scheduler.ContinuousBatcher`."""
        return ServingPlan.from_config(config)

    def apply(self, config: Dict[str, Any]):
        """Context manager installing the kernel-launch half on the dispatch
        registry (the scheduler half deploys via :meth:`plan_of`)."""
        from repro.tuner.space import launch_config_of

        return dispatch.use_launch_config(launch_config_of(config))


def make_serving_pair(source: Union[str, TraceWorkload],
                      target: Union[str, TraceWorkload],
                      cell: Optional[KernelWorkload] = None,
                      families: Optional[Iterable[str]] = None,
                      seed: int = 0, **kw: Any
                      ) -> Tuple[ServingEnv, ServingEnv]:
    """(source, target) serving environments differing ONLY in workload —
    the paper's workload-fluctuation environment change.  Identical
    configuration space; independent measurement-noise streams."""
    src = ServingEnv(source, cell, families, seed=seed + 1, **kw)
    tgt = ServingEnv(target, cell, src.families, seed=seed + 2, **kw)
    return src, tgt


def make_fleet_pair(workload: Union[str, TraceWorkload] = "poisson",
                    shift: Union[str, Sequence[EnvShift]] = "straggler",
                    cell: Optional[KernelWorkload] = None,
                    families: Optional[Iterable[str]] = None,
                    seed: int = 0, num_devices: int = 8, **kw: Any
                    ) -> Tuple[ServingEnv, ServingEnv]:
    """(source, target) FLEET environments differing ONLY in the fleet
    disruption: same workload trace realization, same devices — the target
    additionally suffers ``shift`` (a shift kind name like ``"straggler"``/
    ``"resize"`` or explicit :class:`EnvShift` list).  The paper's transfer
    question at fleet scale: does the router/replica configuration learned
    on the healthy fleet carry to the degraded one?"""
    trace_seed = kw.pop("trace_seed", seed)
    src = ServingEnv(workload, cell, families, seed=seed + 1, fleet=True,
                     num_devices=num_devices, trace_seed=trace_seed, **kw)
    tgt = ServingEnv(workload, cell, src.families, seed=seed + 2, fleet=True,
                     shifts=shift, num_devices=num_devices,
                     trace_seed=trace_seed, **kw)
    return src, tgt
