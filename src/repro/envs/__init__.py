from repro.envs.base import PerfEnv, PooledEnv  # noqa: F401
from repro.envs.sandbox import SandboxSCMEnv, make_sandbox_pair  # noqa: F401
from repro.envs.analytic import AnalyticTPUEnv, tpu_config_space  # noqa: F401
from repro.envs.kernel_launch import (  # noqa: F401
    KernelLaunchEnv, KernelWorkload)
from repro.envs.measure import (  # noqa: F401
    AnalyticBackend, FakeClock, LaunchGeometry, MeasurementBackend,
    TimingResult, WallClockBackend, make_backend, timeit)
