from repro.envs.base import PerfEnv, PooledEnv  # noqa: F401
from repro.envs.sandbox import SandboxSCMEnv, make_sandbox_pair  # noqa: F401
from repro.envs.analytic import AnalyticTPUEnv, tpu_config_space  # noqa: F401
from repro.envs.kernel_launch import (  # noqa: F401
    KernelLaunchEnv, KernelWorkload)
from repro.envs.measure import (  # noqa: F401
    SHIFT_KINDS, AnalyticBackend, EnvShift, FakeClock, HardwareSpec,
    LaunchGeometry, MeasurementBackend, ShiftedAnalyticBackend, TimingResult,
    WallClockBackend, backend_names, make_backend, register_backend,
    shift_kinds, shifts_for, timeit)


# ServingEnv / ReplayServingEnv sit above the workloads subsystem, which
# itself measures through repro.envs.measure — importing them eagerly here
# would close an import cycle (workloads.sim -> repro.envs -> serving_env ->
# workloads.sim), so the re-exports are lazy (PEP 562).
_SERVING_EXPORTS = {
    "ServingEnv": "serving_env",
    "make_serving_pair": "serving_env",
    "make_fleet_pair": "serving_env",
    "fleet_spec_for": "serving_env",
    "ReplayServingEnv": "replay_env",
    "make_sim2real_pair": "replay_env",
}


def __getattr__(name):
    module = _SERVING_EXPORTS.get(name)
    if module is not None:
        import importlib

        return getattr(importlib.import_module(f"repro.envs.{module}"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
