from repro.envs.base import PerfEnv, PooledEnv  # noqa: F401
from repro.envs.sandbox import SandboxSCMEnv, make_sandbox_pair  # noqa: F401
from repro.envs.analytic import AnalyticTPUEnv, tpu_config_space  # noqa: F401
from repro.envs.kernel_launch import (  # noqa: F401
    KernelLaunchEnv, KernelWorkload)
from repro.envs.measure import (  # noqa: F401
    SHIFT_KINDS, AnalyticBackend, EnvShift, FakeClock, HardwareSpec,
    LaunchGeometry, MeasurementBackend, ShiftedAnalyticBackend, TimingResult,
    WallClockBackend, make_backend, shift_kinds, shifts_for, timeit)
