import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, with zero real allocation (ShapeDtypeStruct inputs).

The two lines above MUST run before any other import (jax locks the device
count on first init) — which is why this flag lives here and nowhere else;
smoke tests and benches see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all               # 40-cell sweep
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod   # 2x16x16

Per cell this records to artifacts/dryrun/:
    memory_analysis (proves the cell fits 16 GB/chip),
    cost_analysis (XLA's numbers, unscaled),
    hlo_analysis (our while-scaled per-chip FLOPs / bytes / collective bytes),
    the collective schedule head.
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs.registry import (
    all_cells, arch_shapes, default_parallel, input_specs, list_archs,
    make_run)
from repro.launch.build import lower_step
from repro.launch.hlo_analysis import analyze_hlo, collective_schedule
from repro.launch.mesh import make_mesh
from repro.utils.config import ParallelConfig

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def parallel_overrides(par: ParallelConfig, kv: Optional[str]) -> ParallelConfig:
    if not kv:
        return par
    out = {}
    for item in kv.split(","):
        k, v = item.split("=", 1)
        cur = getattr(par, k)
        if isinstance(cur, bool):
            out[k] = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            out[k] = int(v)
        else:
            out[k] = v
    return par.replace(**out)


def dryrun_cell(arch: str, shape: str, *, multi_pod: bool = False,
                par_kv: Optional[str] = None, save: bool = True,
                tag: str = "", mesh_kv: Optional[str] = None) -> Dict:
    t0 = time.time()
    run = make_run(arch, shape, multi_pod=multi_pod)
    run = run.replace(parallel=parallel_overrides(run.parallel, par_kv))
    if mesh_kv:
        # logical re-mesh of the same chips, e.g. "64x4" -> data=64, model=4
        from repro.utils.config import MeshConfig
        dims = tuple(int(x) for x in mesh_kv.split("x"))
        axes = (("pod", "data", "model") if len(dims) == 3
                else ("data", "model"))
        run = run.replace(mesh=MeshConfig(shape=dims, axes=axes))
    run.validate()
    mesh = make_mesh(run.mesh)
    chips = run.mesh.num_devices

    bundle, lowered = lower_step(run, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    costs = analyze_hlo(hlo_text)
    sched = collective_schedule(hlo_text, limit=24)

    rec = {
        "arch": arch,
        "shape": shape,
        "kind": bundle.kind,
        "mesh": {"shape": list(run.mesh.shape), "axes": list(run.mesh.axes)},
        "chips": chips,
        "parallel": run.parallel.to_dict(),
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "xla_cost_analysis": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "hlo_analysis": {
            "flops_per_chip": costs.flops,
            "bytes_per_chip": costs.bytes_accessed,
            "collective_bytes_per_chip": costs.collective_bytes,
            "collective_count": costs.collective_count,
            "total_collective_bytes_per_chip": costs.total_collective_bytes,
        },
        "collective_schedule_head": sched,
    }
    print(f"[dryrun] {arch} x {shape} ({'2x16x16' if multi_pod else '16x16'}"
          f"{' ' + tag if tag else ''}): OK  "
          f"flops/chip={costs.flops:.3e}  bytes/chip={costs.bytes_accessed:.3e}  "
          f"coll/chip={costs.total_collective_bytes:.3e}  "
          f"args+temp={(mem.argument_size_in_bytes + mem.temp_size_in_bytes)/2**30:.2f}GiB "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        name = f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}"
        if tag:
            name += f"__{tag}"
        with open(os.path.join(ARTIFACT_DIR, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="sweep all cells")
    ap.add_argument("--parallel", help="comma list of ParallelConfig overrides, "
                                       "e.g. tp=8,remat=dots,microbatch=2")
    ap.add_argument("--mesh", help="logical re-mesh of the same chips, "
                                   "e.g. 64x4 (data x model)")
    ap.add_argument("--tag", default="", help="artifact suffix for perf iters")
    args = ap.parse_args()

    failures = []
    if args.all:
        for arch in list_archs():
            for shape in arch_shapes(arch):
                try:
                    dryrun_cell(arch, shape, multi_pod=args.multi_pod,
                                par_kv=args.parallel, tag=args.tag)
                except (ValueError, KeyError, TypeError,
                        RuntimeError) as e:
                    # RuntimeError covers jax's XlaRuntimeError (compile /
                    # lowering failures); the rest are config-cell bugs.
                    # Recorded on the report and surfaced via exit code —
                    # anything else (KeyboardInterrupt, MemoryError)
                    # propagates and kills the sweep.
                    failures.append((arch, shape, repr(e)))
                    print(f"[dryrun] {arch} x {shape}: FAIL {e}")
                    traceback.print_exc()
        print(f"[dryrun] sweep done, {len(failures)} failures")
        for f in failures:
            print("  FAIL:", f)
        return 1 if failures else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    dryrun_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                par_kv=args.parallel, tag=args.tag, mesh_kv=args.mesh)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
