"""Mesh construction + sharding assembly for the production meshes.

``make_production_mesh`` is a FUNCTION (never a module constant) so importing
this module never touches jax device state — required because only
``dryrun.py`` runs under the 512-device XLA flag.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.specs import (
    batch_specs, cache_specs, param_specs, serve_state_specs,
    train_state_specs)
from repro.utils.config import MeshConfig, RunConfig


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig) -> Mesh:
    n = cfg.num_devices
    avail = jax.devices()
    if len(avail) < n:
        raise RuntimeError(
            f"mesh {cfg.shape} needs {n} devices, have {len(avail)} "
            "(dryrun.py sets --xla_force_host_platform_device_count=512)")
    return jax.make_mesh(cfg.shape, cfg.axes, devices=avail[:n])


def _as_named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def state_shardings(state_template, run: RunConfig, mesh: Mesh):
    """NamedShardings for a TrainState template (params/opt/step/error_buf)."""
    return _as_named(
        train_state_specs(state_template, run.model, run.parallel, mesh), mesh)


def serve_shardings(state_template, run: RunConfig, mesh: Mesh):
    return _as_named(
        serve_state_specs(state_template, run.model, run.parallel, mesh), mesh)


def params_shardings(params_template, run: RunConfig, mesh: Mesh):
    return _as_named(
        param_specs(params_template, run.model, run.parallel, mesh), mesh)


def batch_shardings(batch_template, mesh: Mesh):
    return _as_named(batch_specs(batch_template, mesh), mesh)
