"""Roofline analysis over dry-run artifacts.

Per (arch x shape) cell on the single-pod mesh (per the task spec):

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s        (197 TFLOP/s bf16)
    memory term     = HLO_bytes_per_chip / HBM_bw             (819 GB/s)
    collective term = collective_bytes_per_chip / link_bw     (50 GB/s ICI)

All inputs come from the post-SPMD module, so per-chip values divide by
per-chip peaks (identical to global values over chips x peak).  MODEL_FLOPS
uses the standard conventions:

    train   6 * N * D      (N = params, active params for MoE; D = tokens)
    prefill 2 * N * D
    decode  2 * N * B      (one token per sequence)

and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs_per_chip * chips)
exposes remat / redundancy / routing waste.
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs.registry import get_model_config
from repro.utils.hardware import HARDWARE, HardwareSpec, TPU_V5E

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    kind: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    step_time_s: float          # max of the three terms (no-overlap bound)
    roofline_frac: float        # dominant-term share: compute_s / step bound
    note: str = ""

    def as_dict(self) -> Dict:
        return dict(self.__dict__)


def model_flops_for(arch: str, shape_kind: str, global_batch: int,
                    seq_len: int) -> float:
    cfg = get_model_config(arch)
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * global_batch * seq_len
    if shape_kind == "prefill":
        return 2.0 * n * global_batch * seq_len
    return 2.0 * n * global_batch  # decode: one token per sequence


_SHAPE_DIMS = {
    "train_4k": (256, 4096), "prefill_32k": (32, 32768),
    "decode_32k": (128, 32768), "long_500k": (1, 524288),
}


def roofline_from_record(rec: Dict, hw: HardwareSpec = TPU_V5E) -> RooflineRow:
    h = rec["hlo_analysis"]
    chips = rec["chips"]
    compute_s = h["flops_per_chip"] / hw.peak_flops_bf16
    memory_s = h["bytes_per_chip"] / hw.hbm_bandwidth
    collective_s = h["total_collective_bytes_per_chip"] / hw.ici_bandwidth
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    gb, sl = _SHAPE_DIMS[rec["shape"]]
    mf = model_flops_for(rec["arch"], rec["kind"], gb, sl)
    hlo_global = h["flops_per_chip"] * chips
    step = max(terms.values())
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], kind=rec["kind"], chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else 0.0,
        step_time_s=step,
        roofline_frac=(mf / hw.peak_flops_bf16 / chips) / step if step else 0.0,
    )


def load_records(pattern: str = "*__pod.json") -> List[Dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(ARTIFACT_DIR, pattern))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'chips':5s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'MFU-bound':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:26s} {r.shape:12s} {r.chips:5d} "
            f"{r.compute_s:10.3e} {r.memory_s:10.3e} {r.collective_s:10.3e} "
            f"{r.dominant:>10s} {r.useful_ratio:7.2f} {r.roofline_frac:9.3f}")
    return "\n".join(lines)


def main():
    recs = load_records()
    if not recs:
        print("no dry-run artifacts found; run repro.launch.dryrun --all first")
        return 1
    rows = [roofline_from_record(r) for r in recs]
    rows.sort(key=lambda r: (r.arch, r.shape))
    print(format_table(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
