"""Production serving launcher: batched prefill + decode for an assigned
architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batch 4 --prompt-len 64 --gen 32

``--tune-launch N`` closes the CAMEO loop before serving: a transfer-tuning
run (analytic source, ``--measure-backend`` target) over the kernel-launch
space picks block sizes / chunk lengths for this serving shape, and the
winning configuration is baked into the jitted prefill/decode steps.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config, get_model_config, list_archs
from repro.data.pipeline import make_data
from repro.launch.tune import measure_backend_arg, tune_launch_config
from repro.models.model import build_model
from repro.train.serve_step import jitted_steps, sample_token
from repro.utils.config import MeshConfig, RunConfig, ShapeConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--tune-launch", type=int, default=0, metavar="BUDGET",
                    help="intervention budget for a kernel-launch tuning run "
                         "before serving (0 = serve with registry defaults)")
    ap.add_argument("--measure-backend", type=measure_backend_arg,
                    default=None,
                    help="target measurement backend for --tune-launch: "
                         "analytic, wallclock, or shifted:<kind> "
                         "(default: REPRO_MEASURE_BACKEND, then analytic)")
    args = ap.parse_args()

    cfg = (get_model_config(args.arch) if args.full_config
           else get_smoke_config(args.arch))
    cache_len = args.prompt_len + args.gen
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve_cli", cache_len, args.batch,
                                      "decode"),
                    mesh=MeshConfig(shape=(1,), axes=("data",)))
    model = build_model(cfg, run.parallel)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch={args.batch}")

    data = make_data(cfg, run.shape, seed=0)
    raw = data.batch_at(0)
    batch = {"tokens": jnp.asarray(raw["inputs"][:args.batch,
                                                 :args.prompt_len])}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(raw["vision_embeds"][:args.batch])
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(raw["frames"][:args.batch])

    launch_config = None
    if args.tune_launch > 0:
        launch_config = tune_launch_config(cfg, args.batch, cache_len,
                                           args.tune_launch,
                                           args.measure_backend)
    prefill, decode = jitted_steps(model, run, cache_len=cache_len,
                                   launch_config=launch_config)

    t0 = time.perf_counter()
    state, logits = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{(time.perf_counter()-t0)*1000:.1f} ms")

    tok = sample_token(logits, jax.random.PRNGKey(1), args.temperature)
    lats = []
    outs = [tok]
    for i in range(args.gen - 1):
        t1 = time.perf_counter()
        state, logits = decode(params, state, tok[:, None])
        jax.block_until_ready(logits)
        lats.append(time.perf_counter() - t1)
        tok = sample_token(logits, jax.random.PRNGKey(2 + i),
                           args.temperature)
        outs.append(tok)
    lat = np.asarray(lats[1:]) * 1000
    print(f"[serve] decode p50={np.percentile(lat, 50):.2f} ms "
          f"p99={np.percentile(lat, 99):.2f} ms "
          f"({args.batch/np.mean(lat)*1000:.0f} tok/s)")
    print("[serve] sample:", np.asarray(jnp.stack(outs, 1))[0][:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
