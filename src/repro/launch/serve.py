"""Production serving launcher: batched prefill + decode for an assigned
architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batch 4 --prompt-len 64 --gen 32

``--tune-launch N`` closes the CAMEO loop before serving: a transfer-tuning
run (analytic source, ``--measure-backend`` target) over the kernel-launch
space picks block sizes / chunk lengths for this serving shape, and the
winning configuration is baked into the jitted prefill/decode steps.

``--workload <spec>`` switches to trace-driven continuous batching: a
seeded request trace (``repro.workloads`` grammar, e.g.
``bursty:rate=2000``) is replayed through the real ``ContinuousBatcher``.
With ``--tune-serving N`` the full serving stack — scheduler knobs AND
kernel launch geometry — is transfer-tuned against that trace in the
workload simulator first, and the winning plan + launch config drive the
batcher:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --workload "bursty:rate=2000,horizon=0.03" --tune-serving 10

``--sim2real-eval`` additionally prices the deployed plan in the simulator
and prints sim-predicted vs replayed-actual — the single-deployment view of
the gap ``benchmarks/sim2real_bench.py`` sweeps.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config, get_model_config, list_archs
from repro.data.pipeline import make_data
from repro.launch.tune import (measure_backend_arg, tune_launch_config,
                               tune_serving_config)
from repro.models.model import build_model
from repro.obs import trace as obs_trace
from repro.train.serve_step import jitted_steps, sample_token
from repro.utils.config import MeshConfig, RunConfig, ShapeConfig


def serve_workload(model, run, params, workload_spec: str, *,
                   tune_budget: int = 0, seed: int = 0,
                   ticks_per_s=None, method: str = "cameo",
                   query_batch: int = 1, sim2real_eval: bool = False):
    """Trace-driven serving: generate the trace, optionally transfer-tune
    the serving stack against it in the simulator, then replay it through
    the real ``ContinuousBatcher`` under the tuned plan.  Returns
    ``(plan, launch_config, replay_report)`` so callers (and tests) can
    audit exactly what was deployed.  ``sim2real_eval`` additionally prices
    the deployed configuration in the simulator and prints sim-predicted vs
    replayed-actual — the per-deployment view of the sim-to-real gap the
    ``sim2real`` benchmark sweeps."""
    from repro.envs.serving_env import ServingEnv
    from repro.launch.tune import predicted_serving_report
    from repro.serving.replay import replay_trace
    from repro.serving.scheduler import ContinuousBatcher
    from repro.workloads import ServingPlan, make_workload

    workload = make_workload(workload_spec)
    trace = workload.generate(seed)
    print(f"[serve] workload {workload.spec}: {len(trace)} requests, "
          f"max context {trace.max_context}, "
          f"~{trace.mean_rate():.0f} req/s modeled")

    launch_config = None
    best_config = None
    plan = ServingPlan()
    if tune_budget > 0:
        result = tune_serving_config(model.cfg, workload_spec, tune_budget,
                                     method=method, query_batch=query_batch,
                                     seed=seed)
        best_config = result.best_config or {}
        plan = ServingPlan.from_config(best_config)
        launch_config = result.launch_config
    batcher = ContinuousBatcher(model, run, params,
                                num_slots=plan.num_slots,
                                cache_len=plan.cache_len,
                                interleave=plan.interleave,
                                launch_config=launch_config)
    report = replay_trace(batcher, trace, admit_chunk=plan.admit_chunk,
                          ticks_per_s=ticks_per_s, seed=seed)
    print(f"[serve] replay: {report.completed} completed "
          f"({report.rejected} rejected), {report.ticks} ticks, "
          f"{report.tokens} tokens in {report.wall_s:.2f}s wall, "
          f"occupancy {report.mean_occupancy:.2f}, "
          f"latency p50={report.p50_latency_ms:.1f} ms "
          f"p99={report.p99_latency_ms:.1f} ms")
    if sim2real_eval:
        from repro.serving.scheduler import DrainStall

        try:
            pred = predicted_serving_report(model.cfg, trace, best_config)
        except DrainStall as e:
            # the replay above already drained — a simulator that cannot is
            # itself a sim-to-real finding, not a crash
            print(f"[serve] sim2real: simulator stalled pricing the "
                  f"deployed plan ({e}) while the replay drained — a "
                  f"fidelity gap worth investigating")
            return plan, launch_config, report
        if not pred.feasible:
            print(f"[serve] sim2real: simulator calls the deployed plan "
                  f"infeasible ({pred.reason}) — the replay measured it "
                  f"anyway, a fidelity gap worth investigating")
        else:
            print(f"[serve] sim2real: sim-predicted p99="
                  f"{pred.p99_latency_us:.0f} us modeled, occupancy "
                  f"{pred.occupancy_mean:.2f}, queue depth "
                  f"{pred.queue_depth_mean:.2f} | replayed-actual p99="
                  f"{report.p99_latency_ms:.1f} ms wall, occupancy "
                  f"{report.mean_occupancy:.2f}, queue depth "
                  f"{report.queue_depth_mean:.2f}")
    return plan, launch_config, report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--tune-launch", type=int, default=0, metavar="BUDGET",
                    help="intervention budget for a kernel-launch tuning run "
                         "before serving (0 = serve with registry defaults)")
    ap.add_argument("--measure-backend", type=measure_backend_arg,
                    default=None,
                    help="target measurement backend for --tune-launch: "
                         "analytic, wallclock, or shifted:<kind> "
                         "(default: REPRO_MEASURE_BACKEND, then analytic)")
    ap.add_argument("--workload", default=None, metavar="SPEC",
                    help="request-trace spec (repro.workloads grammar, e.g. "
                         "'bursty:rate=2000'): replay it through the real "
                         "continuous batcher instead of a fixed batch")
    ap.add_argument("--tune-serving", type=int, default=0, metavar="BUDGET",
                    help="with --workload: intervention budget for a "
                         "serving-stack tuning run in the workload simulator "
                         "(0 = serve with the default plan)")
    ap.add_argument("--query-batch", type=int, default=1, metavar="K",
                    help="measurements per ask/tell tuning round for "
                         "--tune-launch / --tune-serving (1 = sequential)")
    ap.add_argument("--sim2real-eval", action="store_true",
                    help="with --workload: after the replay, price the "
                         "deployed configuration in the simulator too and "
                         "report sim-predicted vs replayed-actual")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Chrome trace-event JSON of the run "
                         "(request lifecycle, tuner rounds, kernel dispatch) "
                         "— inspect with `python -m repro.obs.report PATH` "
                         "or chrome://tracing / Perfetto")
    args = ap.parse_args()

    if args.trace_out:
        with obs_trace.trace_to(args.trace_out):
            rc = _run(args)
        print(f"[serve] trace written to {args.trace_out}")
        return rc
    return _run(args)


def _run(args) -> int:

    cfg = (get_model_config(args.arch) if args.full_config
           else get_smoke_config(args.arch))
    cache_len = args.prompt_len + args.gen
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("serve_cli", cache_len, args.batch,
                                      "decode"),
                    mesh=MeshConfig(shape=(1,), axes=("data",)))
    model = build_model(cfg, run.parallel)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch={args.batch}")

    if args.workload:
        serve_workload(model, run, params, args.workload,
                       tune_budget=args.tune_serving,
                       query_batch=args.query_batch,
                       sim2real_eval=args.sim2real_eval)
        return 0

    data = make_data(cfg, run.shape, seed=0)
    raw = data.batch_at(0)
    batch = {"tokens": jnp.asarray(raw["inputs"][:args.batch,
                                                 :args.prompt_len])}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(raw["vision_embeds"][:args.batch])
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(raw["frames"][:args.batch])

    launch_config = None
    if args.tune_launch > 0:
        launch_config = tune_launch_config(cfg, args.batch, cache_len,
                                           args.tune_launch,
                                           args.measure_backend,
                                           query_batch=args.query_batch)
    prefill, decode = jitted_steps(model, run, cache_len=cache_len,
                                   launch_config=launch_config)

    # repro: ignore[wall-clock] -- serve-CLI latency printout; not part of the seeded tuning path
    t0 = time.perf_counter()
    state, logits = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          # repro: ignore[wall-clock] -- serve-CLI latency printout; not part of the seeded tuning path
          f"{(time.perf_counter()-t0)*1000:.1f} ms")

    tok = sample_token(logits, jax.random.PRNGKey(1), args.temperature)
    lats = []
    outs = [tok]
    for i in range(args.gen - 1):
        # repro: ignore[wall-clock] -- serve-CLI latency printout; not part of the seeded tuning path
        t1 = time.perf_counter()
        state, logits = decode(params, state, tok[:, None])
        jax.block_until_ready(logits)
        # repro: ignore[wall-clock] -- serve-CLI latency printout; not part of the seeded tuning path
        lats.append(time.perf_counter() - t1)
        tok = sample_token(logits, jax.random.PRNGKey(2 + i),
                           args.temperature)
        outs.append(tok)
    lat = np.asarray(lats[1:]) * 1000
    print(f"[serve] decode p50={np.percentile(lat, 50):.2f} ms "
          f"p99={np.percentile(lat, 99):.2f} ms "
          f"({args.batch/np.mean(lat)*1000:.0f} tok/s)")
    print("[serve] sample:", np.asarray(jnp.stack(outs, 1))[0][:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
