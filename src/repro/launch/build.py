"""Assemble the jit-able step function + shardings for one (run, mesh) cell.

Shared by dryrun.py (lower/compile only), the benchmarks, and the real
launchers.  ``build_step`` returns everything needed to call
``jax.jit(fn, in_shardings=..., out_shardings=..., donate_argnums=...)
.lower(*abstract_args)``.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.registry import input_specs
from repro.launch.mesh import (
    batch_shardings, params_shardings, serve_shardings, state_shardings)
from repro.models.model import build_model
from repro.train.optimizer import make_optimizer
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import init_train_state, make_train_step
from repro.utils.config import RunConfig


class StepBundle(NamedTuple):
    fn: Callable                     # the function to jit
    abstract_args: Tuple[Any, ...]   # ShapeDtypeStruct pytrees for .lower()
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    kind: str


def _replicated_like(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def build_step(run: RunConfig, mesh: Mesh) -> StepBundle:
    cfg = run.model
    model = build_model(cfg, run.parallel)
    specs = input_specs(run)
    kind = run.shape.kind

    if kind == "train":
        optimizer = make_optimizer(run.train)
        train_step = make_train_step(model, run, optimizer)

        def init_state():
            return init_train_state(model, run, optimizer,
                                    jax.random.PRNGKey(run.train.seed))

        state_t = jax.eval_shape(init_state)
        batch_t = specs["batch"]
        state_sh = state_shardings(state_t, run, mesh)
        batch_sh = batch_shardings(batch_t, mesh)
        out_t = jax.eval_shape(train_step, state_t, batch_t)
        out_sh = (state_sh, _replicated_like(out_t[1], mesh))
        return StepBundle(
            fn=train_step,
            abstract_args=(state_t, batch_t),
            in_shardings=(state_sh, batch_sh),
            out_shardings=out_sh,
            donate_argnums=(0,),
            kind=kind,
        )

    params_t = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(run.train.seed)))
    params_sh = params_shardings(params_t, run, mesh)

    if kind == "prefill":
        prefill = make_prefill_step(model, run)
        batch_t = specs["batch"]
        batch_sh = batch_shardings(batch_t, mesh)
        out_t = jax.eval_shape(prefill, params_t, batch_t)
        state_sh = serve_shardings(out_t[0], run, mesh)
        logits_sh = _logits_sharding(out_t[1], mesh)
        return StepBundle(
            fn=prefill,
            abstract_args=(params_t, batch_t),
            in_shardings=(params_sh, batch_sh),
            out_shardings=(state_sh, logits_sh),
            donate_argnums=(),
            kind=kind,
        )

    assert kind == "decode"
    decode = make_decode_step(model, run)
    state_t, tokens_t = specs["state"], specs["tokens"]
    state_sh = serve_shardings(state_t, run, mesh)
    tokens_sh = batch_shardings(tokens_t, mesh)
    out_t = jax.eval_shape(decode, params_t, state_t, tokens_t)
    logits_sh = _logits_sharding(out_t[1], mesh)
    return StepBundle(
        fn=decode,
        abstract_args=(params_t, state_t, tokens_t),
        in_shardings=(params_sh, state_sh, tokens_sh),
        out_shardings=(state_sh, logits_sh),
        donate_argnums=(1,),  # decode state is consumed each step
        kind=kind,
    )


def _logits_sharding(logits_t, mesh: Mesh):
    from repro.sharding.specs import data_axes_of
    import numpy as np

    daxes = data_axes_of(tuple(mesh.axis_names))
    dsize = int(np.prod([dict(mesh.shape)[a] for a in daxes])) if daxes else 1
    msize = dict(mesh.shape).get("model", 1)
    spec = [None] * len(logits_t.shape)
    if daxes and logits_t.shape[0] % dsize == 0:
        spec[0] = daxes
    if msize > 1 and logits_t.shape[-1] % msize == 0:
        spec[-1] = "model"
    return NamedSharding(mesh, P(*spec))


def lower_step(run: RunConfig, mesh: Mesh):
    """jit + lower (no compile). Returns (bundle, lowered).

    ``compat.set_mesh`` (``jax.set_mesh`` where it exists, the mesh's own
    context manager on 0.4.x) so the active mesh is
    visible during tracing — activation sharding constraints
    (``sharding.specs.activation_sharding``) are no-ops otherwise and XLA
    then replicates the layer-scan AD residuals across the batch axis.
    """
    b = build_step(run, mesh)
    with compat.set_mesh(mesh):
        jitted = jax.jit(b.fn, in_shardings=b.in_shardings,
                         out_shardings=b.out_shardings,
                         donate_argnums=b.donate_argnums)
        lowered = jitted.lower(*b.abstract_args)
    return b, lowered
