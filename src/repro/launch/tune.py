"""Shared launch-tuning plumbing for the serve/train launchers.

Both entry points close the CAMEO loop the same way before running: build
the :class:`KernelWorkload` cell matching the assignment, transfer-tune the
kernel-launch space (analytic source, ``--measure-backend`` target), and
bake the winning configuration into the jitted steps.  This module is the
single implementation both import, so the tuned surface (family gating via
``launch_families_for``) and the backend selection semantics cannot drift
between launchers.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from repro.envs.measure import resolve_backend_name


def launch_workload_for(cfg, batch: int, seq_len: int, *,
                        kind: str = "serve"):
    """A KernelWorkload cell matching this assignment — attention dims from
    the config, and for ssm/hybrid models the mamba surface too (d_inner
    channels, recurrent state, mamba-2 head geometry), so the tuned
    chunk/block optimum is for the kernels this model actually runs."""
    from repro.envs.kernel_launch import KernelWorkload

    kw = KernelWorkload()
    d_inner = cfg.ssm_expand * cfg.d_model
    is_ssm = cfg.family in ("ssm", "hybrid")
    return KernelWorkload(
        name=f"{kind}-{cfg.name}", batch=batch, seq_len=seq_len,
        heads=cfg.num_heads or kw.heads,
        kv_heads=cfg.num_kv_heads or cfg.num_heads or kw.kv_heads,
        head_dim=getattr(cfg, "head_dim", 0) or kw.head_dim,
        d_model=cfg.d_model,
        channels=d_inner if is_ssm else kw.channels,
        scan_state=(cfg.ssm_state or kw.scan_state) if is_ssm else kw.scan_state,
        ssm_heads=cfg.ssm_num_heads or kw.ssm_heads,
        ssm_head_dim=(d_inner // cfg.ssm_num_heads if cfg.ssm_num_heads
                      else kw.ssm_head_dim),
        ssm_state=(cfg.ssm_state or kw.ssm_state) if is_ssm else kw.ssm_state)


def tune_launch_config(cfg, batch: int, seq_len: int, budget: int,
                       backend: Optional[str], *, kind: str = "serve",
                       query_batch: int = 1, seed: int = 0
                       ) -> Dict[str, Any]:
    """One transfer-tuning run over this assignment's kernel-launch space;
    returns the winning ``family.param`` config for the step factories."""
    from repro.tuner.runner import tune_kernel_launch
    from repro.tuner.space import launch_families_for

    result = tune_kernel_launch(
        launch_workload_for(cfg, batch, seq_len, kind=kind),
        families=launch_families_for(cfg), budget=budget,
        target_backend=backend, query_batch=query_batch, seed=seed)
    print(f"[{kind}] tuned launch config ({result.method}, "
          f"budget={budget}, y={result.best_y:.1f} us): "
          f"{result.launch_config}")
    return result.launch_config


def tune_serving_config(cfg, workload: str, budget: int, *,
                        source_workload: Optional[str] = None,
                        n_source: int = 48, n_target_init: int = 3,
                        method: str = "cameo", query_batch: int = 1,
                        seed: int = 0):
    """Transfer-tune the full serving stack (scheduler knobs + kernel launch
    geometry) for one workload trace: cheap ``source_workload`` trace
    (default: the benchmark's canonical calm-Poisson source) as the
    observational source, the requested ``workload`` as the target.  Returns
    the :class:`TuneResult`; deploy with ``ServingEnv.plan_of(best_config)``
    + ``TuneResult.launch_config``."""
    from repro.envs.serving_env import make_serving_pair
    from repro.tuner.bench import DEFAULT_SOURCE_TRACE
    from repro.tuner.runner import transfer_tune
    from repro.tuner.space import launch_families_for

    source_workload = source_workload or DEFAULT_SOURCE_TRACE

    cell = launch_workload_for(cfg, batch=1, seq_len=512, kind="serve")
    src, tgt = make_serving_pair(source_workload, workload, cell,
                                 families=launch_families_for(cfg),
                                 seed=seed)
    result = transfer_tune(method, src, tgt, budget=budget,
                           n_source=n_source, n_target_init=n_target_init,
                           query_batch=query_batch,
                           query_text=tgt.query_text, seed=seed)
    print(f"[serve] tuned serving config ({result.method}, budget={budget}, "
          f"p99={result.best_y:.0f} us modeled): {result.best_config}")
    return result


def predicted_serving_report(cfg, trace, config: Optional[Dict[str, Any]]):
    """Price a serving configuration on ``trace`` in the deterministic
    simulator — the sim-predicted half of ``--sim2real-eval`` (the replayed
    half comes from ``serving/replay.py``).  Uses the same cell derivation
    and family gating as serving tuning, so the prediction is for the model
    the batcher actually deploys."""
    from repro.envs import measure as measure_mod
    from repro.tuner.space import launch_families_for
    from repro.workloads import ServingPlan, ServingSimulator

    config = config or {}
    cell = launch_workload_for(cfg, batch=1, seq_len=512, kind="serve")
    modeled = measure_mod.modeled_families()
    families = [f for f in launch_families_for(cfg) if f in modeled]
    sim = ServingSimulator(cell, families)
    return sim.run(trace, ServingPlan.from_config(config), config)


def measure_backend_arg(name: str) -> str:
    """argparse ``type=`` validator for ``--measure-backend``: any name
    ``resolve_backend_name`` accepts (analytic, wallclock, shifted:<kind>)."""
    try:
        return resolve_backend_name(name)
    except ValueError as e:
        import argparse

        raise argparse.ArgumentTypeError(str(e))
