"""Compiled-HLO analysis: FLOPs, HBM traffic, and collective bytes, with
while-loop (scan) trip-count scaling.

Why not ``compiled.cost_analysis()`` alone: on the CPU backend XLA does not
scale ``while`` bodies by trip count, so a 61-layer scanned model reports one
layer's FLOPs.  This module parses the post-SPMD HLO text itself:

- per computation: dot FLOPs (2 * prod(result) * prod(contracting)), bytes
  accessed (operands + outputs of top-level ops, fusions counted at their
  boundary — the same traffic model XLA's cost analysis uses), and collective
  operand bytes by op kind;
- a call graph walk multiplies ``while`` bodies by their trip count
  (recovered from the loop-condition comparison constant) and adds called
  computations (call / conditional branches counted once).

All shapes in the post-SPMD module are per-device shard shapes, so every
number this module emits is *per chip*; the roofline divides by per-chip
peaks directly (equivalently: global values over chips x peak).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_ENTRY_RE = re.compile(r"^ENTRY\s+%?([\w\.\-]+)", re.MULTILINE)
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(type_str: str) -> int:
    """'bf16[2,4096,512]' -> bytes. tuple types handled by caller."""
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _all_shapes_bytes(text: str) -> int:
    """Sum of every shape literal in `text` (used for operand lists)."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _itemsize_of(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    return _DTYPE_BYTES.get(m.group(1), 4) if m else 4


@dataclass
class CompStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)
    # (callee_name, multiplier, cond_name) edges: while bodies carry their
    # condition computation so each loop resolves its own trip count
    calls: List[Tuple[str, float, Optional[str]]] = field(default_factory=list)
    # raw text lines (condition computations need constant extraction)
    const_ints: List[int] = field(default_factory=list)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    depth = 0
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                depth = 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(line)
    return comps


_DOT_CONTRACT_RE = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_CALLEE_RE = {
    "while_body": re.compile(r"body=%?([\w\.\-]+)"),
    "while_cond": re.compile(r"condition=%?([\w\.\-]+)"),
    "call": re.compile(r"(?:to_apply|called_computations=\{)%?([\w\.\-]+)"),
    "cond_branches": re.compile(r"branch_computations=\{([^}]*)\}"),
    "fusion": re.compile(r"calls=%?([\w\.\-]+)"),
}
_CONST_RE = re.compile(r"constant\((\d+)\)")


_OPCODE_RE = re.compile(r"(?:^|[\)\]\}])\s*([a-z][a-z0-9\-]*)\(")
_NAME_REF_RE = re.compile(r"%([\w\.\-]+)")
_OPNAME_META_RE = re.compile(r'op_name="([^"]*)"')

# ops whose op_name metadata carries this scope are the interior of one
# Pallas kernel (see repro.kernels.ops.KERNEL_SCOPE): their FLOPs count but
# their intermediates live in VMEM — only scope-boundary reads/writes hit HBM
KERNEL_SCOPE_MARK = "repro_kernel"


def _arg_list(rest: str, start: int) -> str:
    """The parenthesized argument list starting at/after `start`."""
    lp = rest.find("(", start)
    if lp < 0:
        return ""
    depth = 0
    for i in range(lp, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                return rest[lp + 1:i]
    return rest[lp + 1:]


def _analyze_computation(name: str, lines: List[str]) -> CompStats:
    st = CompStats()
    # pass 1: symbol table op-name -> result type string (scheduled HLO
    # prints operands without types, so operand sizes resolve via this table)
    parsed = []
    types: Dict[str, str] = {}
    in_scope: Dict[str, bool] = {}
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        opname, rest = m.groups()
        om = _OPCODE_RE.search(rest)
        opcode = om.group(1) if om else ""
        type_str = rest[:om.start() + 1] if om else rest
        # The CPU backend upcasts bf16 to f32 around dots and elementwise
        # chains; TPUs execute bf16 natively. Upcast converts are free on
        # TPU (fused) and the widened value would never exist — alias the
        # converted name to its source type so downstream reads charge the
        # narrow dtype.
        if opcode == "convert":
            srcs = _NAME_REF_RE.findall(_arg_list(rest, om.end() - 1))
            if srcs and srcs[0] in types:
                src_t = types[srcs[0]]
                if 0 < _itemsize_of(src_t) < _itemsize_of(type_str):
                    type_str = src_t
        types[opname] = type_str
        meta = _OPNAME_META_RE.search(rest)
        in_scope[opname] = bool(meta and KERNEL_SCOPE_MARK in meta.group(1))
        parsed.append((opname, opcode, rest, om.end() - 1 if om else 0,
                       meta is not None))

    # XLA-synthesized ops (wide/sunk clones, layout copies) carry no
    # metadata; inherit the computation's majority scope so a fusion inside
    # an attention-backward region isn't charged as if it hit HBM.
    # Parameters/constants never inherit: they are boundary values by
    # definition (reads of them must be charged).
    _boundary_ops = ("parameter", "constant", "iota", "get-tuple-element",
                     "tuple")
    with_meta = [(n, in_scope[n]) for (n, _, _, _, has) in parsed if has]
    if with_meta:
        frac = sum(1 for _, s in with_meta if s) / len(with_meta)
        if frac > 0.5:
            for (n, oc, _, _, has) in parsed:
                if not has and oc not in _boundary_ops:
                    in_scope[n] = True
    parsed = [(n, oc, r, ap) for (n, oc, r, ap, _) in parsed]

    # scope-boundary writes: in-scope values read by out-of-scope ops
    read_by_outside = set()
    is_root = set()
    for opname, opcode, rest, argpos in parsed:
        if not in_scope.get(opname):
            for ref in _NAME_REF_RE.findall(_arg_list(rest, argpos)):
                read_by_outside.add(ref)
    for line in lines:
        lm = re.match(r"\s*ROOT\s+%?([\w\.\-]+)", line)
        if lm:
            is_root.add(lm.group(1))

    for opname, opcode, rest, argpos in parsed:
        result_bytes = _all_shapes_bytes(types[opname])
        rm = _SHAPE_RE.search(types[opname])
        result_elems = _shape_elems(rm.group(0)) if rm else 0

        for const in _CONST_RE.finditer(rest):
            st.const_ints.append(int(const.group(1)))

        if opcode in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id",
                      "iota", "convert"):
            continue

        args = _arg_list(rest, argpos)
        operand_names = _NAME_REF_RE.findall(args)
        operand_types = [types.get(n, "") for n in operand_names]
        operand_bytes = sum(_all_shapes_bytes(t) for t in operand_types)

        is_collective = None
        for c in COLLECTIVE_OPS:
            if opcode.startswith(c):
                is_collective = c
                break
        if is_collective:
            if opcode.endswith("-done"):
                continue  # bytes counted at the -start op
            st.collective_bytes[is_collective] = (
                st.collective_bytes.get(is_collective, 0.0) + operand_bytes)
            st.collective_count[is_collective] = (
                st.collective_count.get(is_collective, 0) + 1)
            st.bytes_accessed += operand_bytes + result_bytes
            continue

        if opcode == "while":
            bm = _CALLEE_RE["while_body"].search(rest)
            cm = _CALLEE_RE["while_cond"].search(rest)
            tm = _TRIP_RE.search(rest)  # XLA annotates known trip counts
            if bm:
                if tm:
                    st.calls.append((bm.group(1), float(tm.group(1)), None))
                else:
                    st.calls.append((bm.group(1), -1.0,
                                     cm.group(1) if cm else None))
            continue
        if opcode == "conditional":
            bm = _CALLEE_RE["cond_branches"].search(rest)
            if bm:
                for callee in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                    st.calls.append((callee, 1.0, None))
            continue
        scoped = in_scope.get(opname, False)
        if scoped:
            # interior of a Pallas kernel: charge only boundary traffic —
            # reads of out-of-scope values (bounded by output size: kLoop
            # semantics) and the result if it escapes the scope.
            boundary = 0.0
            for n_, t_ in zip(operand_names, operand_types):
                if not in_scope.get(n_, False):
                    boundary += min(_all_shapes_bytes(t_),
                                    max(result_elems, 1) * _itemsize_of(t_))
            if opname in read_by_outside or opname in is_root:
                boundary += result_bytes
            st.bytes_accessed += boundary

        if opcode in ("call", "custom-call", "map", "reduce", "sort",
                      "reduce-window", "scatter", "select-and-scatter",
                      "fusion"):
            fm = _CALLEE_RE["fusion"].search(rest) or _CALLEE_RE["call"].search(rest)
            if opcode == "call" and fm:
                st.calls.append((fm.group(1), 1.0, None))
            # kLoop fusions compute each output element from O(1) reads per
            # operand, so an operand's traffic is bounded by the output size
            # (this is what makes scan-over-layers charge one layer slice per
            # iteration, not the whole stacked weight). kInput (reduce)
            # fusions legitimately read more than they write -> full operands.
            if not scoped:
                if opcode == "fusion" and "kind=kLoop" in rest:
                    used = sum(
                        min(_all_shapes_bytes(t),
                            result_elems * max(_itemsize_of(t), 1))
                        for t in operand_types)
                    st.bytes_accessed += used + result_bytes
                else:
                    st.bytes_accessed += operand_bytes + result_bytes
            st.flops += float(result_elems)
            continue

        if opcode == "dynamic-slice":
            # reads only the slice it emits (+ scalar indices)
            if not scoped:
                st.bytes_accessed += 2.0 * result_bytes
            st.flops += float(result_elems)
            continue
        if opcode == "dynamic-update-slice":
            # in-place: read + write the update slice only
            if not scoped:
                upd = (_all_shapes_bytes(operand_types[1])
                       if len(operand_types) > 1 else result_bytes)
                st.bytes_accessed += 2.0 * upd
            continue

        if not scoped:
            if opcode == "dot" and operand_types:
                # MXU accumulates in f32 on-chip; the HBM write is at the
                # input precision (CPU's widened f32 output is an artifact)
                out_item = min(_itemsize_of(t) for t in operand_types)
                st.bytes_accessed += (operand_bytes
                                      + result_elems * out_item)
            else:
                st.bytes_accessed += operand_bytes + result_bytes

        if opcode == "dot":
            cm = _DOT_CONTRACT_RE.search(rest)
            contract_elems = 1
            if cm and operand_types:
                dims_idx = [int(x) for x in cm.group(1).split(",") if x]
                rhs_t = operand_types[1] if len(operand_types) > 1 else operand_types[0]
                mm = _SHAPE_RE.search(rhs_t)
                if mm and mm.group(2):
                    rdims = [int(x) for x in mm.group(2).split(",")]
                    for di in dims_idx:
                        if di < len(rdims):
                            contract_elems *= rdims[di]
            st.flops += 2.0 * result_elems * contract_elems
        else:
            # elementwise / copy / reduce: 1 flop per output element
            st.flops += float(result_elems)
    return st


def _trip_count(cond_stats: CompStats) -> float:
    """Loop condition compares the counter to a constant: take the max
    constant in the condition computation (scan lengths, microbatch counts)."""
    if not cond_stats.const_ints:
        return 1.0
    return float(max(cond_stats.const_ints))


@dataclass
class HloCosts:
    flops: float
    bytes_accessed: float
    collective_bytes: Dict[str, float]
    collective_count: Dict[str, int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo_text: str, entry: Optional[str] = None) -> HloCosts:
    comps = _split_computations(hlo_text)
    stats = {name: _analyze_computation(name, lines)
             for name, lines in comps.items()}

    if entry is None:
        em = _ENTRY_RE.search(hlo_text)
        if em:
            entry = em.group(1)
        else:
            # fallback: a computation never referenced as a callee
            called = set(re.findall(
                r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)", hlo_text))
            entry = next((n for n in comps if n not in called), list(comps)[0])

    memo: Dict[str, Tuple[float, float, Dict[str, float], Dict[str, int]]] = {}

    def total(name: str, seen=()):
        if name in memo:
            return memo[name]
        if name not in stats or name in seen:
            return 0.0, 0.0, {}, {}
        st = stats[name]
        fl, by = st.flops, st.bytes_accessed
        cb = dict(st.collective_bytes)
        cc = dict(st.collective_count)
        for callee, mult, cond in st.calls:
            if mult < 0:  # while body: trip count from its own condition
                trips = _trip_count(stats.get(cond, CompStats())) if cond else 1.0
            else:
                trips = mult
            cfl, cby, ccb, ccc = total(callee, seen + (name,))
            fl += trips * cfl
            by += trips * cby
            for k, v in ccb.items():
                cb[k] = cb.get(k, 0.0) + trips * v
            for k, v in ccc.items():
                cc[k] = cc.get(k, 0) + int(trips * v)
        memo[name] = (fl, by, cb, cc)
        return memo[name]

    fl, by, cb, cc = total(entry)
    return HloCosts(flops=fl, bytes_accessed=by, collective_bytes=cb,
                    collective_count=cc)


def collective_schedule(hlo_text: str, limit: int = 40) -> List[str]:
    """Human-readable list of collectives in program order (entry + bodies)."""
    out = []
    for line in hlo_text.splitlines():
        for c in COLLECTIVE_OPS:
            if re.search(rf"\b{c}(-start|-done)?\(", line):
                frag = line.strip()
                out.append(frag[:160])
                break
        if len(out) >= limit:
            break
    return out
