"""Production training launcher.

Selects an assigned architecture (``--arch``), builds the mesh from the
available devices, assembles the sharded train step, and runs the
fault-tolerant driver with checkpointing.  On this CPU container it runs the
smoke-scale config end-to-end; on a real TPU slice the same entry point runs
the full config (the mesh adapts to ``jax.device_count()``).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --steps 50

``--tune-launch N`` closes the CAMEO loop before training (mirroring
serve): a transfer-tuning run (analytic source, ``--measure-backend``
target) over the kernel-launch space picks block sizes / chunk lengths for
this training shape, and the winning configuration is baked into the jitted
train step.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import compat
from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config, get_model_config, list_archs
from repro.data.pipeline import make_data
from repro.launch.mesh import make_mesh, state_shardings, batch_shardings
from repro.launch.tune import measure_backend_arg, tune_launch_config
from repro.models.model import build_model
from repro.runtime.driver import TrainDriver
from repro.runtime.elastic import adjust_run_for_devices
from repro.train.optimizer import make_optimizer
from repro.train.train_step import init_train_state, make_train_step
from repro.utils.config import (MeshConfig, ParallelConfig, RunConfig,
                                ShapeConfig, TrainConfig)
from repro.utils.logging import MetricsLogger


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not smoke) architecture config; "
                         "requires a real accelerator slice")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--tune-launch", type=int, default=0, metavar="BUDGET",
                    help="intervention budget for a kernel-launch tuning run "
                         "before training (0 = train with registry defaults)")
    ap.add_argument("--measure-backend", type=measure_backend_arg,
                    default=None,
                    help="target measurement backend for --tune-launch: "
                         "analytic, wallclock, or shifted:<kind> "
                         "(default: REPRO_MEASURE_BACKEND, then analytic)")
    ap.add_argument("--query-batch", type=int, default=1, metavar="K",
                    help="measurements per ask/tell tuning round for "
                         "--tune-launch (1 = sequential)")
    args = ap.parse_args()

    cfg = (get_model_config(args.arch) if args.full_config
           else get_smoke_config(args.arch))
    ndev = jax.device_count()
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("train_cli", args.seq, args.batch, "train"),
        mesh=MeshConfig(shape=(ndev,), axes=("data",)),
        parallel=ParallelConfig(),
        train=TrainConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps),
        checkpoint_dir=args.ckpt_dir, checkpoint_every=25, log_every=5,
    )
    run = adjust_run_for_devices(run, ndev) if ndev > 1 else run
    run.validate()
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params on "
          f"{ndev} device(s)")

    model = build_model(cfg, run.parallel)
    optimizer = make_optimizer(run.train)
    mesh = make_mesh(run.mesh)

    launch_config = None
    if args.tune_launch > 0:
        launch_config = tune_launch_config(cfg, args.batch, args.seq,
                                           args.tune_launch,
                                           args.measure_backend, kind="train",
                                           query_batch=args.query_batch)

    def init_state():
        return init_train_state(model, run, optimizer,
                                jax.random.PRNGKey(run.train.seed))

    with compat.set_mesh(mesh), \
            MetricsLogger(name=f"train-{args.arch}") as logger:
        state_t = jax.eval_shape(init_state)
        step_fn = jax.jit(
            make_train_step(model, run, optimizer,
                            launch_config=launch_config),
            in_shardings=(state_shardings(state_t, run, mesh), None),
            donate_argnums=(0,))
        driver = TrainDriver(
            run, step_fn, init_state, make_data(cfg, run.shape, seed=0),
            CheckpointManager(run.checkpoint_dir, keep=run.keep_checkpoints),
            logger=logger)
        state = driver.run_steps(args.steps)
    print(f"[train] finished at step {int(state.step)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
