"""Pallas TPU flash attention (forward) + single-token decode attention.

TPU adaptation notes
--------------------
- Online-softmax accumulation lives in VMEM scratch; the kv grid dimension is
  sequential ("arbitrary") so the scratch carries across kv blocks, exactly
  the HBM->VMEM streaming structure flash attention wants on TPU.
- Block sizes (``q_block`` x ``kv_block``) are first-class tuning knobs
  (CAMEO tunes them); defaults are MXU-aligned multiples of 128.
- Causal / sliding-window block-level skipping is done with ``pl.when`` so
  fully-masked blocks do no FLOPs (the grid point still issues, which is the
  TPU idiom — grids are static).
- GQA is handled in the index maps: the kv head index is ``q_head // group``,
  so no K/V replication ever materializes in HBM or VMEM.

Layouts: q (B, Sq, Hq, D); k/v (B, Skv, Hkv, D); out (B, Sq, Hq, Dv).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

NEG_INF = -1e30
_LANE = 128  # TPU lane width: scratch second-minor stats padded to this


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - rem)
    return jnp.pad(x, pad)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, sliding_window: int,
                 logit_softcap: float, q_offset: int, kv_valid: int,
                 q_block: int, kv_block: int, n_kv: int):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = q_offset + iq * q_block
    kv_start = ikv * kv_block

    # Block-level visibility: skip blocks that are entirely masked.
    visible = kv_start < kv_valid
    if causal:
        visible &= kv_start <= q_start + q_block - 1
    if sliding_window > 0:
        visible &= kv_start + kv_block - 1 > q_start - sliding_window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (Qb, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (Kb, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)              # (Kb, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (Qb, Kb)
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = k_pos < kv_valid
        if causal:
            mask &= k_pos <= q_pos
        if sliding_window > 0:
            mask &= k_pos > q_pos - sliding_window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                    # (Qb,)
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)  # exact zero for masked (handles -inf rows)
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    sliding_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, dv = v.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5

    q_block = max(8, min(q_block, sq))
    kv_block = max(8, min(kv_block, skv))
    qp = _pad_to(q, 1, q_block)
    kp = _pad_to(k, 1, kv_block)
    vp = _pad_to(v, 1, kv_block)
    n_q = qp.shape[1] // q_block
    n_kv = kp.shape[1] // kv_block

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal,
        sliding_window=sliding_window, logit_softcap=logit_softcap,
        q_offset=q_offset, kv_valid=skv, q_block=q_block, kv_block=kv_block,
        n_kv=n_kv)

    grid = (b, hq, n_q, n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, 1, d), lambda ib, ih, iq, ikv: (ib, iq, ih, 0)),
            pl.BlockSpec((1, kv_block, 1, d), lambda ib, ih, iq, ikv: (ib, ikv, ih // g, 0)),
            pl.BlockSpec((1, kv_block, 1, dv), lambda ib, ih, iq, ikv: (ib, ikv, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, dv), lambda ib, ih, iq, ikv: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, qp.shape[1], hq, dv), q.dtype),
        scratch_shapes=[
            compat.vmem((q_block, dv), jnp.float32),
            compat.vmem((q_block, _LANE), jnp.float32),
            compat.vmem((q_block, _LANE), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]


# --------------------------------------------------------------------------
# decode attention (single new token over a KV cache)
# --------------------------------------------------------------------------

def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, scale: float, sliding_window: int, logit_softcap: float,
                   g: int, kv_block: int, n_kv: int):
    ib = pl.program_id(0)
    ikv = pl.program_id(2)
    cache_len = len_ref[ib]

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_start = ikv * kv_block
    visible = kv_start < cache_len
    if sliding_window > 0:
        visible &= kv_start + kv_block - 1 >= cache_len - sliding_window

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale       # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)               # (Kb, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)               # (Kb, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, Kb)
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], kv_block), 1)
        mask = k_pos < cache_len
        if sliding_window > 0:
            mask &= k_pos >= cache_len - sliding_window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,        # (B, 1, Hq, D)
    k_cache: jax.Array,  # (B, Skv, Hkv, D)
    v_cache: jax.Array,  # (B, Skv, Hkv, Dv)
    cache_len: jax.Array,  # (B,) int32 valid entries (incl. the new token)
    *,
    sliding_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    kv_block: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, dv = v_cache.shape
    assert sq == 1
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kv_block = max(8, min(kv_block, skv))
    kp = _pad_to(k_cache, 1, kv_block)
    vp = _pad_to(v_cache, 1, kv_block)
    n_kv = kp.shape[1] // kv_block

    kernel = functools.partial(
        _decode_kernel, scale=scale, sliding_window=sliding_window,
        logit_softcap=logit_softcap, g=g, kv_block=kv_block, n_kv=n_kv)

    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(b, hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ikv, len_ref: (ib, 0, ih, 0)),
            pl.BlockSpec((1, kv_block, 1, d), lambda ib, ih, ikv, len_ref: (ib, ikv, ih, 0)),
            pl.BlockSpec((1, kv_block, 1, dv), lambda ib, ih, ikv, len_ref: (ib, ikv, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda ib, ih, ikv, len_ref: (ib, 0, ih, 0)),
        scratch_shapes=[
            compat.vmem((g, dv), jnp.float32),
            compat.vmem((g, _LANE), jnp.float32),
            compat.vmem((g, _LANE), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, hq, dv), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), q, kp, vp)
    return out
