"""Pure-jnp oracle for flash attention (GQA / causal / sliding window / softcap).

This is the semantic reference the Pallas kernel must match, and also the
implementation used when lowering for XLA cost analysis (the dry-run path),
since it produces honest HLO FLOPs for the attention contraction.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    sliding_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,  # (B,) valid kv length for decode
) -> jax.Array:
    """Grouped-query attention oracle. Returns (B, Sq, Hq, Dv)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, dv = v.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # (B, Hkv, G, Sq, Skv)
    qg = qf.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)

    q_pos = q_offset + jnp.arange(sq)[:, None]  # (Sq, 1)
    k_pos = jnp.arange(skv)[None, :]  # (1, Skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window > 0:
        mask &= k_pos > q_pos - sliding_window
    mask_b = jnp.broadcast_to(mask, (b, 1, 1, sq, skv))
    if kv_len is not None:
        valid = k_pos < kv_len[:, None]  # (B, Skv)
        mask_b = mask_b & valid[:, None, None, None, :]
    logits = jnp.where(mask_b, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def attention_blockwise_ref(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    sliding_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    q_offset: int = 0,
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax attention, scanned over kv blocks.

    Mathematically identical to ``attention_ref`` (f32 accumulation), but the
    lowered HLO mirrors the Pallas kernel's streaming structure: the (Sq x
    kv_block) score block is a loop-local temporary instead of a full (Sq x
    Skv) HBM materialization.  This is the implementation the dry-run lowers,
    so the roofline's memory term reflects the TPU kernel, not a CPU oracle.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, dv = v.shape
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kv_block = max(8, min(kv_block, skv))
    pad = (-skv) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = k.shape[1] // kv_block

    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, d)
    ks = k.astype(jnp.float32).reshape(b, n_blocks, kv_block, hkv, d
                                       ).transpose(1, 0, 2, 3, 4)
    vs = v.astype(jnp.float32).reshape(b, n_blocks, kv_block, hkv, dv
                                       ).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc, blk = carry
        kb, vb = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb)
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        k_pos = blk * kv_block + jnp.arange(kv_block)
        mask = (k_pos[None, :] < skv)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if sliding_window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - sliding_window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
        return (m_new, l_new, acc_new, blk + 1), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    # checkpoint: differentiating through the scan then saves only the
    # (m, l, acc) carries per block and recomputes the (Sq x kv_block)
    # score block in the backward — the flash-attention backward contract
    body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, jnp.int32(0)), (ks, vs))
    denom = jnp.where(l == 0.0, 1.0, l)
    out = (acc / denom[..., None]).transpose(0, 3, 1, 2, 4)  # (b, sq, hkv, g, dv)
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,      # (B, 1, Hq, D)
    k_cache: jax.Array,  # (B, Skv, Hkv, D)
    v_cache: jax.Array,  # (B, Skv, Hkv, Dv)
    cache_len: jax.Array,  # (B,) int32 — number of valid entries incl. new one
    *,
    sliding_window: int = 0,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode attention over a (possibly ring) KV cache."""
    b, sq, hq, d = q.shape
    _, skv, hkv, dv = v_cache.shape
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    qg = qf.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(jnp.float32))
    if logit_softcap > 0.0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    k_pos = jnp.arange(skv)[None, :]
    valid = k_pos < cache_len[:, None]
    if sliding_window > 0:
        valid &= k_pos >= (cache_len[:, None] - sliding_window)
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, sq, hq, dv).astype(q.dtype)
