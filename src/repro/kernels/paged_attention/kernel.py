"""Pallas TPU paged decode attention (single new token over a paged KV pool).

Structure mirrors the dense decode kernel
(:mod:`repro.kernels.flash_attention.kernel`): grid ``(B, Hkv, n_pages)``
with the page dimension sequential so the online-softmax scratch carries
across a slot's pages.  The difference is *where* each kv block comes from:
the block index map reads the slot's page table (scalar-prefetched, so it is
available at index-map time) and streams pool page ``page_table[ib, ip]``
into VMEM instead of a contiguous cache slice.  This is the vLLM-style
paged-attention dataflow: K/V never materialize contiguously per slot.

Both the page table and the per-slot valid lengths ride in scalar prefetch
(``num_scalar_prefetch=2``); unused table entries must hold valid pool
indices (their rows are masked by ``cache_len``).

Layouts: q (B, 1, Hq, D); pools (P, page_size, Hkv, D); out (B, 1, Hq, Dv).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat

NEG_INF = -1e30
_LANE = 128


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         logit_softcap: float, page_size: int, n_pages: int):
    ib = pl.program_id(0)
    ip = pl.program_id(2)
    cache_len = len_ref[ib]

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_start = ip * page_size

    @pl.when(kv_start < cache_len)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale        # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)                # (ps, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, ps)
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        k_pos = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page_size), 1)
        mask = k_pos < cache_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_cur[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur[:, None], l_ref.shape)

    @pl.when(ip == n_pages - 1)
    def _finalize():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array,           # (B, 1, Hq, D)
    k_pages: jax.Array,     # (P, page_size, Hkv, D)
    v_pages: jax.Array,     # (P, page_size, Hkv, Dv)
    page_table: jax.Array,  # (B, n_pages) int32 pool indices
    cache_len: jax.Array,   # (B,) int32 valid tokens (incl. the new one)
    *,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, page_size, hkv, dv = v_pages.shape
    assert sq == 1
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    n_pages = page_table.shape[1]
    if scale is None:
        scale = d ** -0.5

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, logit_softcap=logit_softcap,
        page_size=page_size, n_pages=n_pages)

    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ib, ih, ip, tbl, lens: (ib, 0, ih, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda ib, ih, ip, tbl, lens: (tbl[ib, ip], 0, ih, 0)),
            pl.BlockSpec((1, page_size, 1, dv),
                         lambda ib, ih, ip, tbl, lens: (tbl[ib, ip], 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda ib, ih, ip, tbl, lens: (ib, 0, ih, 0)),
        scratch_shapes=[
            compat.vmem((g, dv), jnp.float32),
            compat.vmem((g, _LANE), jnp.float32),
            compat.vmem((g, _LANE), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, hq, dv), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), cache_len.astype(jnp.int32),
      q, k_pages, v_pages)
    return out
