"""Pure-jnp oracle for paged decode attention.

The paged layout stores K/V in a shared block pool of ``(pool_pages,
page_size)`` rows; each batch slot owns a page table of pool indices.  Token
``t`` of slot ``b`` lives in pool page ``page_table[b, t // page_size]`` at
row ``t % page_size``.

The oracle gathers the slot's pages back into a contiguous per-slot cache and
runs the exact dense decode-attention math
(:func:`repro.kernels.flash_attention.ref.decode_attention_ref`).  This is
what anchors the dense-equivalence invariant: with a single full-size page
per slot whose table is the identity, the gathered array IS the dense cache
(same shape, same rows), so the computation is bit-identical to the dense
path — not merely numerically close.

Unused page-table entries must still hold valid pool indices (0 is fine);
their rows are masked out by ``cache_len`` exactly like the dense cache's
tail.  Sliding-window attention is not supported in the paged layout (the
window would straddle page boundaries the pallas kernel skips wholesale).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import decode_attention_ref


def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P, ps, Hkv, D) pool + (B, n_pages) table -> (B, n_pages*ps, Hkv, D)."""
    b, n_pages = page_table.shape
    _, ps, hkv, d = pool.shape
    gathered = pool[page_table]  # (B, n_pages, ps, Hkv, D)
    return gathered.reshape(b, n_pages * ps, hkv, d)


def paged_decode_attention_ref(
    q: jax.Array,           # (B, 1, Hq, D)
    k_pages: jax.Array,     # (P, page_size, Hkv, D) shared pool
    v_pages: jax.Array,     # (P, page_size, Hkv, Dv)
    page_table: jax.Array,  # (B, n_pages) int32 pool indices
    cache_len: jax.Array,   # (B,) int32 valid tokens (incl. the new one)
    *,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode attention over a block-paged KV pool."""
    k_cache = gather_pages(k_pages, page_table)
    v_cache = gather_pages(v_pages, page_table)
    return decode_attention_ref(
        q, k_cache, v_cache, cache_len,
        logit_softcap=logit_softcap, scale=scale)
