"""Public kernel entry points.

Each op routes through the unified dispatch registry
(:mod:`repro.kernels.dispatch`):

- on TPU backends the Pallas kernel is used;
- on CPU (this container) the oracle is used for model execution and XLA cost
  analysis, and the Pallas kernels are exercised in ``interpret=True`` mode by
  the tests;
- ``REPRO_KERNEL_MODE`` env var overrides: ``ref`` | ``pallas`` |
  ``pallas_interpret``.

Launch parameters (block sizes, chunk lengths) left as ``None`` resolve
through the registry: an active tuned configuration installed with
``dispatch.use_launch_config`` wins, then the registry defaults.  Explicit
call-site values (e.g. ``par.attn_q_block`` from the parallelism plan) are
honored unless a tuned configuration is active.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.flash_attention import ref as _attn_ref
from repro.kernels.mamba_scan import ref as _scan_ref
from repro.kernels.paged_attention import ref as _paged_ref
from repro.kernels.rmsnorm import ref as _rms_ref
from repro.kernels.ssd import ref as _ssd_ref


def kernel_mode() -> str:
    return dispatch.default_mode()


def _interpret() -> bool:
    return kernel_mode() == dispatch.PALLAS_INTERPRET


# Every ref-path op body is wrapped in this named scope.  The HLO analyzer
# treats ops carrying the scope as the interior of ONE Pallas kernel: FLOPs
# count, intermediate HBM round-trips do not (they live in VMEM on the TPU
# target) — only boundary reads/writes are charged.  This is what makes the
# dry-run roofline reflect the TPU kernels rather than the CPU oracle.
KERNEL_SCOPE = "repro_kernel"


def _scoped(name: str):
    return jax.named_scope(f"{KERNEL_SCOPE}.{name}")


def _recompute_vjp(name: str, fn):
    """custom_vjp wrapper with a flash-attention-style backward contract:
    save only the op INPUTS, recompute the forward inside the backward and
    differentiate there.  This kills jax's per-iteration residual stacking
    through the scanned ref (which would re-materialize the S^2 / (L,C,N)
    intermediates the kernels exist to avoid) — matching what the real
    Pallas backward kernels do on TPU."""

    @jax.custom_vjp
    def op(*args):
        with _scoped(name):
            return fn(*args)

    def fwd(*args):
        with _scoped(name):
            return fn(*args), args

    def bwd(args, dy):
        with _scoped(name + "_bwd"):
            _, vjp = jax.vjp(fn, *args)
            return vjp(dy)

    op.defvjp(fwd, bwd)
    return op


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _attention_op(causal, sliding_window, logit_softcap, scale, q_offset,
                  kv_block):
    def fn(q, k, v):
        # blockwise online-softmax: HLO mirrors the kernel's streaming
        return _attn_ref.attention_blockwise_ref(
            q, k, v, causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale, q_offset=q_offset,
            kv_block=kv_block)
    return _recompute_vjp("flash_attention", fn)


def flash_attention(q, k, v, *, causal=True, sliding_window=0, logit_softcap=0.0,
                    scale=None, q_offset=0, q_block=None, kv_block=None):
    res = dispatch.resolve("flash_attention", q_block=q_block,
                           kv_block=kv_block)
    if res.mode == dispatch.REF:
        return _attention_op(causal, sliding_window, logit_softcap, scale,
                             q_offset, res.launch["kv_block"])(q, k, v)
    return res.impl(
        q, k, v, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale, q_offset=q_offset,
        q_block=res.launch["q_block"], kv_block=res.launch["kv_block"],
        interpret=res.interpret)


def decode_attention(q, k_cache, v_cache, cache_len, *, sliding_window=0,
                     logit_softcap=0.0, scale=None, kv_block=None):
    res = dispatch.resolve("flash_attention", kv_block=kv_block)
    if res.mode == dispatch.REF:
        with _scoped("decode_attention"):
            return _attn_ref.decode_attention_ref(
                q, k_cache, v_cache, cache_len, sliding_window=sliding_window,
                logit_softcap=logit_softcap, scale=scale)
    fn = dispatch.pallas_fn("flash_attention", variant="decode")
    return fn(q, k_cache, v_cache, cache_len, sliding_window=sliding_window,
              logit_softcap=logit_softcap, scale=scale,
              kv_block=res.launch["kv_block"], interpret=res.interpret)


def paged_decode_attention(q, k_pages, v_pages, page_table, cache_len, *,
                           logit_softcap=0.0, scale=None):
    """Single-token decode over a block-paged KV pool.

    The family's launch options (``page_size``, ``pages_per_slot_max``,
    ``prefill_chunk``) shape the pool the caller built, not this call — the
    kernel reads its geometry off the arrays.  Resolving the family here
    still records the decision (mode + launch) for the dispatch audit.
    """
    res = dispatch.resolve("paged_attention")
    if res.mode == dispatch.REF:
        with _scoped("paged_decode_attention"):
            return _paged_ref.paged_decode_attention_ref(
                q, k_pages, v_pages, page_table, cache_len,
                logit_softcap=logit_softcap, scale=scale)
    fn = dispatch.pallas_fn("paged_attention")
    return fn(q, k_pages, v_pages, page_table, cache_len,
              logit_softcap=logit_softcap, scale=scale,
              interpret=res.interpret)


# --------------------------------------------------------------------------
# mamba-1 selective scan
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _selective_scan_op(chunk):
    def fn(x, dt, A, Bmat, Cmat, D):
        return _scan_ref.selective_scan_chunked_ref(
            x, dt, A, Bmat, Cmat, D, chunk=chunk)
    return _recompute_vjp("selective_scan", fn)


def selective_scan(x, dt, A, Bmat, Cmat, D, *, chunk=None, c_block=None,
                   return_state=False):
    res = dispatch.resolve("mamba_scan", chunk=chunk, c_block=c_block)
    if return_state:
        # the final-state variant is a serving/prefill path (no grad needed)
        with _scoped("selective_scan"):
            return _scan_ref.selective_scan_chunked_ref(
                x, dt, A, Bmat, Cmat, D, chunk=res.launch["chunk"],
                return_state=True)
    if res.mode == dispatch.REF:
        return _selective_scan_op(res.launch["chunk"])(x, dt, A, Bmat, Cmat, D)
    return res.impl(x, dt, A, Bmat, Cmat, D, chunk=res.launch["chunk"],
                    c_block=res.launch["c_block"], interpret=res.interpret)


def selective_scan_step(h, x_t, dt_t, A, B_t, C_t, D):
    with _scoped("selective_scan_step"):
        return _scan_ref.selective_scan_step_ref(h, x_t, dt_t, A, B_t, C_t, D)


# --------------------------------------------------------------------------
# mamba-2 SSD
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _ssd_op(chunk):
    def fn(x, dt, A, Bmat, Cmat, D):
        return _ssd_ref.ssd_ref(x, dt, A, Bmat, Cmat, D, chunk=chunk)
    return _recompute_vjp("ssd", fn)


def ssd(x, dt, A, Bmat, Cmat, D, *, chunk=None, init_state=None,
        return_state=False):
    res = dispatch.resolve("ssd", chunk=chunk)
    if init_state is not None or return_state:
        with _scoped("ssd"):  # serving/prefill path, no grad
            return _ssd_ref.ssd_ref(x, dt, A, Bmat, Cmat, D,
                                    chunk=res.launch["chunk"],
                                    init_state=init_state,
                                    return_state=return_state)
    if res.mode == dispatch.REF:
        return _ssd_op(res.launch["chunk"])(x, dt, A, Bmat, Cmat, D)
    return res.impl(x, dt, A, Bmat, Cmat, D, chunk=res.launch["chunk"],
                    interpret=res.interpret)


def ssd_step(state, x_t, dt_t, A, B_t, C_t, D):
    with _scoped("ssd_step"):
        return _ssd_ref.ssd_step_ref(state, x_t, dt_t, A, B_t, C_t, D)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

def rmsnorm(x, weight, *, eps=1e-5, residual=None, row_block=None):
    res = dispatch.resolve("rmsnorm", row_block=row_block)
    if res.mode == dispatch.REF:
        with _scoped("rmsnorm"):
            return _rms_ref.rmsnorm_ref(x, weight, eps=eps, residual=residual)
    return res.impl(x, weight, eps=eps, residual=residual,
                    row_block=res.launch["row_block"], interpret=res.interpret)
