"""Public kernel entry points.

Each op dispatches between the Pallas TPU kernel and the pure-jnp oracle:

- on TPU backends the Pallas kernel is used;
- on CPU (this container) the oracle is used for model execution and XLA cost
  analysis, and the Pallas kernels are exercised in ``interpret=True`` mode by
  the tests;
- ``REPRO_KERNEL_MODE`` env var overrides: ``ref`` | ``pallas`` |
  ``pallas_interpret``.
"""

from __future__ import annotations

import functools
import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref as _attn_ref
from repro.kernels.mamba_scan import ref as _scan_ref
from repro.kernels.rmsnorm import ref as _rms_ref
from repro.kernels.ssd import ref as _ssd_ref


def kernel_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_MODE", "")
    if mode:
        return mode
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return kernel_mode() == "pallas_interpret"


# Every ref-path op body is wrapped in this named scope.  The HLO analyzer
# treats ops carrying the scope as the interior of ONE Pallas kernel: FLOPs
# count, intermediate HBM round-trips do not (they live in VMEM on the TPU
# target) — only boundary reads/writes are charged.  This is what makes the
# dry-run roofline reflect the TPU kernels rather than the CPU oracle.
KERNEL_SCOPE = "repro_kernel"


def _scoped(name: str):
    return jax.named_scope(f"{KERNEL_SCOPE}.{name}")


def _recompute_vjp(name: str, fn):
    """custom_vjp wrapper with a flash-attention-style backward contract:
    save only the op INPUTS, recompute the forward inside the backward and
    differentiate there.  This kills jax's per-iteration residual stacking
    through the scanned ref (which would re-materialize the S^2 / (L,C,N)
    intermediates the kernels exist to avoid) — matching what the real
    Pallas backward kernels do on TPU."""

    @jax.custom_vjp
    def op(*args):
        with _scoped(name):
            return fn(*args)

    def fwd(*args):
        with _scoped(name):
            return fn(*args), args

    def bwd(args, dy):
        with _scoped(name + "_bwd"):
            _, vjp = jax.vjp(fn, *args)
            return vjp(dy)

    op.defvjp(fwd, bwd)
    return op


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _attention_op(causal, sliding_window, logit_softcap, scale, q_offset,
                  kv_block):
    def fn(q, k, v):
        # blockwise online-softmax: HLO mirrors the kernel's streaming
        return _attn_ref.attention_blockwise_ref(
            q, k, v, causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale, q_offset=q_offset,
            kv_block=kv_block)
    return _recompute_vjp("flash_attention", fn)


def flash_attention(q, k, v, *, causal=True, sliding_window=0, logit_softcap=0.0,
                    scale=None, q_offset=0, q_block=512, kv_block=1024):
    if kernel_mode() == "ref":
        return _attention_op(causal, sliding_window, logit_softcap, scale,
                             q_offset, kv_block)(q, k, v)
    from repro.kernels.flash_attention.kernel import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale, q_offset=q_offset,
        q_block=q_block, kv_block=kv_block, interpret=_interpret())


def decode_attention(q, k_cache, v_cache, cache_len, *, sliding_window=0,
                     logit_softcap=0.0, scale=None, kv_block=1024):
    if kernel_mode() == "ref":
        with _scoped("decode_attention"):
            return _attn_ref.decode_attention_ref(
                q, k_cache, v_cache, cache_len, sliding_window=sliding_window,
                logit_softcap=logit_softcap, scale=scale)
    from repro.kernels.flash_attention.kernel import decode_attention_pallas

    return decode_attention_pallas(
        q, k_cache, v_cache, cache_len, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale, kv_block=kv_block,
        interpret=_interpret())


# --------------------------------------------------------------------------
# mamba-1 selective scan
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _selective_scan_op(chunk):
    def fn(x, dt, A, Bmat, Cmat, D):
        return _scan_ref.selective_scan_chunked_ref(
            x, dt, A, Bmat, Cmat, D, chunk=chunk)
    return _recompute_vjp("selective_scan", fn)


def selective_scan(x, dt, A, Bmat, Cmat, D, *, chunk=256, return_state=False):
    if return_state:
        # the final-state variant is a serving/prefill path (no grad needed)
        with _scoped("selective_scan"):
            return _scan_ref.selective_scan_chunked_ref(
                x, dt, A, Bmat, Cmat, D, chunk=chunk, return_state=True)
    if kernel_mode() == "ref":
        return _selective_scan_op(chunk)(x, dt, A, Bmat, Cmat, D)
    from repro.kernels.mamba_scan.kernel import selective_scan_pallas

    return selective_scan_pallas(x, dt, A, Bmat, Cmat, D, chunk=chunk,
                                 interpret=_interpret())


def selective_scan_step(h, x_t, dt_t, A, B_t, C_t, D):
    with _scoped("selective_scan_step"):
        return _scan_ref.selective_scan_step_ref(h, x_t, dt_t, A, B_t, C_t, D)


# --------------------------------------------------------------------------
# mamba-2 SSD
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _ssd_op(chunk):
    def fn(x, dt, A, Bmat, Cmat, D):
        return _ssd_ref.ssd_ref(x, dt, A, Bmat, Cmat, D, chunk=chunk)
    return _recompute_vjp("ssd", fn)


def ssd(x, dt, A, Bmat, Cmat, D, *, chunk=64, init_state=None, return_state=False):
    if init_state is not None or return_state:
        with _scoped("ssd"):  # serving/prefill path, no grad
            return _ssd_ref.ssd_ref(x, dt, A, Bmat, Cmat, D, chunk=chunk,
                                    init_state=init_state,
                                    return_state=return_state)
    if kernel_mode() == "ref":
        return _ssd_op(chunk)(x, dt, A, Bmat, Cmat, D)
    from repro.kernels.ssd.kernel import ssd_pallas

    return ssd_pallas(x, dt, A, Bmat, Cmat, D, chunk=chunk,
                      interpret=_interpret())


def ssd_step(state, x_t, dt_t, A, B_t, C_t, D):
    with _scoped("ssd_step"):
        return _ssd_ref.ssd_step_ref(state, x_t, dt_t, A, B_t, C_t, D)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

def rmsnorm(x, weight, *, eps=1e-5, residual=None):
    if kernel_mode() == "ref":
        with _scoped("rmsnorm"):
            return _rms_ref.rmsnorm_ref(x, weight, eps=eps, residual=residual)
    from repro.kernels.rmsnorm.kernel import rmsnorm_pallas

    return rmsnorm_pallas(x, weight, eps=eps, residual=residual,
                          interpret=_interpret())
