"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) chunked algorithm.

Semantics (per head h, scalar decay per head per step):
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T      (state: (N, P))
    y_t = C_t^T h_t + D_h * x_t

Implemented with the chunked block decomposition from the Mamba-2 paper
(intra-chunk quadratic term + inter-chunk low-rank state passing), which is
exactly what the Pallas kernel tiles on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _segsum(log_a: jax.Array) -> jax.Array:
    """Stable segment-sum: S[i, j] = sum_{k=j+1..i} log_a[k], lower-triangular.

    log_a: (..., L). Returns (..., L, L) with -inf above the diagonal.
    """
    L = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool), k=0)
    return jnp.where(mask, s, -jnp.inf)


def ssd_ref(
    x: jax.Array,    # (B, L, H, P)   head channels
    dt: jax.Array,   # (B, L, H)      positive step sizes
    A: jax.Array,    # (H,)           negative scalars
    Bmat: jax.Array, # (B, L, G, N)   G groups (G divides H)
    Cmat: jax.Array, # (B, L, G, N)
    D: jax.Array,    # (H,)
    chunk: int = 64,
    init_state: jax.Array | None = None,  # (B, H, N, P)
    return_state: bool = False,
):
    """Returns y: (B, L, H, P) (and final state if requested)."""
    b, l, h, p = x.shape
    g, n = Bmat.shape[2], Bmat.shape[3]
    rep = h // g
    orig_l = l
    if l % chunk != 0:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = x.shape[1]
    nc = l // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = Bmat.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Cf = Cmat.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bf, rep, axis=3)  # (b, nc, c, h, n)
    Ch = jnp.repeat(Cf, rep, axis=3)

    log_a = dtf * A.astype(jnp.float32)[None, None, None, :]  # (b, nc, c, h) <= 0
    xdt = xf * dtf[..., None]  # dt-weighted inputs

    # 1) intra-chunk (quadratic) term
    L_mat = jnp.exp(_segsum(log_a.transpose(0, 1, 3, 2)))  # (b, nc, h, c, c)
    scores = jnp.einsum("bzchn,bzshn->bzhcs", Ch, Bh)  # (b,nc,h,c,s)
    y_diag = jnp.einsum("bzhcs,bzhcs,bzshp->bzchp", scores, L_mat, xdt)

    # 2) chunk-final states: S_z = sum_s a(end..s) * B_s x_s^T
    a_end = jnp.exp(jnp.cumsum(log_a, axis=2)[:, :, -1:, :] - jnp.cumsum(log_a, axis=2))
    # a_end: decay from step s (exclusive) to chunk end: (b, nc, c, h)
    states = jnp.einsum("bzshn,bzsh,bzshp->bzhnp", Bh, a_end, xdt)

    # 3) inter-chunk recurrence over chunk states
    a_chunk = jnp.exp(jnp.sum(log_a, axis=2))  # (b, nc, h) total chunk decay

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_cum, states_cum = jax.lax.associative_scan(combine, (a_chunk, states), axis=1)
    if init_state is not None:
        states_cum = states_cum + a_cum[..., None, None] * init_state[:, None].astype(jnp.float32)
    # state entering chunk z is states_cum[z-1]
    prev = jnp.concatenate(
        [jnp.zeros_like(states_cum[:, :1]) if init_state is None
         else init_state[:, None].astype(jnp.float32),
         states_cum[:, :-1]], axis=1)

    # 4) inter-chunk output: y_off_t = C_t^T (a(t..chunk_start) * prev_state)
    a_start = jnp.exp(jnp.cumsum(log_a, axis=2))  # decay from chunk start to t inclusive
    y_off = jnp.einsum("bzchn,bzch,bzhnp->bzchp", Ch, a_start, prev)

    y = (y_diag + y_off).reshape(b, l, h, p)[:, :orig_l]
    y = y + x[:, :orig_l].astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, states_cum[:, -1]
    return y


def ssd_step_ref(state, x_t, dt_t, A, B_t, C_t, D):
    """Single decode step.

    state: (B, H, N, P); x_t: (B, H, P); dt_t: (B, H); B_t/C_t: (B, G, N).
    Returns (state_new, y_t: (B, H, P)).
    """
    b, hh, n, p = state.shape
    g = B_t.shape[1]
    rep = hh // g
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)  # (B, H, N)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32)[None, :])  # (B, H)
    xdt = x_t.astype(jnp.float32) * dtf[..., None]  # (B, H, P)
    new_state = state.astype(jnp.float32) * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    y = y + x_t.astype(jnp.float32) * D.astype(jnp.float32)[None, :, None]
    return new_state, y.astype(x_t.dtype)
