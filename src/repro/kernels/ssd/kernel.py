"""Pallas TPU kernel for the Mamba-2 SSD chunked algorithm.

TPU adaptation notes
--------------------
The SSD decomposition is MXU-native: per (batch, head, chunk) the work is
three small matmuls — the (chunk x chunk) intra-chunk score matrix, the
(chunk x N) @ (N x P) inter-chunk output, and the (N x chunk) @ (chunk x P)
state update.  We grid over (B, H, chunks) with the chunk dimension
sequential, carrying the (N, P) recurrent state in VMEM scratch.  The chunk
size is the tuning knob trading quadratic intra-chunk FLOPs against the
length of the sequential inter-chunk dependency.

Layouts: x (B, L, H, P); dt (B, L, H); A (H,); Bmat/Cmat (B, L, G, N);
D (H,); y (B, L, H, P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref, *,
                chunk: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xc = x_ref[0, :, 0, :].astype(jnp.float32)        # (chunk, P)
    dtc = dt_ref[0, :, 0].astype(jnp.float32)         # (chunk,)
    a = a_ref[0, 0]                                   # scalar
    Bc = b_ref[0, :, 0, :].astype(jnp.float32)        # (chunk, N)
    Cc = c_ref[0, :, 0, :].astype(jnp.float32)        # (chunk, N)
    Dh = d_ref[0, 0]                                  # scalar

    log_a = dtc * a                                   # (chunk,) <= 0
    cum = jnp.cumsum(log_a)                           # (chunk,)
    xdt = xc * dtc[:, None]                           # (chunk, P)

    # intra-chunk quadratic term: L[t,s] = exp(cum[t]-cum[s]) for s <= t
    seg = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    Lm = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (c, c)
    y = jax.lax.dot_general(scores * Lm, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (c, P)

    # inter-chunk contribution from the carried state
    a_start = jnp.exp(cum)                            # decay start->t inclusive
    y = y + jax.lax.dot_general(Cc * a_start[:, None], state_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: S <- a_chunk * S + B^T (a_end * xdt)
    a_end = jnp.exp(cum[-1] - cum)                    # (chunk,)
    state_ref[...] = (jnp.exp(cum[-1]) * state_ref[...]
                      + jax.lax.dot_general(Bc, xdt * a_end[:, None],
                                            (((0,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))

    y = y + Dh * xc
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_pallas(
    x: jax.Array,     # (B, L, H, P)
    dt: jax.Array,    # (B, L, H)
    A: jax.Array,     # (H,)
    Bmat: jax.Array,  # (B, L, G, N)
    Cmat: jax.Array,  # (B, L, G, N)
    D: jax.Array,     # (H,)
    *,
    chunk: int = 64,
    init_state=None,
    return_state: bool = False,
    interpret: bool = False,
):
    if init_state is not None or return_state:
        # continuation states are a serving-path feature; the oracle handles it
        from repro.kernels.ssd.ref import ssd_ref
        return ssd_ref(x, dt, A, Bmat, Cmat, D, chunk=chunk,
                       init_state=init_state, return_state=return_state)
    b, l, h, p = x.shape
    g, n = Bmat.shape[2], Bmat.shape[3]
    rep = h // g
    orig_l = l
    chunk = max(8, min(chunk, l))
    if l % chunk != 0:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = x.shape[1]
    n_chunks = l // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    grid = (b, h, n_chunks)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, it: (ib, it, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, it: (ib, it, ih)),
            pl.BlockSpec((1, 1), lambda ib, ih, it: (0, ih)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, it: (ib, it, ih // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, it: (ib, it, ih // rep, 0)),
            pl.BlockSpec((1, 1), lambda ib, ih, it: (0, ih)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, it: (ib, it, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
        scratch_shapes=[compat.vmem((n, p), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32)[None, :], Bmat, Cmat,
      D.astype(jnp.float32)[None, :])
    return y[:, :orig_l]
