"""Pure-jnp oracle for the Mamba-1 selective scan.

Recurrence (per batch, per channel c, state dim n):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = sum_n C_t[n] * h_t[n] + D * x_t

The oracle uses a chunked associative scan over the sequence so that it is
both numerically exact and memory-bounded, which is also the decomposition the
Pallas kernel implements on TPU (HBM->VMEM chunks, sequential across chunks).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(
    x: jax.Array,    # (B, L, C)  channels = d_inner
    dt: jax.Array,   # (B, L, C)  softplus-activated step sizes
    A: jax.Array,    # (C, N)     negative (log-parameterized outside)
    Bmat: jax.Array, # (B, L, N)
    Cmat: jax.Array, # (B, L, N)
    D: jax.Array,    # (C,)
) -> jax.Array:
    """Returns y: (B, L, C). float32 internal math."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)

    # decay a_t = exp(dt_t * A): (B, L, C, N); input u_t = dt_t * B_t * x_t
    dA = jnp.exp(jnp.einsum("blc,cn->blcn", dtf, Af))
    dBx = jnp.einsum("blc,bln->blcn", dtf * xf, Bf)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    a_scan, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    del a_scan
    y = jnp.einsum("blcn,bln->blc", h, Cf)
    y = y + xf * D.astype(jnp.float32)[None, None, :]
    return y.astype(x.dtype)


def selective_scan_chunked_ref(x, dt, A, Bmat, Cmat, D, chunk: int = 256,
                               return_state: bool = False):
    """Chunked variant: sequential over chunks, associative scan inside.

    Matches `selective_scan_ref` exactly; bounded memory O(B * chunk * C * N).
    With ``return_state`` also returns the final recurrent state (B, C, N)
    (zero-padded tail steps have dt=0 so they do not perturb the state).
    """
    b, l, c = x.shape
    n = A.shape[1]
    if l % chunk != 0:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    lp = x.shape[1]
    nchunks = lp // chunk

    xs = x.reshape(b, nchunks, chunk, c).swapaxes(0, 1)
    dts = dt.reshape(b, nchunks, chunk, c).swapaxes(0, 1)
    Bs = Bmat.reshape(b, nchunks, chunk, n).swapaxes(0, 1)
    Cs = Cmat.reshape(b, nchunks, chunk, n).swapaxes(0, 1)

    Af = A.astype(jnp.float32)

    def chunk_step(h0, inp):
        xc, dtc, Bc, Cc = inp
        xf = xc.astype(jnp.float32)
        dtf = dtc.astype(jnp.float32)
        dA = jnp.exp(jnp.einsum("blc,cn->blcn", dtf, Af))
        dBx = jnp.einsum("blc,bln->blcn", dtf * xf, Bc.astype(jnp.float32))

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_all, h_local = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        # fold in carry state: h_t = h_local_t + (prod of decays up to t) * h0
        h_full = h_local + a_all * h0[:, None]
        y = jnp.einsum("blcn,bln->blc", h_full, Cc.astype(jnp.float32))
        return h_full[:, -1], y

    h0 = jnp.zeros((b, c, n), jnp.float32)
    # checkpoint: backward saves only the (B, C, N) chunk-entry states and
    # recomputes the (chunk, C, N) decay/input tensors per chunk
    step = jax.checkpoint(chunk_step, prevent_cse=False)
    h_last, ys = jax.lax.scan(step, h0, (xs, dts, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(b, lp, c)[:, :l]
    y = y + x[:, :l].astype(jnp.float32) * D.astype(jnp.float32)[None, None, :]
    y = y.astype(x.dtype)
    if return_state:
        return y, h_last
    return y


def selective_scan_step_ref(h, x_t, dt_t, A, B_t, C_t, D):
    """Single decode step. h: (B, C, N); x_t, dt_t: (B, C); B_t, C_t: (B, N).

    Returns (h_new, y_t: (B, C)).
    """
    hf = h.astype(jnp.float32)
    dA = jnp.exp(jnp.einsum("bc,cn->bcn", dt_t.astype(jnp.float32), A.astype(jnp.float32)))
    dBx = jnp.einsum("bc,bn->bcn", dt_t.astype(jnp.float32) * x_t.astype(jnp.float32),
                     B_t.astype(jnp.float32))
    h_new = dA * hf + dBx
    y = jnp.einsum("bcn,bn->bc", h_new, C_t.astype(jnp.float32))
    y = y + x_t.astype(jnp.float32) * D.astype(jnp.float32)[None, :]
    return h_new, y.astype(x_t.dtype)
