"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation notes
--------------------
The CUDA selective-scan kernel parallelizes over channels within a thread
block and keeps state in registers.  On TPU we tile channels into VMEM blocks
(``c_block`` lanes) and keep the (c_block, N) recurrent state in VMEM scratch.
The sequence is processed in ``chunk``-sized HBM->VMEM blocks (the sequential
"arbitrary" grid dimension); inside a chunk the recurrence runs as a
``fori_loop`` over time — per step the update is a (c_block, N) VPU op plus a
(c_block, N) x (N,) contraction, which keeps the working set entirely in
VMEM/VREGs.

Layouts: x/dt (B, L, C); A (C, N); Bmat/Cmat (B, L, N); D (C,); y (B, L, C).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref, *,
                 chunk: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[0].astype(jnp.float32)        # (chunk, Cb)
    dtb = dt_ref[0].astype(jnp.float32)      # (chunk, Cb)
    A = a_ref[...].astype(jnp.float32)       # (Cb, N)
    Bb = b_ref[0].astype(jnp.float32)        # (chunk, N)
    Cb_ = c_ref[0].astype(jnp.float32)       # (chunk, N)
    Dv = d_ref[0].astype(jnp.float32)        # (Cb,)

    def step(t, h):
        dt_t = dtb[t][:, None]                       # (Cb, 1)
        dA = jnp.exp(dt_t * A)                       # (Cb, N)
        dBx = (dt_t * xb[t][:, None]) * Bb[t][None, :]
        h = dA * h + dBx
        y_t = jnp.sum(h * Cb_[t][None, :], axis=1)   # (Cb,)
        y_t = y_t + Dv * xb[t]
        y_ref[0, pl.ds(t, 1), :] = y_t[None].astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def selective_scan_pallas(
    x: jax.Array,     # (B, L, C)
    dt: jax.Array,    # (B, L, C)
    A: jax.Array,     # (C, N)
    Bmat: jax.Array,  # (B, L, N)
    Cmat: jax.Array,  # (B, L, N)
    D: jax.Array,     # (C,)
    *,
    chunk: int = 256,
    c_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, l, c = x.shape
    n = A.shape[1]
    orig_l = l
    chunk = max(8, min(chunk, l))
    if l % chunk != 0:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        l = x.shape[1]
    c_block = min(c_block, c)
    while c % c_block != 0:
        c_block //= 2
    n_cb = c // c_block
    n_chunks = l // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    grid = (b, n_cb, n_chunks)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, c_block), lambda ib, ic, it: (ib, it, ic)),
            pl.BlockSpec((1, chunk, c_block), lambda ib, ic, it: (ib, it, ic)),
            pl.BlockSpec((c_block, n), lambda ib, ic, it: (ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ic, it: (ib, it, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ic, it: (ib, it, 0)),
            pl.BlockSpec((1, c_block), lambda ib, ic, it: (0, ic)),
        ],
        out_specs=pl.BlockSpec((1, chunk, c_block), lambda ib, ic, it: (ib, it, ic)),
        out_shape=jax.ShapeDtypeStruct((b, l, c), x.dtype),
        scratch_shapes=[compat.vmem((c_block, n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bmat, Cmat, D.astype(jnp.float32)[None, :])
    return y[:, :orig_l]
