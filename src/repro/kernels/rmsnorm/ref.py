"""Pure-jnp oracle for fused RMSNorm (optionally with residual-add)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-5,
                residual: jax.Array | None = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return y.astype(x.dtype)
