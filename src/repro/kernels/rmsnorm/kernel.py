"""Pallas TPU kernel for fused RMSNorm (optionally fused residual-add).

Rows are flattened to (R, D) and tiled ``row_block`` rows at a time; each
block is one HBM->VMEM stream, normalized in fp32 on the VPU.  Fusing the
residual add removes one full activation round-trip to HBM per layer norm —
visible in the memory roofline term.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _rms_kernel(x_ref, w_ref, y_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def _rms_res_kernel(x_ref, r_ref, w_ref, y_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def rmsnorm_pallas(x: jax.Array, weight: jax.Array, *, eps: float = 1e-5,
                   residual: jax.Array | None = None, row_block: int = 256,
                   interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    r = xf.shape[0]
    row_block = max(1, min(row_block, r))
    pad = (-r) % row_block
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n_rb = xf.shape[0] // row_block

    if residual is not None:
        rf = residual.reshape(-1, d)
        if pad:
            rf = jnp.pad(rf, ((0, pad), (0, 0)))
        kernel = functools.partial(_rms_res_kernel, eps=eps)
        in_specs = [
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ]
        args = (xf, rf, weight)
    else:
        kernel = functools.partial(_rms_kernel, eps=eps)
        in_specs = [
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ]
        args = (xf, weight)

    y = pl.pallas_call(
        kernel,
        grid=(n_rb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((row_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    if pad:
        y = y[:r]
    return y.reshape(orig_shape)
