"""Unified kernel dispatch: one registry routing every kernel family to the
Pallas TPU kernel, the Pallas interpreter, or the pure-jax reference.

Why a registry
--------------
The serve/train/benchmark surfaces all need the same decision — "which
implementation of flash_attention/mamba_scan/ssd/rmsnorm runs here?" — and
the answer depends on the detected backend, an env-var override, and (for
the Pallas paths) launch parameters.  Centralizing it means:

- CPU-only hosts (this container, CI) execute everything through the
  reference or the Pallas interpreter without any call-site branching;
- kernel *launch parameters* (block sizes, chunk lengths) become first-class
  configuration options: :func:`launch_space` exposes them as a
  ``repro.core.spaces.ConfigSpace`` so CAMEO tunes them exactly like the
  paper tunes cpu_frequency or swappiness, and :func:`use_launch_config`
  installs a tuned configuration for everything dispatched underneath it.

Modes
-----
``ref`` | ``pallas`` | ``pallas_interpret``; the ``REPRO_KERNEL_MODE`` env
var overrides, otherwise TPU backends get ``pallas`` and everything else
gets ``ref``.

Precedence for launch parameters (highest first): an active tuned config
installed via :func:`use_launch_config` (the tuner speaking — it must win so
a tuned serve/train step does not silently fall back to static defaults),
then explicit call-site keyword arguments, then the registry defaults.
"""

from __future__ import annotations

import contextlib
import functools
import importlib
import inspect
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax

from repro import compat
from repro.core.spaces import ConfigSpace, Option
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

REF = "ref"
PALLAS = "pallas"
PALLAS_INTERPRET = "pallas_interpret"
MODES = (REF, PALLAS, PALLAS_INTERPRET)

KERNEL_MODE_ENV = "REPRO_KERNEL_MODE"


def detect_backend() -> str:
    """The effective jax backend: 'tpu' | 'gpu' | 'cpu'."""
    return jax.default_backend()


def default_mode(backend: Optional[str] = None) -> str:
    """Dispatch mode before per-call overrides: env var, then backend."""
    env = os.environ.get(KERNEL_MODE_ENV, "")
    if env:
        if env not in MODES:
            raise ValueError(
                f"{KERNEL_MODE_ENV}={env!r} is not one of {MODES}")
        return env
    backend = backend or detect_backend()
    if backend == "tpu" and compat.HAS_PALLAS_TPU:
        return PALLAS
    return REF


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelFamily:
    """One kernel family: implementations + its tunable launch surface.

    ``pallas``/``ref`` are lazy ``"module:attr"`` references so importing the
    registry never imports kernel modules (and therefore never requires a
    functional pallas lowering).  ``variants`` holds secondary entry points
    that share the family's launch surface (e.g. decode attention).
    """

    name: str
    pallas: str
    ref: str
    launch_options: Tuple[Option, ...] = ()
    variants: Tuple[Tuple[str, Tuple[str, str]], ...] = ()  # (name, (pallas, ref))

    def option(self, name: str) -> Option:
        for o in self.launch_options:
            if o.name == name:
                return o
        raise KeyError(f"{self.name} has no launch option {name!r}")


_REGISTRY: Dict[str, KernelFamily] = {}


def register_family(fam: KernelFamily) -> KernelFamily:
    if fam.name in _REGISTRY:
        raise ValueError(f"kernel family {fam.name!r} already registered")
    _REGISTRY[fam.name] = fam
    return fam


def get_family(name: str) -> KernelFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel family {name!r}; known: {sorted(_REGISTRY)}")


def families() -> List[str]:
    return sorted(_REGISTRY)


@functools.lru_cache(maxsize=None)
def _load(ref: str) -> Callable:
    module, attr = ref.split(":")
    return getattr(importlib.import_module(module), attr)


def _impl_ref(fam: KernelFamily, mode: str, variant: Optional[str]) -> str:
    pallas, ref = fam.pallas, fam.ref
    if variant is not None:
        pallas, ref = dict(fam.variants)[variant]
    return ref if mode == REF else pallas


def pallas_fn(family: str, variant: Optional[str] = None) -> Callable:
    return _load(_impl_ref(get_family(family), PALLAS, variant))


def ref_fn(family: str, variant: Optional[str] = None) -> Callable:
    return _load(_impl_ref(get_family(family), REF, variant))


# --------------------------------------------------------------------------
# launch configuration
# --------------------------------------------------------------------------

_local = threading.local()


def _active() -> Dict[str, Dict[str, Any]]:
    return getattr(_local, "launch", {})


def split_launch_config(config: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Normalize flat ``{"family.param": v}`` / nested dicts to nested form.

    Unknown families or parameters raise — a tuned configuration that cannot
    land on a real launch knob is a bug in the space, not noise to ignore.
    """
    nested: Dict[str, Dict[str, Any]] = {}
    for key, val in (config or {}).items():
        if isinstance(val, dict):
            fam_name, params = key, val
        elif "." in key:
            fam_name, pname = key.split(".", 1)
            params = {pname: val}
        else:
            raise KeyError(
                f"launch config key {key!r} is not 'family.param' or nested")
        fam = get_family(fam_name)
        for pname, v in params.items():
            fam.option(pname)  # existence check
            nested.setdefault(fam_name, {})[pname] = v
    return nested


class use_launch_config:
    """Install a tuned launch configuration for dispatches underneath.

    Accepts flat (``{"flash_attention.q_block": 256}``) or nested
    (``{"flash_attention": {"q_block": 256}}``) form; nests are merged over
    any outer active config.  With ``exclusive=True`` the config underneath
    is exactly this one — any outer active config is shadowed, not merged
    (the serve/train step factories use this so a compiled step is a pure
    function of its ``launch_config``, whatever happens to be installed when
    jax finally traces it).  Values are trace-time constants: wrapping the
    traced body of a jit-compiled serve/train step bakes them into that
    trace.  jax's jit cache does NOT see the active config — re-entering an
    already-compiled step under a different config is a cache hit that keeps
    the old launch geometry.  Deploying a new config to a jitted step
    requires a fresh jit (or threading the config through static args — the
    ``launch_config`` argument of the serve/train step factories does the
    former).

    The manager is re-entrant and reusable — one instance may be entered
    recursively, across sequential ``with`` blocks, or from several threads
    at once (the save-stack is per-thread, since the active config is) —
    and the prior configuration is restored on exit even when the body
    raises.  Validation against the registry happens eagerly at
    construction.
    """

    def __init__(self, config: Optional[Dict[str, Any]], *,
                 exclusive: bool = False):
        self._overrides = split_launch_config(config or {})
        self._exclusive = exclusive

    def __enter__(self) -> Dict[str, Dict[str, Any]]:
        prev = _active()
        if self._exclusive:
            merged = {f: dict(p) for f, p in self._overrides.items()}
        else:
            merged = {f: dict(p) for f, p in prev.items()}
            for f, p in self._overrides.items():
                merged.setdefault(f, {}).update(p)
        saved = getattr(_local, "saved_configs", None)
        if saved is None:
            saved = _local.saved_configs = []
        saved.append(prev)
        _local.launch = merged
        return merged

    def __exit__(self, exc_type, exc, tb) -> bool:
        # with-blocks unwind LIFO within a thread, so a plain per-thread
        # stack restores correctly however instances nest or interleave
        _local.launch = _local.saved_configs.pop()
        return False


def launch_params(family: str, **explicit: Any) -> Dict[str, Any]:
    """Resolved launch parameters: active tuned > explicit (non-None) > default."""
    fam = get_family(family)
    out = {o.name: o.default for o in fam.launch_options}
    out.update({k: v for k, v in explicit.items() if v is not None})
    out.update(_active().get(family, {}))
    unknown = set(explicit) - {o.name for o in fam.launch_options}
    if unknown:
        raise KeyError(f"{family} has no launch options {sorted(unknown)}")
    return out


@dataclass(frozen=True)
class Resolution:
    """Outcome of one dispatch decision."""
    family: str
    mode: str
    interpret: bool
    launch: Dict[str, Any] = field(default_factory=dict)

    @property
    def impl(self) -> Callable:
        return pallas_fn(self.family) if self.mode != REF else ref_fn(self.family)


@contextlib.contextmanager
def record_resolutions():
    """Observe every dispatch decision made underneath (same thread).

    Yields a list that each :func:`resolve` call appends its
    :class:`Resolution` to — including resolutions made while *tracing* a
    jit-compiled step, which is where launch parameters are baked.  This is
    the ground truth for "did the tuned config reach the kernel call":
    wiring tests and audits read the recorded ``launch`` dicts instead of
    trusting the config plumbing.

    Spies isolate: each nested or concurrent spy gets its OWN result list
    (never a shared one), and the active-spy registry is an immutable
    per-thread tuple — entering or exiting one spy rebuilds the tuple
    instead of mutating a list other spies hold, so an inner spy exiting
    (in any order, e.g. via an ``ExitStack``) can never detach or clobber
    an outer spy's recordings.  Detachment matches by identity, not
    equality: two empty result lists compare equal.
    """
    rec: List[Resolution] = []
    _local.recorders = getattr(_local, "recorders", ()) + (rec,)
    try:
        yield rec
    finally:
        active = getattr(_local, "recorders", ())
        for i in range(len(active) - 1, -1, -1):
            if active[i] is rec:
                _local.recorders = active[:i] + active[i + 1:]
                break


def _notify_recorders(res: Resolution) -> None:
    for rec in getattr(_local, "recorders", ()):
        rec.append(res)


def resolve(family: str, mode: Optional[str] = None,
            **explicit: Any) -> Resolution:
    mode = mode or default_mode()
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not one of {MODES}")
    res = Resolution(family=family, mode=mode,
                     interpret=(mode == PALLAS_INTERPRET),
                     launch=launch_params(family, **explicit))
    _notify_recorders(res)
    _notify_profiles(res)
    return res


# --------------------------------------------------------------------------
# dispatch profiling (obs hooks)
# --------------------------------------------------------------------------

class DispatchProfile:
    """Aggregated dispatch telemetry: per-(family, mode) resolution counts
    and wall time spent inside dispatched calls.

    Built on the same notification path as :func:`record_resolutions`, but
    *cross-thread*: a profile observes every resolution process-wide while
    active, because profiling is aggregate bookkeeping (how much, how long),
    not the per-thread wiring ground truth the spy provides.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.resolutions: Dict[Tuple[str, str], int] = {}
        self.wall_s: Dict[Tuple[str, str], float] = {}

    def _saw(self, res: Resolution) -> None:
        key = (res.family, res.mode)
        with self._lock:
            self.resolutions[key] = self.resolutions.get(key, 0) + 1

    def _timed(self, family: str, mode: str, dt: float) -> None:
        key = (family, mode)
        with self._lock:
            self.wall_s[key] = self.wall_s.get(key, 0.0) + dt

    def summary(self) -> Dict[str, Dict[str, Any]]:
        """``{"family [mode]": {"resolutions": n, "wall_s": s}}``."""
        out: Dict[str, Dict[str, Any]] = {}
        with self._lock:
            for (fam, mode), n in self.resolutions.items():
                out[f"{fam} [{mode}]"] = {
                    "resolutions": n,
                    "wall_s": round(self.wall_s.get((fam, mode), 0.0), 6)}
            for (fam, mode), s in self.wall_s.items():
                out.setdefault(f"{fam} [{mode}]",
                               {"resolutions": 0})["wall_s"] = round(s, 6)
        return out


_PROFILES: List[DispatchProfile] = []
_PROFILES_LOCK = threading.Lock()


def _notify_profiles(res: Resolution) -> None:
    if _PROFILES:
        with _PROFILES_LOCK:
            active = list(_PROFILES)
        for p in active:
            p._saw(res)


@contextlib.contextmanager
def profile_dispatches():
    """Profile every dispatch made while active (all threads): yields a
    :class:`DispatchProfile` accumulating per-family resolution counts and
    the wall time spent inside dispatched implementations.  When the obs
    tracer is active, each dispatched call additionally exports a span on
    the kernel track and bumps the ``dispatch_wall_s`` /
    ``dispatch_resolutions_total`` registry instruments."""
    prof = DispatchProfile()
    with _PROFILES_LOCK:
        _PROFILES.append(prof)
    try:
        yield prof
    finally:
        with _PROFILES_LOCK:
            for i in range(len(_PROFILES) - 1, -1, -1):
                if _PROFILES[i] is prof:
                    del _PROFILES[i]
                    break


def dispatch(family: str, *args: Any, mode: Optional[str] = None,
             variant: Optional[str] = None, launch: Optional[Dict] = None,
             **kwargs: Any) -> Any:
    """Generic router: run ``family`` on the resolved implementation.

    Launch parameters the chosen implementation does not accept (e.g.
    ``q_block`` on a reference that has no blocking) are dropped by
    signature inspection, so one launch config drives every mode.

    Note on timing: for jit-compiled callers, ``dispatch`` runs while jax
    *traces* the step, so the profiled wall time is trace/build time — the
    per-family compile cost a tuned launch config pays — not steady-state
    execution time (which the wall-clock measurement backend owns).
    """
    res = resolve(family, mode=mode, **(launch or {}))
    fn = _load(_impl_ref(get_family(family), res.mode, variant))
    accepted = set(inspect.signature(fn).parameters)
    kw = {k: v for k, v in res.launch.items() if k in accepted}
    kw.update(kwargs)
    if res.mode != REF and "interpret" in accepted:
        kw["interpret"] = res.interpret
    if not _PROFILES and not obs_trace.enabled():
        return fn(*args, **kw)
    t0 = time.perf_counter()
    with obs_trace.span(family, cat="dispatch", track=obs_trace.TRACK_KERNEL,
                        mode=res.mode,
                        variant=variant if variant else ""):
        out = fn(*args, **kw)
    dt = time.perf_counter() - t0
    if _PROFILES:
        with _PROFILES_LOCK:
            active = list(_PROFILES)
        for p in active:
            p._timed(family, res.mode, dt)
    if obs_trace.enabled():
        obs_metrics.REGISTRY.inc("dispatch_resolutions_total",
                                 family=family, mode=res.mode)
        obs_metrics.REGISTRY.inc("dispatch_wall_s", dt,
                                 family=family, mode=res.mode)
    return out


# --------------------------------------------------------------------------
# the tunable launch surface
# --------------------------------------------------------------------------

def launch_space(names: Optional[Iterable[str]] = None) -> ConfigSpace:
    """Every registered launch parameter as one CAMEO ``ConfigSpace``.

    Options are prefixed ``family.param`` so the space composes with the
    framework-level space (``repro.tuner.space``) without name collisions.
    """
    opts: List[Option] = []
    for fname in (sorted(names) if names is not None else families()):
        fam = get_family(fname)
        for o in fam.launch_options:
            opts.append(Option(f"{fname}.{o.name}", o.values,
                               default=o.default, kind=o.kind))
    return ConfigSpace(opts)


# --------------------------------------------------------------------------
# built-in families
# --------------------------------------------------------------------------
# Domains are MXU/VPU-aligned recommended-value lists (the analogue of the
# paper's Tables 7-12); defaults match the historical call-site defaults.

register_family(KernelFamily(
    name="flash_attention",
    pallas="repro.kernels.flash_attention.kernel:flash_attention_pallas",
    ref="repro.kernels.flash_attention.ref:attention_blockwise_ref",
    launch_options=(
        Option("q_block", (128, 256, 512, 1024), default=512),
        Option("kv_block", (256, 512, 1024, 2048), default=1024),
    ),
    variants=(
        ("decode", ("repro.kernels.flash_attention.kernel:decode_attention_pallas",
                    "repro.kernels.flash_attention.ref:decode_attention_ref")),
    ),
))

# The paged family's launch surface is consumed by the *serving stack*, not
# the kernel call: ``page_size``/``pages_per_slot_max`` shape the KV pool the
# caches are built with, ``prefill_chunk`` drives the batcher's chunked
# admission (0 = whole-prompt prefill).  Registering them here keeps the
# contract — every kernel-family knob joins ``launch_space()`` — while the
# kernel itself reads the geometry off the pool arrays it is handed.
register_family(KernelFamily(
    # repro: ignore[kernel-option-unused] -- consumed by the serving stack (pool geometry / chunked admission), not the kernel signature; see comment above
    name="paged_attention",
    pallas="repro.kernels.paged_attention.kernel:paged_decode_attention_pallas",
    ref="repro.kernels.paged_attention.ref:paged_decode_attention_ref",
    launch_options=(
        Option("page_size", (32, 64, 128, 256), default=64),
        Option("pages_per_slot_max", (4, 8, 16, 32), default=8),
        Option("prefill_chunk", (0, 64, 128, 256), default=0),
    ),
))

register_family(KernelFamily(
    name="mamba_scan",
    pallas="repro.kernels.mamba_scan.kernel:selective_scan_pallas",
    ref="repro.kernels.mamba_scan.ref:selective_scan_chunked_ref",
    launch_options=(
        Option("chunk", (64, 128, 256, 512), default=256),
        Option("c_block", (128, 256, 512, 1024), default=512),
    ),
))

register_family(KernelFamily(
    name="ssd",
    pallas="repro.kernels.ssd.kernel:ssd_pallas",
    ref="repro.kernels.ssd.ref:ssd_ref",
    launch_options=(
        Option("chunk", (32, 64, 128, 256), default=64),
    ),
))

register_family(KernelFamily(
    name="rmsnorm",
    pallas="repro.kernels.rmsnorm.kernel:rmsnorm_pallas",
    ref="repro.kernels.rmsnorm.ref:rmsnorm_ref",
    launch_options=(
        Option("row_block", (64, 128, 256, 512), default=256),
    ),
))
