"""Kernel families (flash_attention / mamba_scan / ssd / rmsnorm).

Every family ships a Pallas TPU kernel (``<family>/kernel.py``), a pure-jax
oracle (``<family>/ref.py``), and registers itself with the unified dispatch
registry (``dispatch.py``); ``ops.py`` holds the public entry points.  Add a
new family only for compute hot-spots worth a custom kernel, and register it
so its launch parameters join the tunable surface.
"""

from repro.kernels import dispatch  # noqa: F401  (registry side effects)
