"""Deterministic, shard-aware, resumable synthetic LM data pipeline.

Design constraints for thousand-node training:

- **Stateless addressing** — ``batch_at(step)`` is a pure function of
  (seed, step, shard), so resume-after-failure needs no pipeline state in the
  checkpoint beyond the step counter, and every host can independently
  produce exactly its shard of the global batch (no data redistribution
  collective at the input layer).
- **Learnable structure** — tokens follow a fixed seeded Markov chain over
  the vocabulary, so end-to-end examples show genuinely decreasing loss
  (pure-uniform tokens would train to the entropy floor immediately and hide
  optimizer bugs).
- **Modality stubs** — per the task spec, vlm/audio frontends are stubbed:
  the pipeline emits deterministic patch/frame embeddings alongside tokens.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.utils.config import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0
    # Markov-chain sharpness: higher -> more predictable tokens
    chain_concentration: float = 0.3
    branching: int = 8  # plausible next-tokens per state
    # modality stubs
    vision_seq: int = 0
    vision_dim: int = 0
    audio_seq: int = 0
    audio_dim: int = 0


class SyntheticLMData:
    """Markov-chain LM data. ``batch_at(step)`` returns this shard's slice."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_shards == 0, (
            cfg.global_batch, cfg.num_shards)
        self.cfg = cfg
        self.shard_batch = cfg.global_batch // cfg.num_shards
        # The chain itself must be identical on every shard: seed only by cfg.seed.
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xC0FFEE]))
        v, b = cfg.vocab_size, min(cfg.branching, cfg.vocab_size)
        self._succ = rng.integers(0, v, size=(v, b), dtype=np.int32)
        probs = rng.dirichlet(np.full(b, cfg.chain_concentration), size=v)
        self._cum = np.cumsum(probs, axis=1).astype(np.float32)

    def _rng_for(self, step: int) -> np.random.Generator:
        c = self.cfg
        return np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.shard_id]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = self._rng_for(step)
        b, s, v = self.shard_batch, c.seq_len, c.vocab_size
        # vectorized Markov walk: one uniform per (b, t), inverse-CDF lookup
        u = rng.random((b, s + 1), dtype=np.float32)
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        cum, succ = self._cum, self._succ
        for t in range(1, s + 1):
            prev = toks[:, t - 1]
            slot = (u[:, t, None] > cum[prev]).sum(axis=1)
            toks[:, t] = succ[prev, np.minimum(slot, succ.shape[1] - 1)]
        out = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
        if c.vision_seq:
            out["vision_embeds"] = rng.standard_normal(
                (b, c.vision_seq, c.vision_dim)).astype(np.float32)
        if c.audio_seq:
            out["frames"] = rng.standard_normal(
                (b, c.audio_seq, c.audio_dim)).astype(np.float32)
        return out

    # iterator sugar for the examples
    def iter_from(self, step: int):
        while True:
            yield self.batch_at(step)
            step += 1


def make_data(model_cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
              num_shards: int = 1, shard_id: int = 0) -> SyntheticLMData:
    kw = {}
    if model_cfg.family == "vlm":
        kw = dict(vision_seq=model_cfg.vision_seq or 16,
                  vision_dim=model_cfg.vision_dim or model_cfg.d_model)
    if model_cfg.family == "audio":
        kw = dict(audio_seq=model_cfg.encoder_seq or 64,
                  audio_dim=model_cfg.d_model)
    return SyntheticLMData(DataConfig(
        vocab_size=model_cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        num_shards=num_shards, shard_id=shard_id, **kw))
