"""Version-compatibility shims for the jax APIs this repo leans on.

The reproduction targets the pinned container jax (0.4.37 today) while the
code is written against the modern surface; every API that moved, was
renamed, or changed signature between jax 0.4.x and 0.6+ is centralized here
behind a stable function.  Nothing outside this module may touch
``jax.experimental.pallas.tpu`` attributes or version-gated ``jax.sharding``
lookups directly — kernels go through :mod:`repro.kernels.dispatch`, which in
turn goes through here.

Shimmed surfaces
----------------
- ``jax.sharding.get_abstract_mesh`` (added ~0.5): :func:`get_abstract_mesh`
  falls back to the thread-local physical mesh that ``with mesh:`` installs
  on 0.4.x.
- ``AbstractMesh`` constructor: 0.4.x takes ``((name, size), ...)``, newer
  jax takes ``(sizes, names)`` — :func:`make_abstract_mesh` accepts the
  modern form everywhere.
- ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` rename:
  :func:`tpu_compiler_params` builds whichever class exists and silently
  drops kwargs the pinned class does not know.
- pallas-TPU availability: CPU-only jaxlib builds may lack the mosaic
  lowering entirely; ``HAS_PALLAS_TPU`` gates it and :func:`pallas_tpu`
  raises a actionable error instead of an AttributeError mid-kernel.
- tree utils: ``jax.tree.map`` only exists from 0.4.26; :func:`tree_map`
  always works.
"""

from __future__ import annotations

import inspect
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

try:  # pallas is present in every pinned container; TPU lowering may not be
    from jax.experimental import pallas as pl  # noqa: F401
    from jax.experimental.pallas import tpu as _pltpu

    HAS_PALLAS_TPU = True
except ImportError:  # pragma: no cover - exercised on stripped builds only
    _pltpu = None
    HAS_PALLAS_TPU = False


# --------------------------------------------------------------------------
# pallas TPU surface
# --------------------------------------------------------------------------

def pallas_tpu():
    """The ``jax.experimental.pallas.tpu`` module, or a clear error."""
    if _pltpu is None:
        raise ImportError(
            "jax.experimental.pallas.tpu is unavailable in this jaxlib "
            "build; run kernels in 'ref' mode (REPRO_KERNEL_MODE=ref)")
    return _pltpu


def _compiler_params_cls():
    tpu = pallas_tpu()
    cls = getattr(tpu, "CompilerParams", None)  # jax >= 0.6 name
    if cls is None:
        cls = getattr(tpu, "TPUCompilerParams", None)  # 0.4.x - 0.5 name
    if cls is None:  # pragma: no cover - no known jax lacks both
        raise AttributeError("no pallas TPU CompilerParams class found")
    return cls


def tpu_compiler_params(**kwargs) -> Any:
    """``CompilerParams``/``TPUCompilerParams`` with unknown kwargs dropped.

    Dropping (rather than raising) keeps kernels expressible against the
    newest parameter set while still compiling on the pinned jax.
    """
    cls = _compiler_params_cls()
    accepted = set(inspect.signature(cls).parameters)
    return cls(**{k: v for k, v in kwargs.items() if k in accepted})


def vmem(shape: Tuple[int, ...], dtype) -> Any:
    """A VMEM scratch-shape allocation request."""
    return pallas_tpu().VMEM(shape, dtype)


def prefetch_scalar_grid_spec(*, num_scalar_prefetch: int, grid, in_specs,
                              out_specs, scratch_shapes=()) -> Any:
    return pallas_tpu().PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch, grid=grid,
        in_specs=in_specs, out_specs=out_specs,
        scratch_shapes=list(scratch_shapes))


# --------------------------------------------------------------------------
# mesh lookups
# --------------------------------------------------------------------------

def get_abstract_mesh() -> Optional[Any]:
    """The mesh currently installed by a ``with mesh:`` context, or None.

    On modern jax this is ``jax.sharding.get_abstract_mesh()``; on 0.4.x the
    equivalent signal is the thread-local *physical* mesh.  Both expose
    ``axis_names`` / ``shape``, which is all the sharding rules consume.
    """
    gam = getattr(jax.sharding, "get_abstract_mesh", None)
    if gam is not None:
        m = gam()
        return None if m is None or m.empty else m
    from jax._src import mesh as mesh_lib  # jax <= 0.4.x

    env = getattr(mesh_lib, "thread_resources", None)
    m = getattr(getattr(env, "env", None), "physical_mesh", None)
    if m is None or m.empty:
        return None
    return m


def set_mesh(mesh: Any):
    """Context manager installing ``mesh`` for tracing/dispatch.

    Modern jax spells this ``jax.set_mesh``; on 0.4.x the ``Mesh`` object is
    itself the context manager and installs the thread-local physical mesh
    that :func:`get_abstract_mesh` reads back.
    """
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh


def make_abstract_mesh(axis_sizes: Sequence[int],
                       axis_names: Sequence[str]) -> Any:
    """``AbstractMesh(axis_sizes, axis_names)`` across the constructor skew."""
    from jax.sharding import AbstractMesh

    params = list(inspect.signature(AbstractMesh.__init__).parameters)
    if "shape_tuple" in params:  # jax 0.4.x: one ((name, size), ...) tuple
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


# --------------------------------------------------------------------------
# tree utils
# --------------------------------------------------------------------------

_tree = getattr(jax, "tree", jax.tree_util)


def tree_map(f, tree, *rest, is_leaf=None):
    return _tree.map(f, tree, *rest, is_leaf=is_leaf) \
        if hasattr(_tree, "map") else \
        jax.tree_util.tree_map(f, tree, *rest, is_leaf=is_leaf)


def tree_leaves(tree, is_leaf=None):
    if hasattr(_tree, "leaves"):
        return _tree.leaves(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_leaves(tree, is_leaf=is_leaf)
