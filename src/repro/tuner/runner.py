"""Transfer-tuning runner: CAMEO (or a baseline) on a (source, target) pair.

The canonical production flow: collect a cheap observational dataset in the
source (analytic staging model or a previously-measured cell), then tune the
expensive target (a compiled cell, a different shape, a different arch, or
the multi-pod topology) under a fixed intervention budget.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.baselines import make_baseline
from repro.core.cameo import Cameo, Dataset
from repro.core.query import Query, parse_query


@dataclass
class TuneResult:
    method: str
    best_config: Optional[Dict]
    best_y: float
    trace_best_y: List[float]
    wall_s: float
    extras: Dict[str, Any] = field(default_factory=dict)


def transfer_tune(
    method: str,
    source_env,
    target_env,
    *,
    budget: int = 50,
    n_source: int = 300,
    n_target_init: int = 5,
    query_text: str = "minimize step_time within {budget} samples",
    seed: int = 0,
) -> TuneResult:
    t0 = time.time()
    d_s = source_env.dataset(n_source, seed=seed + 1)

    if method == "cameo":
        q = parse_query(query_text.format(budget=budget))
        # optimization operates on the TARGET's configuration space; source
        # measurements map onto the shared options (missing ones take the
        # target default) — the paper's software-change setting
        cam = Cameo(target_env.space, q, d_s,
                    counter_names=source_env.counter_names, seed=seed)
        cam.seed_target(target_env.dataset(n_target_init, seed=seed + 2))
        cfg, y = cam.run(target_env, budget)
        return TuneResult(
            method="cameo", best_config=cfg, best_y=y,
            trace_best_y=list(cam.trace.best_y), wall_s=time.time() - t0,
            extras={"k": cam.k, "reduced_space": list(cam.reduced_names),
                    "extraction_s": cam.extraction_s})

    tuner = make_baseline(method, target_env.space, d_s,
                          counter_names=source_env.counter_names, seed=seed)
    cfg, y = tuner.run(target_env, budget)
    return TuneResult(method=method, best_config=cfg, best_y=y,
                      trace_best_y=list(tuner.trace.best_y),
                      wall_s=time.time() - t0)
