"""Transfer-tuning runner: CAMEO (or a baseline) on a (source, target) pair.

The canonical production flow: collect a cheap observational dataset in the
source (analytic staging model or a previously-measured cell), then tune the
expensive target (a compiled cell, a different shape, a different arch, or
the multi-pod topology) under a fixed intervention budget.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.baselines import make_baseline
from repro.core.cameo import Cameo, Dataset
from repro.core.query import Query, parse_query


@dataclass
class TuneResult:
    method: str
    best_config: Optional[Dict]
    best_y: float
    trace_best_y: List[float]
    wall_s: float
    extras: Dict[str, Any] = field(default_factory=dict)
    #: per-round history when tuning ran ask/tell rounds: one record per
    #: round with ``size`` (measurements), ``actions``, and ``wall_s``
    rounds: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def launch_config(self) -> Dict[str, Any]:
        """The kernel-launch subset (``family.param`` keys) of the winning
        configuration — what the serve/train step factories install."""
        from repro.tuner.space import launch_config_of

        return launch_config_of(self.best_config or {})

    def install(self):
        """Context manager deploying the winning launch configuration onto
        the dispatch registry — this governs *raw* kernel dispatches
        underneath.  Serve/train steps are hermetic: to deploy into them,
        pass ``launch_config=result.launch_config`` to the step factories /
        ``jitted_steps`` instead (launch parameters are trace-time
        constants)."""
        from repro.kernels import dispatch

        return dispatch.use_launch_config(self.launch_config)


def transfer_tune(
    method: str,
    source_env,
    target_env,
    *,
    budget: int = 50,
    n_source: int = 300,
    n_target_init: int = 5,
    query_batch: int = 1,
    query_text: str = "minimize step_time within {budget} samples",
    l_alpha: float = 0.1,
    seed: int = 0,
) -> TuneResult:
    """``budget`` counts MEASUREMENTS, not rounds: with ``query_batch=k``
    the tuner runs ceil(budget / k) ask/tell rounds of (up to) k
    measurements each, so methods stay comparable at any k.  k=1 reproduces
    the historical sequential trajectories exactly."""
    t0 = time.time()
    qb = max(int(query_batch), 1)
    d_s = source_env.dataset(n_source, seed=seed + 1)
    # every method starts from the IDENTICAL free initial target dataset —
    # giving it only to CAMEO (via seed_target) would bias each comparison
    # by n_target_init free target measurements
    d_init = target_env.dataset(n_target_init, seed=seed + 2, query_batch=qb)
    init_record = {"n_target_init": len(d_init),
                   "target_init_ys": [float(y) for y in d_init.ys],
                   "query_batch": qb}
    rounds: List[Dict[str, Any]] = []

    if method == "cameo":
        q = parse_query(query_text.format(budget=budget))
        # optimization operates on the TARGET's configuration space; source
        # measurements map onto the shared options (missing ones take the
        # target default) — the paper's software-change setting
        cam = Cameo(target_env.space, q, d_s,
                    counter_names=source_env.counter_names, seed=seed,
                    l_alpha=l_alpha)
        cam.seed_target(d_init)
        cfg, y = cam.run(target_env, budget, query_batch=qb,
                         round_log=rounds)
        return TuneResult(
            method="cameo", best_config=cfg, best_y=y,
            trace_best_y=list(cam.trace.best_y), wall_s=time.time() - t0,
            extras={"k": cam.k, "reduced_space": list(cam.reduced_names),
                    "extraction_s": cam.extraction_s,
                    "model_update_s": float(np.mean(
                        cam.trace.model_update_s or [0.0])),
                    "recommend_s": float(np.mean(
                        cam.trace.recommend_s or [0.0])),
                    **init_record},
            rounds=rounds)

    tuner = make_baseline(method, target_env.space, d_s,
                          counter_names=source_env.counter_names, seed=seed)
    for c, cnt, y in zip(d_init.configs, d_init.counters, d_init.ys):
        tuner.update(c, cnt, y)
    cfg, y = tuner.run(target_env, budget, query_batch=qb, round_log=rounds)
    return TuneResult(method=method, best_config=cfg, best_y=y,
                      trace_best_y=list(tuner.trace.best_y),
                      wall_s=time.time() - t0, extras=dict(init_record),
                      rounds=rounds)


def tune_kernel_launch(target_workload, *, source_workload=None,
                       families=None, method: str = "cameo",
                       budget: int = 15, n_source: int = 64,
                       n_target_init: int = 4,
                       target_backend: Optional[str] = None,
                       query_batch: int = 1,
                       seed: int = 0) -> TuneResult:
    """Transfer-tune the kernel-launch space for one workload cell.

    Source is always the cheap analytic geometry backend (the staging
    environment); the target measures with ``target_backend`` (``None`` ->
    ``REPRO_MEASURE_BACKEND`` -> analytic; pass ``"wallclock"`` on a real
    host to time the actual kernels).  ``families`` restricts the tuned
    surface to the kernel families the workload actually dispatches —
    leaving it ``None`` tunes (and, under wallclock, times) every modeled
    family.  The returned ``TuneResult.launch_config`` feeds straight into
    the serve/train step factories or ``TuneResult.install()``.
    """
    from repro.envs.kernel_launch import KernelLaunchEnv

    source_workload = source_workload or target_workload
    src = KernelLaunchEnv(source_workload, families=families, seed=seed + 1,
                          backend="analytic")
    tgt = KernelLaunchEnv(target_workload, families=families, seed=seed + 2,
                          backend=target_backend)
    return transfer_tune(method, src, tgt, budget=budget, n_source=n_source,
                         n_target_init=n_target_init,
                         query_batch=query_batch, seed=seed)
