from repro.tuner.space import framework_space, config_to_parallel_kv  # noqa: F401
from repro.tuner.compiled_env import CompiledPerfEnv  # noqa: F401
from repro.tuner.runner import transfer_tune  # noqa: F401
