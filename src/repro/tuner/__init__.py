from repro.tuner.space import framework_space, config_to_parallel_kv  # noqa: F401
from repro.tuner.compiled_env import CompiledPerfEnv  # noqa: F401
from repro.tuner.runner import transfer_tune  # noqa: F401
from repro.tuner.bench import (  # noqa: F401
    BenchCell, make_shifted_pair, run_transfer_bench)
