"""Environment-shift transfer benchmarks: kernel-launch space under
``shifted:<kind>`` backends, and the serving stack under workload swaps.

The paper's central claim is that causal transfer survives *environmental
changes*.  This module measures exactly that on CPU-reproducible
environments, along two axes:

- **Kernel-launch sweep** (:func:`run_transfer_bench`): the source is the
  unshifted analytic launch-geometry model, the target is a
  :class:`~repro.envs.measure.ShiftedAnalyticBackend` a fixed distance away
  (scaled hardware constants, workload-shape changes, heteroscedastic
  noise, tightened VMEM feasibility).
- **Serving sweep** (:func:`run_serving_bench`): the tuned surface is the
  whole serving stack (scheduler knobs + launch geometry,
  :class:`~repro.envs.serving_env.ServingEnv`) and the environment change
  is a *workload-trace swap* — source trace → target trace, the paper's
  workload-fluctuation axis (``repro.workloads`` registry kinds).

For every cell x change x method tuple the sweep runs ``transfer_tune``
under a fixed intervention budget and records best-y and regret-vs-round
trajectories against a pooled ground-truth optimum of the target.

``benchmarks/transfer_bench.py`` / ``benchmarks/serving_bench.py`` are the
CLI wrappers writing ``BENCH_transfer.json`` / ``BENCH_serving.json``; the
``gate`` block is what CI asserts on (CAMEO's mean final regret must not
exceed random search).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.envs.kernel_launch import KernelLaunchEnv, KernelWorkload
from repro.envs.measure import ShiftedAnalyticBackend
from repro.tuner.runner import transfer_tune

#: regret assigned when a method never measured a feasible configuration —
#: far above any real relative regret so aggregate means stay ordered, while
#: trajectories keep ``None`` at those rounds (JSON has no inf)
INFEASIBLE_REGRET = 10.0

DEFAULT_SHIFTS = ("hardware", "workload", "feasibility")
DEFAULT_METHODS = ("cameo", "random")


@dataclass(frozen=True)
class BenchCell:
    """One workload cell the benchmark sweeps."""

    name: str
    workload: KernelWorkload
    families: Optional[Tuple[str, ...]] = None


DEFAULT_CELLS: Tuple[BenchCell, ...] = (
    BenchCell("serve-8b", KernelWorkload()),
    BenchCell("train-2k", KernelWorkload(name="train-2k", batch=16,
                                         seq_len=2048)),
)


def cell_by_name(name: str, cells: Sequence[BenchCell] = DEFAULT_CELLS
                 ) -> BenchCell:
    for c in cells:
        if c.name == name:
            return c
    raise ValueError(f"unknown bench cell {name!r}; "
                     f"known: {[c.name for c in cells]}")


def make_shifted_pair(cell: BenchCell, shift: str, seed: int = 0
                      ) -> Tuple[KernelLaunchEnv, KernelLaunchEnv]:
    """(source, target) environments for one cell under one shift kind:
    unshifted analytic source, shifted analytic target, identical launch
    space.  The source env owns the family defaulting (modeled ∩ registered
    when the cell doesn't pin them) and the target reuses its choice."""
    src = KernelLaunchEnv(cell.workload, families=cell.families,
                          seed=seed + 1, backend="analytic")
    tgt_backend = ShiftedAnalyticBackend(cell.workload, src.families,
                                         seed=seed + 2, shifts=shift)
    tgt = KernelLaunchEnv(cell.workload, backend=tgt_backend, seed=seed + 2)
    return src, tgt


def target_optimum(cell: BenchCell, shift: str, pool: int = 512,
                   seed: int = 99) -> float:
    """Ground-truth Y_opt of the shifted target: best measured value over a
    random pool (the paper's protocol, on a fresh noise stream)."""
    _, tgt = make_shifted_pair(cell, shift, seed=seed)
    rng = np.random.default_rng(seed)
    best = np.inf
    for cfg in tgt.space.sample(rng, pool):
        _, y = tgt.intervene(cfg)
        if np.isfinite(y) and y < best:
            best = float(y)
    if not np.isfinite(best):
        raise RuntimeError(
            f"no feasible configuration in a {pool}-sample pool for "
            f"cell={cell.name} shift={shift}")
    return best


def _regret(y: float, y_opt: float) -> Optional[float]:
    if not np.isfinite(y):
        return None
    return max(0.0, (float(y) - y_opt) / y_opt)


def _final_regret(trace: Sequence[float], y_opt: float) -> float:
    finite = [y for y in trace if np.isfinite(y)]
    if not finite:
        return INFEASIBLE_REGRET
    return _regret(min(finite), y_opt)


def _method_runs(make_pair, y_opt: float, *, methods: Sequence[str],
                 seeds: Sequence[int], budget: int, n_source: int,
                 n_target_init: int, query_batch: int = 1,
                 use_env_query: bool = False,
                 include_best_config: bool = False) -> Dict[str, Any]:
    """The per-method x per-seed run records every sweep shares: one
    ``transfer_tune`` per (method, seed) against a FRESH env pair from
    ``make_pair(seed)`` (backends' noise RNGs are stateful, so sharing a
    pair across methods would make results depend on run order), scored as
    regret trajectories against ``y_opt``.  ``query_batch`` restructures
    each run into ask/tell rounds of that many measurements (the budget is
    measurements either way); per-round sizes and wall-clock land in the
    run record's ``rounds``."""
    per_method: Dict[str, Any] = {}
    for method in methods:
        runs = []
        for seed in seeds:
            src, tgt = make_pair(seed)
            kw = {"query_text": tgt.query_text} if use_env_query else {}
            res = transfer_tune(method, src, tgt, budget=budget,
                                n_source=n_source,
                                n_target_init=n_target_init,
                                query_batch=query_batch, seed=seed,
                                **kw)
            trace = [float(y) for y in res.trace_best_y]
            run = {
                "seed": int(seed),
                "best_y": (float(res.best_y)
                           if np.isfinite(res.best_y) else None),
                "final_regret": _final_regret(trace, y_opt),
                "regret": [_regret(y, y_opt) for y in trace],
                "best_y_trace": [float(y) if np.isfinite(y) else None
                                 for y in trace],
                "wall_s": float(res.wall_s),
                "n_target_init": res.extras.get("n_target_init"),
                "rounds": [{"size": r["size"], "wall_s": r["wall_s"]}
                           for r in res.rounds],
            }
            if include_best_config:
                run["best_config"] = res.best_config
            runs.append(run)
        per_method[method] = {
            "runs": runs,
            "mean_final_regret": float(np.mean(
                [r["final_regret"] for r in runs])),
        }
    return per_method


def _finalize_doc(meta: Dict[str, Any], cells: List[Dict[str, Any]],
                  t_start: float) -> Dict[str, Any]:
    """Common document epilogue: meta + cells + the CI gate + wall time."""
    doc = {"meta": {**meta, "wall_s": None}, "cells": cells}
    doc["gate"] = gate_summary(doc)
    doc["meta"]["wall_s"] = round(time.time() - t_start, 2)
    return doc


def run_transfer_bench(
    *,
    cells: Sequence[BenchCell] = DEFAULT_CELLS,
    shifts: Sequence[str] = DEFAULT_SHIFTS,
    methods: Sequence[str] = DEFAULT_METHODS,
    budget: int = 20,
    n_source: int = 64,
    n_target_init: int = 4,
    seeds: Sequence[int] = (0, 1),
    pool: int = 512,
    query_batch: int = 1,
) -> Dict[str, Any]:
    """The full sweep; returns the ``BENCH_transfer.json`` document."""
    t_start = time.time()
    out_cells: List[Dict[str, Any]] = []
    for cell in cells:
        for shift in shifts:
            y_opt = target_optimum(cell, shift, pool=pool)
            out_cells.append({
                "cell": cell.name,
                "shift": shift,
                "y_opt": y_opt,
                "methods": _method_runs(
                    lambda seed: make_shifted_pair(cell, shift, seed=seed),
                    y_opt, methods=methods, seeds=seeds, budget=budget,
                    n_source=n_source, n_target_init=n_target_init,
                    query_batch=query_batch),
            })
    return _finalize_doc({
        "budget": int(budget),
        "n_source": int(n_source),
        "n_target_init": int(n_target_init),
        "seeds": [int(s) for s in seeds],
        "pool": int(pool),
        "query_batch": int(query_batch),
        "cells": [c.name for c in cells],
        "shifts": list(shifts),
        "methods": list(methods),
    }, out_cells, t_start)


# --------------------------------------------------------------------------
# serving sweep: source trace -> target trace
# --------------------------------------------------------------------------

#: the default cheap observational source — a calm memoryless arrival
#: process staging can always produce
DEFAULT_SOURCE_TRACE = "poisson:rate=2500"

#: target workload swaps the smoke sweep exercises: a burst regime, a
#: heavy-tailed length mixture (loaded enough that its y_opt is not tiny —
#: tiny optima amplify relative regret into gate noise), and a diurnal
#: rate cycle
DEFAULT_TARGET_TRACES: Tuple[str, ...] = (
    "bursty:rate=2500,burst=6",
    "heavy_tail:rate=2600",
    "diurnal:rate=2500",
)


@dataclass(frozen=True)
class ServingCell:
    """One served-model cell of the serving sweep: kernel dimensions plus
    the families the model dispatches and the source arrival process."""

    name: str
    cell: KernelWorkload
    families: Tuple[str, ...] = ("flash_attention", "rmsnorm")
    source: str = DEFAULT_SOURCE_TRACE


DEFAULT_SERVING_CELLS: Tuple[ServingCell, ...] = (
    ServingCell("serve-8b", KernelWorkload()),
)


def serving_cell_by_name(name: str,
                         cells: Sequence[ServingCell] = DEFAULT_SERVING_CELLS
                         ) -> ServingCell:
    for c in cells:
        if c.name == name:
            return c
    raise ValueError(f"unknown serving cell {name!r}; "
                     f"known: {[c.name for c in cells]}")


def paged_serving_surface(cells: Sequence[Any]) -> Tuple[Any, ...]:
    """Each cell's family set with ``paged_attention`` joined: the sweep
    then tunes the paged-KV surface — ``pages.*`` scheduler knobs plus the
    family's launch options (page size, pages per slot, prefill chunk) —
    alongside ``serving.*`` and the other launch geometry.  Works for both
    :class:`ServingCell` and :class:`FleetCell`."""
    return tuple(
        c if "paged_attention" in c.families
        else replace(c, families=c.families + ("paged_attention",))
        for c in cells)


#: the trace realization every (cell, target) sweep point shares.  Unlike
#: the shifted kernel backends (where the seed only drives noise), a
#: ServingEnv's seed would otherwise pick the trace itself — and y_opt,
#: y_default, and every method run must score against the SAME arrival
#: process or regret compares different environments.
BENCH_TRACE_SEED = 0


def make_serving_bench_pair(cell: ServingCell, target: str, seed: int = 0):
    """(source, target) ServingEnv pair for one cell and one target trace.
    ``seed`` varies only the measurement-noise streams; the trace
    realization is pinned to ``BENCH_TRACE_SEED``."""
    from repro.envs.serving_env import make_serving_pair

    return make_serving_pair(cell.source, target, cell.cell,
                             families=cell.families, seed=seed,
                             trace_seed=BENCH_TRACE_SEED)


def serving_target_optimum(cell: ServingCell, target: str, pool: int = 256,
                           seed: int = 99
                           ) -> Tuple[float, Optional[float]]:
    """(Y_opt, y_default) of the target serving environment: best measured
    value over a random pool plus the default configuration's measurement —
    the deploy-nothing baseline the tuned config must beat."""
    _, tgt = make_serving_bench_pair(cell, target, seed=seed)
    rng = np.random.default_rng(seed)
    _, y_default = tgt.intervene(tgt.space.default_config())
    best = y_default if np.isfinite(y_default) else np.inf
    for cfg in tgt.space.sample(rng, pool):
        _, y = tgt.intervene(cfg)
        if np.isfinite(y) and y < best:
            best = float(y)
    if not np.isfinite(best):
        raise RuntimeError(
            f"no feasible configuration in a {pool}-sample pool for "
            f"cell={cell.name} target={target}")
    return best, (float(y_default) if np.isfinite(y_default) else None)


def run_serving_bench(
    *,
    cells: Sequence[ServingCell] = DEFAULT_SERVING_CELLS,
    targets: Sequence[str] = DEFAULT_TARGET_TRACES,
    methods: Sequence[str] = DEFAULT_METHODS,
    budget: int = 12,
    n_source: int = 48,
    n_target_init: int = 3,
    seeds: Sequence[int] = (0, 1),
    pool: int = 256,
    query_batch: int = 1,
    paged: bool = False,
) -> Dict[str, Any]:
    """The serving-stack sweep (cell x target trace x method); returns the
    ``BENCH_serving.json`` document.  Shape mirrors the kernel-launch sweep
    with ``source``/``target`` trace specs instead of a shift kind, plus a
    per-cell ``y_default`` so 'tuned beats the default plan' is auditable.
    ``paged=True`` widens every cell to the paged-KV surface
    (:func:`paged_serving_surface`) and stamps the mode into ``meta``."""
    t_start = time.time()
    if paged:
        cells = paged_serving_surface(cells)
    out_cells: List[Dict[str, Any]] = []
    for cell in cells:
        for target in targets:
            y_opt, y_default = serving_target_optimum(cell, target,
                                                      pool=pool)
            out_cells.append({
                "cell": cell.name,
                "source": cell.source,
                "target": target,
                "y_opt": y_opt,
                "y_default": y_default,
                "methods": _method_runs(
                    lambda seed: make_serving_bench_pair(cell, target,
                                                         seed=seed),
                    y_opt, methods=methods, seeds=seeds, budget=budget,
                    n_source=n_source, n_target_init=n_target_init,
                    query_batch=query_batch,
                    use_env_query=True, include_best_config=True),
            })
    return _finalize_doc({
        "budget": int(budget),
        "n_source": int(n_source),
        "n_target_init": int(n_target_init),
        "seeds": [int(s) for s in seeds],
        "pool": int(pool),
        "query_batch": int(query_batch),
        "cells": [c.name for c in cells],
        "sources": [c.source for c in cells],
        "targets": list(targets),
        "methods": list(methods),
        "paged": bool(paged),
    }, out_cells, t_start)


# --------------------------------------------------------------------------
# fleet sweep: healthy fleet source -> disrupted fleet target
# --------------------------------------------------------------------------

#: fleet-disruption shift kinds the fleet sweep defaults to — the two
#: registered by this subsystem (``shifted:straggler``/``shifted:resize``)
DEFAULT_FLEET_SHIFTS: Tuple[str, ...] = ("straggler", "resize")


@dataclass(frozen=True)
class FleetCell:
    """One fleet sweep point: a served model + arrival process + device
    budget, tuned with the ``fleet.*`` router/replica knobs joined in."""

    name: str
    cell: KernelWorkload
    families: Tuple[str, ...] = ("flash_attention", "rmsnorm")
    workload: str = "bursty:rate=2500,burst=6"
    num_devices: int = 8


DEFAULT_FLEET_CELLS: Tuple[FleetCell, ...] = (
    FleetCell("serve-8b", KernelWorkload()),
)


def fleet_cell_by_name(name: str,
                       cells: Sequence[FleetCell] = DEFAULT_FLEET_CELLS
                       ) -> FleetCell:
    for c in cells:
        if c.name == name:
            return c
    raise ValueError(f"unknown fleet cell {name!r}; "
                     f"known: {[c.name for c in cells]}")


def make_fleet_bench_pair(cell: FleetCell, shift: str, seed: int = 0):
    """(healthy fleet source, disrupted fleet target) over the pinned trace
    realization — same workload, same device budget, the target additionally
    suffering ``shift`` (straggling devices / an elastic resize).  ``seed``
    varies only the measurement-noise streams."""
    from repro.envs.serving_env import make_fleet_pair

    return make_fleet_pair(cell.workload, shift, cell.cell,
                           families=cell.families, seed=seed,
                           num_devices=cell.num_devices,
                           trace_seed=BENCH_TRACE_SEED)


def fleet_target_optimum(cell: FleetCell, shift: str, pool: int = 256,
                         seed: int = 99) -> Tuple[float, Optional[float]]:
    """(Y_opt, y_default) of the disrupted fleet target: best measured value
    over a random pool plus the default fleet configuration."""
    _, tgt = make_fleet_bench_pair(cell, shift, seed=seed)
    rng = np.random.default_rng(seed)
    _, y_default = tgt.intervene(tgt.space.default_config())
    best = y_default if np.isfinite(y_default) else np.inf
    for cfg in tgt.space.sample(rng, pool):
        _, y = tgt.intervene(cfg)
        if np.isfinite(y) and y < best:
            best = float(y)
    if not np.isfinite(best):
        raise RuntimeError(
            f"no feasible configuration in a {pool}-sample pool for "
            f"fleet cell={cell.name} shift={shift}")
    return best, (float(y_default) if np.isfinite(y_default) else None)


def run_fleet_bench(
    *,
    cells: Sequence[FleetCell] = DEFAULT_FLEET_CELLS,
    shifts: Sequence[str] = DEFAULT_FLEET_SHIFTS,
    methods: Sequence[str] = DEFAULT_METHODS,
    budget: int = 12,
    n_source: int = 48,
    n_target_init: int = 3,
    seeds: Sequence[int] = (0, 1),
    pool: int = 256,
    query_batch: int = 1,
    paged: bool = False,
) -> Dict[str, Any]:
    """The fleet sweep (cell x disruption x method); returns the
    ``BENCH_fleet.json`` document.  Both halves of every pair tune the full
    fleet surface (``fleet.*`` + ``serving.*`` + launch geometry); the
    environment change is the fleet disruption, so the gate asserts CAMEO's
    transfer survives stragglers and elastic resizes — with the winning
    replica count / routing policy auditable per run via ``best_config``.
    ``paged=True`` widens every cell to the paged-KV surface."""
    t_start = time.time()
    if paged:
        cells = paged_serving_surface(cells)
    out_cells: List[Dict[str, Any]] = []
    for cell in cells:
        for shift in shifts:
            y_opt, y_default = fleet_target_optimum(cell, shift, pool=pool)
            out_cells.append({
                "cell": cell.name,
                "workload": cell.workload,
                "shift": shift,
                "num_devices": cell.num_devices,
                "y_opt": y_opt,
                "y_default": y_default,
                "methods": _method_runs(
                    lambda seed: make_fleet_bench_pair(cell, shift,
                                                       seed=seed),
                    y_opt, methods=methods, seeds=seeds, budget=budget,
                    n_source=n_source, n_target_init=n_target_init,
                    query_batch=query_batch,
                    use_env_query=True, include_best_config=True),
            })
    return _finalize_doc({
        "budget": int(budget),
        "n_source": int(n_source),
        "n_target_init": int(n_target_init),
        "seeds": [int(s) for s in seeds],
        "pool": int(pool),
        "query_batch": int(query_batch),
        "cells": [c.name for c in cells],
        "workloads": [c.workload for c in cells],
        "shifts": list(shifts),
        "methods": list(methods),
        "paged": bool(paged),
    }, out_cells, t_start)


# --------------------------------------------------------------------------
# sim-to-real sweep: simulator source -> real-batcher replay target
# --------------------------------------------------------------------------

#: pinned tiny traces the sim2real smoke sweep replays — small enough that a
#: real-batcher measurement (jit compile + replay) stays in CI budget
DEFAULT_SIM2REAL_WORKLOADS: Tuple[str, ...] = (
    "poisson:rate=1500,horizon=0.004,mean_prompt=6,mean_output=4,max_len=16",
    ("bursty:rate=1500,burst=6,horizon=0.004,mean_prompt=6,mean_output=4,"
     "max_len=16"),
)


@dataclass(frozen=True)
class Sim2RealCell:
    """One sim-to-real sweep point: a pinned trace replayed through the
    default tiny deployment (``repro.envs.replay_env.default_replay_model``).
    """

    name: str
    workload: str


DEFAULT_SIM2REAL_CELLS: Tuple[Sim2RealCell, ...] = (
    Sim2RealCell("tiny-poisson", DEFAULT_SIM2REAL_WORKLOADS[0]),
    Sim2RealCell("tiny-bursty", DEFAULT_SIM2REAL_WORKLOADS[1]),
)


def sim2real_cell_by_name(name: str,
                          cells: Sequence[Sim2RealCell] = DEFAULT_SIM2REAL_CELLS
                          ) -> Sim2RealCell:
    for c in cells:
        if c.name == name:
            return c
    raise ValueError(f"unknown sim2real cell {name!r}; "
                     f"known: {[c.name for c in cells]}")


def make_sim2real_bench_pair(cell: Sim2RealCell, seed: int = 0,
                             repeats: int = 3):
    """(simulator source, replay target) for one cell over the pinned trace
    realization (``BENCH_TRACE_SEED``, same convention as the serving
    sweep).  ``seed`` varies the source's noise stream only — the deployment
    (model weights, replay sampling) is part of the environment and stays
    fixed, exactly like real hardware across tuning runs."""
    from repro.envs.replay_env import make_sim2real_pair

    return make_sim2real_pair(cell.workload, seed=seed,
                              trace_seed=BENCH_TRACE_SEED, repeats=repeats)


def sim2real_target_optimum(cell: Sim2RealCell, pool: int = 16,
                            seed: int = 99, repeats: int = 3,
                            query_batch: int = 1
                            ) -> Tuple[float, Optional[float]]:
    """(Y_opt, y_default) of the replay target over a random pool plus the
    default configuration — each entry a real batcher replay, so pools stay
    far smaller than the simulator sweeps'.

    ``query_batch > 1`` collects the pool in compile-key-sharing groups
    through ``intervene_batch`` (the first group anchored on the DEFAULT
    configuration's shared dims, so the default's deployment serves it
    too) — the dominant cost of the sim2real sweep is this pool's jit
    compiles, and grouping collapses them to one per group."""
    _, tgt = make_sim2real_bench_pair(cell, seed=seed, repeats=repeats)
    rng = np.random.default_rng(seed)
    default = tgt.space.default_config()
    if query_batch > 1:
        cfgs = tgt._grouped_sample(rng, pool, query_batch)
        share = [nm for nm in (tgt.batch_share_dims or ())
                 if nm in tgt.space.by_name]
        for c in cfgs[:query_batch]:
            for nm in share:
                c[nm] = default[nm]
        results = tgt.intervene_batch([default] + cfgs)
        y_default = results[0][1]
        ys = [y for _, y in results if np.isfinite(y)]
        best = min(ys) if ys else np.inf
    else:
        _, y_default = tgt.intervene(default)
        best = y_default if np.isfinite(y_default) else np.inf
        for cfg in tgt.space.sample(rng, pool):
            _, y = tgt.intervene(cfg)
            if np.isfinite(y) and y < best:
                best = float(y)
    if not np.isfinite(best):
        raise RuntimeError(
            f"no feasible configuration in a {pool}-sample pool for "
            f"sim2real cell={cell.name}")
    return best, (float(y_default) if np.isfinite(y_default) else None)


def run_sim2real_bench(
    *,
    cells: Sequence[Sim2RealCell] = DEFAULT_SIM2REAL_CELLS,
    methods: Sequence[str] = DEFAULT_METHODS,
    budget: int = 6,
    n_source: int = 32,
    n_target_init: int = 2,
    seeds: Sequence[int] = (0,),
    pool: int = 16,
    repeats: int = 3,
    query_batch: int = 1,
) -> Dict[str, Any]:
    """The sim-to-real sweep (cell x method); returns the
    ``BENCH_sim2real.json`` document.  The source is the deterministic
    serving simulator, the target is the real ``ContinuousBatcher`` replay —
    regret is measured IN THE REPLAY ENVIRONMENT (wall-clock ms), so the
    gate asserts that causal transfer survives the sim-to-real fidelity gap,
    not just a second simulator.  Document shape mirrors the serving sweep
    with a ``workload`` field per cell instead of ``source``/``target``."""
    t_start = time.time()
    out_cells: List[Dict[str, Any]] = []
    for cell in cells:
        y_opt, y_default = sim2real_target_optimum(cell, pool=pool,
                                                   repeats=repeats,
                                                   query_batch=query_batch)
        out_cells.append({
            "cell": cell.name,
            "workload": cell.workload,
            "y_opt": y_opt,
            "y_default": y_default,
            "methods": _method_runs(
                lambda seed: make_sim2real_bench_pair(cell, seed=seed,
                                                      repeats=repeats),
                y_opt, methods=methods, seeds=seeds, budget=budget,
                n_source=n_source, n_target_init=n_target_init,
                query_batch=query_batch,
                use_env_query=True, include_best_config=True),
        })
    return _finalize_doc({
        "budget": int(budget),
        "n_source": int(n_source),
        "n_target_init": int(n_target_init),
        "seeds": [int(s) for s in seeds],
        "pool": int(pool),
        "repeats": int(repeats),
        "query_batch": int(query_batch),
        "cells": [c.name for c in cells],
        "workloads": [c.workload for c in cells],
        "methods": list(methods),
    }, out_cells, t_start)


def gate_summary(doc: Dict[str, Any], champion: str = "cameo",
                 reference: str = "random") -> Dict[str, Any]:
    """CI acceptance: the champion's mean final regret (over every
    cell x shift x seed) must not exceed the reference's.  Absent methods
    make the gate vacuously pass (``checked: False``)."""
    champ, ref = [], []
    for cell in doc["cells"]:
        methods = cell["methods"]
        if champion in methods and reference in methods:
            champ.extend(r["final_regret"] for r in methods[champion]["runs"])
            ref.extend(r["final_regret"] for r in methods[reference]["runs"])
    if not champ:
        return {"checked": False, "passed": True,
                "champion": champion, "reference": reference}
    c, r = float(np.mean(champ)), float(np.mean(ref))
    return {"checked": True, "passed": bool(c <= r),
            "champion": champion, "reference": reference,
            "champion_mean_final_regret": c,
            "reference_mean_final_regret": r}
