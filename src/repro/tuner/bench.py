"""Environment-shift transfer benchmark over the kernel-launch space.

The paper's central claim is that causal transfer survives *environmental
changes*.  This module measures exactly that on CPU-reproducible
environments: the source is the unshifted analytic launch-geometry model,
the target is a :class:`~repro.envs.measure.ShiftedAnalyticBackend` a fixed
distance away (scaled hardware constants, workload-shape changes,
heteroscedastic noise, tightened VMEM feasibility).  For every
(workload cell x shift kind x method) tuple the sweep runs
``transfer_tune`` under a fixed intervention budget and records the best-y
and regret-vs-round trajectories against a pooled ground-truth optimum of
the shifted target.

``benchmarks/transfer_bench.py`` is the CLI wrapper that writes
``BENCH_transfer.json``; the ``gate`` block is what CI asserts on (CAMEO's
mean final regret must not exceed random search on the shifted cells).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.envs.kernel_launch import KernelLaunchEnv, KernelWorkload
from repro.envs.measure import ShiftedAnalyticBackend
from repro.tuner.runner import transfer_tune

#: regret assigned when a method never measured a feasible configuration —
#: far above any real relative regret so aggregate means stay ordered, while
#: trajectories keep ``None`` at those rounds (JSON has no inf)
INFEASIBLE_REGRET = 10.0

DEFAULT_SHIFTS = ("hardware", "workload", "feasibility")
DEFAULT_METHODS = ("cameo", "random")


@dataclass(frozen=True)
class BenchCell:
    """One workload cell the benchmark sweeps."""

    name: str
    workload: KernelWorkload
    families: Optional[Tuple[str, ...]] = None


DEFAULT_CELLS: Tuple[BenchCell, ...] = (
    BenchCell("serve-8b", KernelWorkload()),
    BenchCell("train-2k", KernelWorkload(name="train-2k", batch=16,
                                         seq_len=2048)),
)


def cell_by_name(name: str, cells: Sequence[BenchCell] = DEFAULT_CELLS
                 ) -> BenchCell:
    for c in cells:
        if c.name == name:
            return c
    raise ValueError(f"unknown bench cell {name!r}; "
                     f"known: {[c.name for c in cells]}")


def make_shifted_pair(cell: BenchCell, shift: str, seed: int = 0
                      ) -> Tuple[KernelLaunchEnv, KernelLaunchEnv]:
    """(source, target) environments for one cell under one shift kind:
    unshifted analytic source, shifted analytic target, identical launch
    space.  The source env owns the family defaulting (modeled ∩ registered
    when the cell doesn't pin them) and the target reuses its choice."""
    src = KernelLaunchEnv(cell.workload, families=cell.families,
                          seed=seed + 1, backend="analytic")
    tgt_backend = ShiftedAnalyticBackend(cell.workload, src.families,
                                         seed=seed + 2, shifts=shift)
    tgt = KernelLaunchEnv(cell.workload, backend=tgt_backend, seed=seed + 2)
    return src, tgt


def target_optimum(cell: BenchCell, shift: str, pool: int = 512,
                   seed: int = 99) -> float:
    """Ground-truth Y_opt of the shifted target: best measured value over a
    random pool (the paper's protocol, on a fresh noise stream)."""
    _, tgt = make_shifted_pair(cell, shift, seed=seed)
    rng = np.random.default_rng(seed)
    best = np.inf
    for cfg in tgt.space.sample(rng, pool):
        _, y = tgt.intervene(cfg)
        if np.isfinite(y) and y < best:
            best = float(y)
    if not np.isfinite(best):
        raise RuntimeError(
            f"no feasible configuration in a {pool}-sample pool for "
            f"cell={cell.name} shift={shift}")
    return best


def _regret(y: float, y_opt: float) -> Optional[float]:
    if not np.isfinite(y):
        return None
    return max(0.0, (float(y) - y_opt) / y_opt)


def _final_regret(trace: Sequence[float], y_opt: float) -> float:
    finite = [y for y in trace if np.isfinite(y)]
    if not finite:
        return INFEASIBLE_REGRET
    return _regret(min(finite), y_opt)


def run_transfer_bench(
    *,
    cells: Sequence[BenchCell] = DEFAULT_CELLS,
    shifts: Sequence[str] = DEFAULT_SHIFTS,
    methods: Sequence[str] = DEFAULT_METHODS,
    budget: int = 20,
    n_source: int = 64,
    n_target_init: int = 4,
    seeds: Sequence[int] = (0, 1),
    pool: int = 512,
) -> Dict[str, Any]:
    """The full sweep; returns the ``BENCH_transfer.json`` document."""
    t_start = time.time()
    out_cells: List[Dict[str, Any]] = []
    for cell in cells:
        for shift in shifts:
            y_opt = target_optimum(cell, shift, pool=pool)
            per_method: Dict[str, Any] = {}
            for method in methods:
                runs = []
                for seed in seeds:
                    # fresh env pair per (method, seed): the backends' noise
                    # RNGs are stateful, so sharing one pair across methods
                    # would make results depend on run order
                    src, tgt = make_shifted_pair(cell, shift, seed=seed)
                    res = transfer_tune(method, src, tgt, budget=budget,
                                        n_source=n_source,
                                        n_target_init=n_target_init,
                                        seed=seed)
                    trace = [float(y) for y in res.trace_best_y]
                    runs.append({
                        "seed": int(seed),
                        "best_y": (float(res.best_y)
                                   if np.isfinite(res.best_y) else None),
                        "final_regret": _final_regret(trace, y_opt),
                        "regret": [_regret(y, y_opt) for y in trace],
                        "best_y_trace": [
                            float(y) if np.isfinite(y) else None
                            for y in trace],
                        "wall_s": float(res.wall_s),
                        "n_target_init": res.extras.get("n_target_init"),
                    })
                per_method[method] = {
                    "runs": runs,
                    "mean_final_regret": float(np.mean(
                        [r["final_regret"] for r in runs])),
                }
            out_cells.append({
                "cell": cell.name,
                "shift": shift,
                "y_opt": y_opt,
                "methods": per_method,
            })
    doc = {
        "meta": {
            "budget": int(budget),
            "n_source": int(n_source),
            "n_target_init": int(n_target_init),
            "seeds": [int(s) for s in seeds],
            "pool": int(pool),
            "cells": [c.name for c in cells],
            "shifts": list(shifts),
            "methods": list(methods),
            "wall_s": None,  # filled below
        },
        "cells": out_cells,
    }
    doc["gate"] = gate_summary(doc)
    doc["meta"]["wall_s"] = round(time.time() - t_start, 2)
    return doc


def gate_summary(doc: Dict[str, Any], champion: str = "cameo",
                 reference: str = "random") -> Dict[str, Any]:
    """CI acceptance: the champion's mean final regret (over every
    cell x shift x seed) must not exceed the reference's.  Absent methods
    make the gate vacuously pass (``checked: False``)."""
    champ, ref = [], []
    for cell in doc["cells"]:
        methods = cell["methods"]
        if champion in methods and reference in methods:
            champ.extend(r["final_regret"] for r in methods[champion]["runs"])
            ref.extend(r["final_regret"] for r in methods[reference]["runs"])
    if not champ:
        return {"checked": False, "passed": True,
                "champion": champion, "reference": reference}
    c, r = float(np.mean(champ)), float(np.mean(ref))
    return {"checked": True, "passed": bool(c <= r),
            "champion": champion, "reference": reference,
            "champion_mean_final_regret": c,
            "reference_mean_final_regret": r}
