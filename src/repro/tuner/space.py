"""The framework's own tunable surface as a CAMEO ConfigSpace.

These are the cross-stack knobs a TPU performance engineer actually turns —
the analogue of the paper's cpu_frequency / swappiness / dirty_ratio, with
the same properties: they interact, some combinations are invalid, and their
effect flips across environments (a tp that is optimal for a 15B dense model
is over-sharded for a 1B one).
"""

from __future__ import annotations

from typing import Any, Dict

from repro.core.spaces import ConfigSpace, Option
from repro.utils.config import ModelConfig, ParallelConfig


def launch_families_for(cfg: ModelConfig) -> list:
    """Kernel families this architecture actually dispatches — the single
    source of the applicability rules shared by
    ``framework_space(include_kernel_launch=True)`` and the serve launcher's
    ``--tune-launch``.  Tuning (and, under the wallclock backend, timing) a
    family the model never runs wastes intervention budget on knobs with
    zero effect."""
    fams = ["rmsnorm"]
    if not cfg.is_attention_free:
        fams.append("flash_attention")
    if cfg.family in ("ssm", "hybrid"):
        # ssm_num_heads == 0 -> mamba-1 (selective scan); > 0 -> mamba-2 (ssd)
        fams.append("ssd" if cfg.ssm_num_heads else "mamba_scan")
    return fams


def framework_space(cfg: ModelConfig, kind: str = "train",
                    include_kernel_launch: bool = False) -> ConfigSpace:
    opts = [
        Option("microbatch", (1, 2, 4, 8), default=1),
        Option("remat", ("none", "dots", "full"), default="none",
               kind="categorical"),
        Option("sp", (0, 1), default=0, kind="boolean"),
        Option("grad_compression", ("none", "bf16", "int8_ef"),
               default="none", kind="categorical"),
        Option("scan_layers", (0, 1), default=1, kind="boolean"),
        Option("fsdp", (1, 2), default=2),
    ]
    if not cfg.is_attention_free:
        opts.append(Option("attn_q_block", (256, 512, 1024), default=512))
        opts.append(Option("attn_kv_block", (512, 1024, 2048), default=1024))
    if cfg.family in ("ssm", "hybrid"):
        opts.append(Option("ssm_chunk", (128, 256, 512), default=256))
    if cfg.is_moe:
        opts.append(Option("moe_group_size", (256, 512, 1024), default=512))
        opts.append(Option("moe_expert_axis", ("model", "data"),
                           default="model", kind="categorical"))
    if kind != "train":
        opts = [o for o in opts
                if o.name in ("attn_kv_block", "sp", "scan_layers",
                              "moe_group_size", "moe_expert_axis",
                              "ssm_chunk")]
        if not opts:
            opts = [Option("scan_layers", (0, 1), default=1, kind="boolean")]
    if include_kernel_launch:
        # the dispatch registry's launch parameters (``family.param`` keys)
        # replace the plan-level block knobs — one source of truth per
        # parameter, since an active ``dispatch.use_launch_config`` outranks
        # the ``ParallelConfig`` values at the call sites.  Apply the tuned
        # values with ``use_launch_config(launch_config_of(config))`` around
        # the measured step (and re-jit: launch params are baked at trace
        # time).
        from repro.kernels import dispatch

        overlap = {"attn_q_block": "flash_attention.q_block",
                   "attn_kv_block": "flash_attention.kv_block",
                   "ssm_chunk": "mamba_scan.chunk"}
        opts = [o for o in opts if o.name not in overlap]
        opts = opts + list(dispatch.launch_space(launch_families_for(cfg)).options)
    return ConfigSpace(opts)


def config_to_parallel_kv(config: Dict[str, Any]) -> str:
    """Tuner config -> the dryrun --parallel override string."""
    items = []
    for k, v in config.items():
        if k == "ssm_chunk" or "." in k:
            continue  # model-config / kernel-launch knobs, handled separately
        items.append(f"{k}={v}")
    return ",".join(items)


def launch_config_of(config: Dict[str, Any]) -> Dict[str, Any]:
    """The kernel-launch subset (``family.param`` keys) of a tuner config —
    feed it to ``repro.kernels.dispatch.use_launch_config`` around the step.
    ``serving.*`` scheduler options, ``fleet.*`` router options and
    ``pages.*`` paging options are dotted but are NOT launch knobs (they
    deploy through ``ServingPlan.from_config`` / ``FleetPlan.from_config`` /
    ``PagedPlan.from_config``), so they are excluded.  The prefix literals
    match ``repro.workloads.sim.SERVING_PREFIX`` / ``FLEET_PREFIX`` /
    ``repro.serving.paging.PAGES_PREFIX`` — kept inline so this hot
    extraction path does not import the scheduler/model stack."""
    return {k: v for k, v in config.items()
            if "." in k and not k.startswith(("serving.", "fleet.",
                                              "pages."))}


def apply_config(par: ParallelConfig, config: Dict[str, Any]) -> ParallelConfig:
    kw = {}
    for k, v in config.items():
        if k == "ssm_chunk" or "." in k:
            continue  # kernel-launch keys apply via dispatch.use_launch_config
        cur = getattr(par, k)
        if isinstance(cur, bool):
            kw[k] = bool(v)
        elif isinstance(cur, int):
            kw[k] = int(v)
        else:
            kw[k] = v
    return par.replace(**kw)
