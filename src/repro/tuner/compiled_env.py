"""CompiledPerfEnv — the ground-truth tuning backend.

``intervene(config)`` lowers + compiles the actual train/serve step for one
(arch x shape) cell under the chosen parallel plan (in a subprocess, because
the 512-device XLA flag must be set before jax initializes) and returns the
three-term roofline estimate from the compiled HLO as the objective, with
the roofline terms as system-event counters.

This is exactly the paper's "production environment is expensive to query"
setting: one intervention costs a full XLA compile (tens of seconds), which
is why CAMEO warm-starts from the cheap AnalyticTPUEnv source.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.spaces import ConfigSpace
from repro.envs.base import PooledEnv
from repro.tuner.space import config_to_parallel_kv, framework_space
from repro.utils.hardware import TPU_V5E, HardwareSpec

_REPO_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


def make_aligned_source(arch: str = "llama3.2-1b", seed: int = 0):
    """An AnalyticTPUEnv whose option vocabulary matches the framework's
    (``seq_parallel`` -> ``sp``, ``int8`` -> ``int8_ef``), so its
    observational dataset transfers onto ``framework_space`` by name."""
    from repro.core.spaces import ConfigSpace, Option
    from repro.envs.analytic import AnalyticTPUEnv, TPUEnvSpec

    rename = {"seq_parallel": "sp"}
    value_map = {"grad_compression": {"int8": "int8_ef"}}

    class AlignedAnalyticEnv(AnalyticTPUEnv):
        def __init__(self):
            base_arch = arch if arch in ("llama3.2-1b", "nemotron-4-15b",
                                         "command-r-35b", "falcon-mamba-7b",
                                         "deepseek-v3-671b") else "llama3.2-1b"
            super().__init__(TPUEnvSpec(arch=base_arch), seed=seed)
            opts = []
            for o in self.space.options:
                name = rename.get(o.name, o.name)
                vals = tuple(value_map.get(o.name, {}).get(v, v)
                             for v in o.values)
                dflt = value_map.get(o.name, {}).get(o.default, o.default)
                opts.append(Option(name, vals, default=dflt, kind=o.kind))
            self.space = ConfigSpace(opts)

        def _measure(self, config):
            inner = {}
            inv_rename = {v: k for k, v in rename.items()}
            for k, v in config.items():
                ik = inv_rename.get(k, k)
                if ik in value_map:
                    inv_vals = {nv: ov for ov, nv in value_map[ik].items()}
                    v = inv_vals.get(v, v)
                inner[ik] = v
            return super()._measure(inner)

    return AlignedAnalyticEnv()


class CompiledPerfEnv(PooledEnv):
    counter_names = ("compute_s", "memory_s", "collective_s",
                     "flops_per_chip", "hbm_bytes", "collective_bytes",
                     "peak_mem_gb")

    def __init__(self, arch: str, shape: str, *, multi_pod: bool = False,
                 hardware: HardwareSpec = TPU_V5E, seed: int = 0,
                 timeout_s: int = 1200, cache_dir: Optional[str] = None):
        from repro.configs.registry import get_model_config

        self.arch = arch
        self.shape_name = shape
        self.multi_pod = multi_pod
        self.hw = hardware
        self.timeout_s = timeout_s
        cfg = get_model_config(arch)
        kind = "train" if shape.startswith("train") else (
            "prefill" if shape.startswith("prefill") else "decode")
        space = framework_space(cfg, kind)
        super().__init__(space, self.counter_names, seed=seed, pool_size=64)
        self.cache_dir = cache_dir or os.path.join(
            tempfile.gettempdir(), "repro_compiled_env")
        os.makedirs(self.cache_dir, exist_ok=True)

    def _cache_key(self, kv: str) -> str:
        safe = kv.replace("=", "-").replace(",", "_") or "default"
        return os.path.join(
            self.cache_dir,
            f"{self.arch}__{self.shape_name}__{safe}.json")

    def _measure(self, config) -> Tuple[Dict[str, float], float]:
        kv = config_to_parallel_kv(config)
        cache = self._cache_key(kv)
        if os.path.exists(cache):
            with open(cache) as f:
                rec = json.load(f)
        else:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", self.arch, "--shape", self.shape_name,
                   "--tag", "tuner"]
            if kv:
                cmd += ["--parallel", kv]
            if self.multi_pod:
                cmd += ["--multi-pod"]
            env = dict(os.environ, PYTHONPATH=_REPO_SRC)
            try:
                proc = subprocess.run(cmd, capture_output=True, text=True,
                                      timeout=self.timeout_s, env=env)
            except subprocess.TimeoutExpired:
                return {n: 0.0 for n in self.counter_names}, float("inf")
            if proc.returncode != 0:
                # invalid configuration (sharding/divisibility): infeasible
                return {n: 0.0 for n in self.counter_names}, float("inf")
            art = os.path.join(_REPO_SRC, "..", "artifacts", "dryrun",
                               f"{self.arch}__{self.shape_name}__"
                               f"{'multipod' if self.multi_pod else 'pod'}__tuner.json")
            with open(art) as f:
                rec = json.load(f)
            with open(cache, "w") as f:
                json.dump(rec, f)

        h = rec["hlo_analysis"]
        compute_s = h["flops_per_chip"] / self.hw.peak_flops_bf16
        memory_s = h["bytes_per_chip"] / self.hw.hbm_bandwidth
        coll_s = h["total_collective_bytes_per_chip"] / self.hw.ici_bandwidth
        peak_gb = (rec["memory_analysis"]["argument_bytes"]
                   + rec["memory_analysis"]["temp_bytes"]) / rec["chips"] / 2**30
        step = max(compute_s, memory_s, coll_s)  # no-overlap roofline bound
        counters = {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s,
            "flops_per_chip": h["flops_per_chip"],
            "hbm_bytes": h["bytes_per_chip"],
            "collective_bytes": h["total_collective_bytes_per_chip"],
            "peak_mem_gb": peak_gb,
        }
        if peak_gb > self.hw.hbm_capacity / 2**30:
            return counters, float("inf")
        return counters, float(step)
