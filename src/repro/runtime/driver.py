"""Fault-tolerant training driver.

The driver owns the full restart contract:

  1. on start (or after a fault) restore the latest durable checkpoint —
     params, optimizer state, step counter; the data pipeline needs no state
     because batches are addressed by step;
  2. run jitted train steps, checkpointing every ``checkpoint_every`` steps;
  3. on a step fault (device error, preemption, injected fault), tear down,
     restore, and continue — the loss trajectory is bit-identical to a run
     without the fault (verified in tests);
  4. feed the straggler monitor with per-host step times and surface
     flagged/excluded hosts to the caller (which may trigger elastic
     re-meshing via ``runtime.elastic``).

``FaultInjector`` deterministically raises at chosen steps so fault paths are
unit-testable on CPU.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLMData
from repro.runtime.straggler import StragglerMonitor
from repro.train.train_step import TrainState
from repro.utils.logging import MetricsLogger


class FaultInjector:
    """Raises RuntimeError at the given (1-indexed) global steps, once each."""

    def __init__(self, fault_steps: List[int]):
        self._pending = set(fault_steps)

    def check(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            raise RuntimeError(f"injected fault at step {step}")


class TrainDriver:
    def __init__(
        self,
        run,
        train_step: Callable[[TrainState, Dict], Any],
        init_state: Callable[[], TrainState],
        data: SyntheticLMData,
        ckpt: CheckpointManager,
        logger: Optional[MetricsLogger] = None,
        fault_injector: Optional[FaultInjector] = None,
        num_hosts: int = 1,
        max_restarts: int = 8,
    ):
        self.run = run
        self.train_step = train_step
        self.init_state = init_state
        self.data = data
        self.ckpt = ckpt
        # remember whether we created the logger: run_steps closes a
        # self-owned logger on exit (a caller-provided one stays open —
        # the caller's context manager owns its lifetime)
        self._owns_logger = logger is None
        self.logger = logger or MetricsLogger(name="driver")
        self.fault_injector = fault_injector
        self.straggler = StragglerMonitor(num_hosts)
        self.max_restarts = max_restarts
        self.restarts = 0

    # -- state bootstrap -----------------------------------------------------

    def _bootstrap(self) -> TrainState:
        latest = self.ckpt.latest_step()
        if latest is None:
            state = self.init_state()
            self.logger.log(0, event="init_fresh")
            return state
        template = jax.eval_shape(self.init_state)
        state = self.ckpt.restore(latest, template)
        self.logger.log(latest, event="restored")
        return state

    # -- main loop -----------------------------------------------------------

    def run_steps(self, total_steps: int) -> TrainState:
        try:
            while True:
                try:
                    return self._run_from_checkpoint(total_steps)
                except RuntimeError as e:
                    self.restarts += 1
                    if self.restarts > self.max_restarts:
                        raise
                    self.logger.log(-1, event="fault", error=str(e),
                                    restart=self.restarts)
                    # fall through: next iteration restores from latest
                    # durable ckpt
        finally:
            if self._owns_logger:
                self.logger.close()

    def _run_from_checkpoint(self, total_steps: int) -> TrainState:
        state = self._bootstrap()
        step = int(state.step)
        while step < total_steps:
            if self.fault_injector is not None:
                self.fault_injector.check(step + 1)
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(state.params)
            dt = time.perf_counter() - t0
            step = int(state.step)
            self.straggler.report({0: dt})
            if step % self.run.log_every == 0 or step == total_steps:
                self.logger.log(step, loss=float(metrics["loss"]),
                                grad_norm=float(metrics["grad_norm"]),
                                step_time_s=round(dt, 4))
            if step % self.run.checkpoint_every == 0 or step == total_steps:
                self.ckpt.save(step, state, extra={"step": step})
        self.ckpt.wait()
        return state
