"""Elastic re-meshing: continue a run on a different device count.

The checkpoint format is mesh-agnostic (host numpy per leaf), so scaling is:
build the new mesh, recompute the sharding rules for the same model under
the new mesh, and restore with the new shardings.  The only global-batch
constraint is divisibility by the new data-parallel size; the driver adjusts
microbatching to preserve the global batch (so the loss trajectory is
unchanged across the re-mesh, modulo data order).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.sharding.specs import named_shardings
from repro.utils.config import MeshConfig, RunConfig


def viable_mesh_shape(num_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for `num_devices` keeping TP degree."""
    if num_devices % model_parallel != 0:
        # degrade TP until it divides (prefer keeping TP large)
        while model_parallel > 1 and num_devices % model_parallel != 0:
            model_parallel //= 2
    return num_devices // model_parallel, model_parallel


def remesh_state(ckpt: CheckpointManager, step: int, state_template: Any,
                 run: RunConfig, new_mesh: Mesh) -> Any:
    """Restore checkpoint `step` resharded for `new_mesh`."""
    from repro.launch.mesh import state_shardings  # late: avoids import cycle

    shardings = state_shardings(state_template, run, new_mesh)
    return ckpt.restore(step, state_template, shardings=shardings)


def adjust_run_for_devices(run: RunConfig, num_devices: int) -> RunConfig:
    """Rescale the mesh (and microbatching if needed) to `num_devices`."""
    tp = run.parallel.tp
    data, model = viable_mesh_shape(num_devices, tp)
    mesh = MeshConfig(shape=(data, model), axes=("data", "model"))
    par = run.parallel
    if par.tp != model:
        par = par.replace(tp=model)
    # keep the global batch: if the new data size no longer divides it,
    # increase microbatching
    gb = run.shape.global_batch
    micro = par.microbatch
    while gb % (data * micro) != 0 and micro < gb:
        micro *= 2
    if micro != par.microbatch:
        par = par.replace(microbatch=micro)
    return run.replace(mesh=mesh, parallel=par)
