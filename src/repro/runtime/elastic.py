"""Elastic re-meshing: continue a run on a different device count.

The checkpoint format is mesh-agnostic (host numpy per leaf), so scaling is:
build the new mesh, recompute the sharding rules for the same model under
the new mesh, and restore with the new shardings.  The only global-batch
constraint is divisibility by the new data-parallel size; the driver adjusts
microbatching to preserve the global batch (so the loss trajectory is
unchanged across the re-mesh, modulo data order).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.sharding.specs import named_shardings
from repro.utils.config import MeshConfig, RunConfig


def viable_mesh_shape(num_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for `num_devices` keeping TP degree.

    When the requested TP does not divide the device count, degrade to the
    LARGEST divisor of ``num_devices`` that is <= the request (prefer keeping
    TP large) — halving skips valid divisors (8 devices at TP 6 would land on
    TP 1 when TP 4 is viable; 100 devices at TP 16 on TP 4 when TP 10 is).
    """
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    tp = max(1, min(int(model_parallel), num_devices))
    while num_devices % tp != 0:
        tp -= 1
    return num_devices // tp, tp


def remesh_state(ckpt: CheckpointManager, step: int, state_template: Any,
                 run: RunConfig, new_mesh: Mesh) -> Any:
    """Restore checkpoint `step` resharded for `new_mesh`."""
    from repro.launch.mesh import state_shardings  # late: avoids import cycle

    shardings = state_shardings(state_template, run, new_mesh)
    return ckpt.restore(step, state_template, shardings=shardings)


def adjust_run_for_devices(run: RunConfig, num_devices: int) -> RunConfig:
    """Rescale the mesh (and microbatching if needed) to `num_devices`."""
    tp = run.parallel.tp
    data, model = viable_mesh_shape(num_devices, tp)
    mesh = MeshConfig(shape=(data, model), axes=("data", "model"))
    par = run.parallel
    if par.tp != model:
        par = par.replace(tp=model)
    # keep the global batch: if the new data size no longer divides it,
    # increase microbatching
    gb = run.shape.global_batch
    micro = par.microbatch
    while gb % (data * micro) != 0 and micro < gb:
        micro *= 2
    if gb % (data * micro) != 0:
        # doubling can walk past every valid microbatch (e.g. data=3,
        # global_batch=32): surface it instead of returning a RunConfig
        # whose validate() would reject the batch split
        raise ValueError(
            f"cannot preserve global_batch={gb} on {num_devices} devices: "
            f"no power-of-two microbatch makes it divisible by "
            f"data={data} x microbatch")
    if micro != par.microbatch:
        par = par.replace(microbatch=micro)
    return run.replace(mesh=mesh, parallel=par)
