"""Straggler detection for multi-host training and fleet serving.

Each host reports its per-step wall time; the monitor keeps an EWMA per host
and flags hosts whose smoothed time exceeds ``threshold`` x the fleet median.
On a real deployment the report is an all-gather of scalars (microseconds of
overhead); here the same logic is driven by the driver loop / the fleet
serving simulator (``repro.workloads.sim.FleetSimulator``) / tests.

Reports may be PARTIAL: a host that did no work this step (an idle serving
replica, a host mid-restart) is simply absent from ``step_times``.  Seeding
is therefore per-host — the first report *from that host* seeds its EWMA —
and the fleet median is computed only over hosts that have reported at
least once, so silent hosts neither drag the median toward zero nor get
spuriously flagged.

Mitigation hooks:
- ``flagged()`` — hosts to alert on / drain,
- ``should_exclude(host)`` — persistent stragglers (flagged ``patience``
  consecutive checks) that elastic re-meshing should drop (see
  ``runtime.elastic``).
"""

from __future__ import annotations

from typing import Dict, List


class StragglerMonitor:
    def __init__(self, num_hosts: int, alpha: float = 0.2,
                 threshold: float = 1.5, patience: int = 3):
        self.num_hosts = num_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self._ewma: List[float] = [0.0] * num_hosts
        self._seen: List[bool] = [False] * num_hosts
        self._flag_streak: List[int] = [0] * num_hosts

    def report(self, step_times: Dict[int, float]) -> None:
        """step_times: host_id -> seconds for this step (hosts that did no
        work this step are absent — a late joiner's first report seeds its
        EWMA instead of being blended from 0.0)."""
        for h, t in step_times.items():
            if not self._seen[h]:
                self._ewma[h] = t
                self._seen[h] = True
            else:
                self._ewma[h] = (1 - self.alpha) * self._ewma[h] + self.alpha * t
        med = self._median()
        for h in range(self.num_hosts):
            if (self._seen[h] and med > 0
                    and self._ewma[h] > self.threshold * med):
                self._flag_streak[h] += 1
            else:
                self._flag_streak[h] = 0

    def _median(self) -> float:
        """Median EWMA over hosts with at least one report (0.0 before any
        report) — never-reporting hosts hold EWMA 0.0 and would otherwise
        bias the fleet median down, flagging healthy hosts."""
        xs = sorted(e for e, seen in zip(self._ewma, self._seen) if seen)
        n = len(xs)
        if n == 0:
            return 0.0
        return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])

    def flagged(self) -> List[int]:
        return [h for h in range(self.num_hosts) if self._flag_streak[h] >= 1]

    def should_exclude(self, host: int) -> bool:
        return self._flag_streak[host] >= self.patience

    def excluded(self) -> List[int]:
        return [h for h in range(self.num_hosts) if self.should_exclude(h)]
