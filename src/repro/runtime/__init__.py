from repro.runtime.driver import TrainDriver, FaultInjector  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import remesh_state  # noqa: F401
