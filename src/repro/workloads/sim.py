"""Deterministic discrete-event simulator of the continuous batcher.

The real :class:`repro.serving.scheduler.ContinuousBatcher` keeps a fixed
number of decode slots, admits queued requests into free slots, and runs one
fused decode step per tick.  This module replays that control loop against
the analytic per-kernel cost model (:class:`repro.envs.measure.
LaunchGeometry`), so the full serving stack — scheduler knobs AND kernel
launch geometry — is priceable in microseconds of modeled time on CPU CI:

- one admission costs the modeled prefill of that prompt at batch 1;
- one decode tick costs the modeled cost of the compiled decode shape
  ``(num_slots, cache_len)`` amortized per token — the compiled program runs
  at full batch whether slots are occupied or not, exactly like the real
  batcher;
- the VMEM feasibility gate of the launch space carries over, and a plan
  whose ``cache_len`` cannot hold every request of the trace is infeasible
  (you cannot deploy a cache too small for the workload).

The simulator is pure and seeded by its inputs: the same (trace, plan,
config) triple always yields the identical :class:`SimReport`.
"""

from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.spaces import ConfigSpace, Option
from repro.envs import measure as measure_mod
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.envs.measure import (HardwareSpec, KernelWorkload, LaunchGeometry,
                                family_params)
from repro.serving.paging import PAGES_OPTIONS, PagedPlan
from repro.serving.scheduler import DrainStall
from repro.workloads.traces import Trace

SERVING_PREFIX = "serving."

#: The scheduler's tunable surface.  ``family.param`` launch options join it
#: in :func:`serving_space` — together they are the serving stack CAMEO tunes.
SCHEDULER_OPTIONS: Tuple[Option, ...] = (
    Option("serving.num_slots", (2, 4, 8, 16), default=8),
    Option("serving.admit_chunk", (1, 2, 4, 8), default=4),
    Option("serving.cache_len", (128, 256, 512, 1024, 2048), default=512),
    Option("serving.interleave", ("eager", "drain"), default="eager",
           kind="categorical"),
)


FLEET_PREFIX = "fleet."

#: selectable router policies of the fleet front-end
ROUTING_POLICIES: Tuple[str, ...] = (
    "round_robin", "join_shortest_queue", "power_of_two")

#: The fleet's tunable surface: replica count, routing policy, and the
#: per-replica data-vs-model mesh split (resolved through
#: ``runtime.elastic.viable_mesh_shape``).  Joined into :func:`serving_space`
#: with ``fleet=True``.
FLEET_OPTIONS: Tuple[Option, ...] = (
    Option("fleet.num_replicas", (1, 2, 4, 8), default=2),
    Option("fleet.routing", ROUTING_POLICIES, default="round_robin",
           kind="categorical"),
    Option("fleet.model_parallel", (1, 2, 4), default=1),
)


def serving_space(families: Optional[Iterable[str]] = None, *,
                  fleet: bool = False) -> ConfigSpace:
    """Scheduler options joined with the kernel-launch space — one flat
    ``ConfigSpace`` (``serving.*`` + ``family.param`` keys).  With
    ``fleet=True`` the router/replica knobs (``fleet.*`` keys) join too.
    When the served model dispatches the ``paged_attention`` family, the
    scheduler-level paging knobs (``pages.*``) join as well — the kernel-level
    paging knobs (page size, pages per slot, prefill chunk) already ride in
    via ``dispatch.launch_space``."""
    from repro.kernels import dispatch

    options = list(SCHEDULER_OPTIONS)
    if fleet:
        options += list(FLEET_OPTIONS)
    fams = sorted(families) if families is not None else dispatch.families()
    if "paged_attention" in fams:
        options += list(PAGES_OPTIONS)
    return ConfigSpace(options + list(dispatch.launch_space(fams).options))


@dataclass(frozen=True)
class ServingPlan:
    """The scheduler half of a serving configuration."""

    num_slots: int = 8
    admit_chunk: int = 4
    cache_len: int = 512
    interleave: str = "eager"        # eager: admit every tick; drain: only
                                     # refill once the resident batch empties

    def __post_init__(self):
        if self.num_slots < 1 or self.admit_chunk < 1 or self.cache_len < 1:
            raise ValueError(f"malformed serving plan {self}")
        if self.interleave not in ("eager", "drain"):
            raise ValueError(
                f"unknown interleave policy {self.interleave!r}; "
                f"known: ['drain', 'eager']")

    @classmethod
    def from_config(cls, config: Dict[str, Any]) -> "ServingPlan":
        """Extract the ``serving.*`` keys of a flat tuner configuration,
        defaulting anything unspecified."""
        kw = {}
        for f in dataclasses.fields(cls):
            key = SERVING_PREFIX + f.name
            if key in config:
                v = config[key]
                kw[f.name] = v if f.name == "interleave" else int(v)
        return cls(**kw)


@dataclass(frozen=True)
class SimReport:
    """Counters from one simulated trace run (modeled time in us)."""

    feasible: bool
    reason: str                      # "" when feasible
    completed: int
    ticks: int
    makespan_us: float
    queue_depth_mean: float
    queue_depth_max: float
    occupancy_mean: float
    prefill_us: float
    decode_us: float
    p50_latency_us: float
    p99_latency_us: float
    mean_latency_us: float
    throughput_rps: float            # completed requests / modeled second
    tokens_per_s: float
    slo_violation_rate: float
    # paged-KV mediators (all 0.0 on the dense path, so pre-paging reports
    # and the infeasible sentinel stay field-compatible)
    page_pool_occupancy: float = 0.0   # mean used-pages / pool per tick
    page_faults: float = 0.0           # pool-exhaustion evictions
    prefill_chunks_inflight: float = 0.0  # mean inflight prefills per tick

    @property
    def prefill_decode_ratio(self) -> float:
        return self.prefill_us / max(self.decode_us, 1e-9)

    def counters(self) -> Dict[str, float]:
        """The measurement's metrics dict.  ``latency`` (p99) and
        ``throughput`` use the query engine's metric names so constrained
        queries ("... for which latency is less than X") bind directly —
        but they are NOT in :data:`SIM_COUNTER_NAMES`: each is (a copy of)
        an objective, and admitting an objective clone into the causal
        graph lets the CI machinery condition it away from the config
        options, collapsing the ACE ranking."""
        return {
            "queue_depth_mean": self.queue_depth_mean,
            "queue_depth_max": self.queue_depth_max,
            "occupancy_mean": self.occupancy_mean,
            "prefill_decode_ratio": self.prefill_decode_ratio,
            "latency": self.p99_latency_us,
            "throughput": self.throughput_rps,
            "slo_violation_rate": self.slo_violation_rate,
            "page_pool_occupancy": self.page_pool_occupancy,
            "page_faults": self.page_faults,
            "prefill_chunks_inflight": self.prefill_chunks_inflight,
        }


# The system events C used for causal discovery: genuine mediators between
# configuration and objective (queueing, occupancy, prefill/decode mix, and
# — with paging on — pool pressure and chunked-prefill interleaving).
# Declared in the obs metrics registry — the single source of truth sim,
# fleet, and replay all derive their counter-name tuples from — in the
# "serving" group; declaration order IS discovery-matrix column order.
obs_metrics.declare("queue_depth_mean", group="serving",
                    help="mean waiting-queue depth per tick")
obs_metrics.declare("queue_depth_max", group="serving",
                    help="max waiting-queue depth over the run")
obs_metrics.declare("occupancy_mean", group="serving",
                    help="mean seated-slot occupancy per tick")
obs_metrics.declare("prefill_decode_ratio", group="serving",
                    help="prefill time / decode time over the run")
obs_metrics.declare("slo_violation_rate", group="serving",
                    help="fraction of requests whose latency missed the SLO")
obs_metrics.declare("page_pool_occupancy", group="serving",
                    help="mean used-pages / pool per tick (paged KV)")
obs_metrics.declare("page_faults", group="serving", kind="counter",
                    help="pool-exhaustion evictions (paged KV)")
obs_metrics.declare("prefill_chunks_inflight", group="serving",
                    help="mean inflight chunked prefills per tick")
# objective clones: present in counters() so constrained queries bind, but
# discovery=False keeps them out of the causal graph's variable set
obs_metrics.declare("latency", group="serving", discovery=False,
                    help="p99 latency objective clone", unit="us")
obs_metrics.declare("throughput", group="serving", discovery=False,
                    help="throughput objective clone", unit="rps")

SIM_COUNTER_NAMES: Tuple[str, ...] = obs_metrics.discovery_names("serving")


def _infeasible(reason: str, n_requests: int) -> SimReport:
    return SimReport(feasible=False, reason=reason, completed=0, ticks=0,
                     makespan_us=0.0, queue_depth_mean=float(n_requests),
                     queue_depth_max=float(n_requests), occupancy_mean=0.0,
                     prefill_us=0.0, decode_us=0.0, p50_latency_us=0.0,
                     p99_latency_us=0.0, mean_latency_us=0.0,
                     throughput_rps=0.0, tokens_per_s=0.0,
                     slo_violation_rate=1.0)


class ServingSimulator:
    """Prices a (trace, plan, launch config) triple in modeled microseconds.

    ``cell`` fixes the model dimensions (heads, head_dim, d_model, ...); its
    batch/seq fields are overridden per event by the serving shapes the plan
    implies.  ``families`` are the kernel families the served model
    dispatches — their launch parameters (``family.param`` keys of the
    config) steer every prefill/decode price through the same
    :class:`LaunchGeometry` the kernel-launch environment uses.
    """

    def __init__(self, cell: KernelWorkload, families: Iterable[str], *,
                 hardware: Optional[HardwareSpec] = None,
                 slo_us: float = 2_000.0, max_ticks: int = 200_000):
        self.cell = cell
        self.families = tuple(sorted(families))
        measure_mod._check_modeled(self.families)
        self.hardware = hardware or HardwareSpec()
        self.slo_us = float(slo_us)
        self.max_ticks = int(max_ticks)
        self._cost_cache: Dict[Tuple, Tuple[float, bool]] = {}

    # -- pricing --------------------------------------------------------

    def _shape_cost(self, batch: int, seq_len: int, config: Dict[str, Any],
                    families: Optional[Tuple[str, ...]] = None
                    ) -> Tuple[float, bool]:
        """(modeled us, vmem-feasible) of one launch at (batch, seq_len)."""
        fams = self.families if families is None else families
        key = (fams, batch, seq_len,
               tuple(sorted((k, v) for k, v in config.items() if "." in k)))
        if key not in self._cost_cache:
            w = dataclasses.replace(self.cell, batch=batch, seq_len=seq_len)
            geo = LaunchGeometry(w, self.hardware)
            _, t, feasible = geo.totals(fams, config)
            self._cost_cache[key] = (t, feasible)
        return self._cost_cache[key]

    def _step_families(self, paged_step: bool) -> Tuple[str, ...]:
        """The families one serving step actually launches.  Attention is
        either the dense flash decode OR the paged-pool kernel, never both:
        a dense step (and every prefill — the paged kernel is decode-only)
        drops ``paged_attention``; a paged decode step drops
        ``flash_attention``.  An env without ``paged_attention`` in its
        family set is unaffected, so legacy pricing is bit-identical."""
        if "paged_attention" not in self.families:
            return self.families
        drop = "flash_attention" if paged_step else "paged_attention"
        return tuple(f for f in self.families if f != drop)

    def prefill_us(self, prompt_len: int, plan: ServingPlan,
                   config: Dict[str, Any]) -> Tuple[float, bool]:
        return self._shape_cost(1, max(int(prompt_len), 1), config,
                                self._step_families(paged_step=False))

    def decode_tick_us(self, plan: ServingPlan,
                       config: Dict[str, Any]) -> Tuple[float, bool]:
        """One fused decode step at the compiled shape, amortized per cache
        token: the batch runs at ``num_slots`` whatever the occupancy."""
        t, feasible = self._shape_cost(plan.num_slots, plan.cache_len, config,
                                       self._step_families(paged_step=False))
        return t / plan.cache_len, feasible

    def paged_decode_tick_us(self, plan: ServingPlan, paged: PagedPlan,
                             ctx_tokens: int, config: Dict[str, Any]
                             ) -> Tuple[float, bool]:
        """One paged decode tick, priced at the page-quantized context the
        resident batch actually occupies (the paged kernel skips pages past
        the live span wholesale, so the attended span — not a static
        ``cache_len`` — is what costs).  Priced over the step's real family
        set: the paged kernel replaces the dense flash decode, it does not
        run alongside it, so ``flash_attention`` is dropped here exactly as
        ``paged_attention`` is dropped from dense ticks and prefills.  The
        paged model is linear in context (one query token per slot) where
        the amortized dense tick carries the quadratic relaunch — that gap,
        plus paying the page-quantized span instead of the provisioned
        ``cache_len``, is the modeled paging win."""
        ctx = paged.pages_for(ctx_tokens) * paged.page_size
        t, feasible = self._shape_cost(plan.num_slots, ctx, config,
                                       self._step_families(paged_step=True))
        return t / ctx, feasible

    def resolved_launch(self, config: Dict[str, Any]
                        ) -> Dict[str, Dict[str, Any]]:
        """The launch parameters every price in this run derives from — the
        simulator-side audit mirroring ``dispatch.record_resolutions``."""
        return {f: family_params(f, config) for f in self.families}

    # -- the event loop -------------------------------------------------

    def capacity_reason(self, trace: Trace, plan: ServingPlan,
                        paged: PagedPlan) -> str:
        """"" when every request of the trace fits the deployed cache shape;
        the infeasibility reason otherwise.  Shared with the replay
        environment so the analytic gate and the real deployment agree."""
        if paged.paging:
            if (trace.max_context > paged.slot_capacity
                    or paged.pages_for(trace.max_context) > paged.pool_pages):
                return "pages"
        elif trace.max_context > plan.cache_len:
            return "cache_len"
        return ""

    def run(self, trace: Trace, plan: ServingPlan,
            config: Optional[Dict[str, Any]] = None,
            paged: Optional[PagedPlan] = None) -> SimReport:
        """Drive ONE :class:`_FleetReplica` through the trace — the same
        stepper the fleet loop drives N of, so the scheduler iteration
        (admission, paging, chunked prefill, decode tick) exists exactly
        once.  ``paged`` defaults to ``PagedPlan.from_config(config)``:
        a config with no ``pages.*`` keys resolves to the dense reference."""
        config = config or {}
        if paged is None:
            paged = PagedPlan.from_config(config)
        n = len(trace.requests)
        if n == 0:
            raise ValueError("cannot simulate an empty trace")
        reason = self.capacity_reason(trace, plan, paged)
        if reason:
            return _infeasible(reason, n)
        decode_us, feasible = self.decode_tick_us(plan, config)
        if not feasible:
            return _infeasible("vmem", n)

        reqs = trace.requests
        rep = _FleetReplica(self, plan, config, reqs, decode_us, paged=paged,
                            stall_label="serving simulation", stall_total=n)
        for k, req in enumerate(reqs):
            a_us = req.arrival_s * 1e6
            if not rep.advance_until(a_us):
                return _infeasible(rep.infeasible_reason, n)
            rep.enqueue(k, a_us)
        if not rep.drain():
            return _infeasible(rep.infeasible_reason, n)

        done = sorted(rep.completed)       # request-index order
        lat = np.array([l for _, l in done], np.float64)
        has_lat = lat.size > 0
        makespan = max(rep.clock - reqs[0].arrival_s * 1e6, 1e-9)
        ticks = rep.ticks
        return SimReport(
            feasible=True, reason="", completed=n, ticks=ticks,
            makespan_us=makespan,
            queue_depth_mean=rep.qd_sum / max(ticks, 1),
            queue_depth_max=rep.qd_max,
            occupancy_mean=rep.occ_sum / max(ticks, 1),
            prefill_us=rep.prefill_total, decode_us=rep.decode_total,
            p50_latency_us=float(np.percentile(lat, 50)) if has_lat else 0.0,
            p99_latency_us=float(np.percentile(lat, 99)) if has_lat else 0.0,
            mean_latency_us=float(lat.mean()) if has_lat else 0.0,
            throughput_rps=n / (makespan * 1e-6),
            tokens_per_s=rep.tokens / (makespan * 1e-6),
            slo_violation_rate=(float((lat > self.slo_us).mean())
                                if has_lat else 0.0),
            page_pool_occupancy=rep.pool_occ_sum / max(ticks, 1),
            page_faults=float(rep.page_faults),
            prefill_chunks_inflight=rep.chunks_inflight_sum / max(ticks, 1))


# --------------------------------------------------------------------------
# fleet: N replica batchers behind a router
# --------------------------------------------------------------------------

#: modeled strong-scaling exponent of tensor parallelism: TP over ``m``
#: devices speeds one replica's kernels by ``m ** TP_ALPHA`` (sub-linear —
#: collectives and launch overhead eat the rest), so replica count vs TP
#: degree is a genuine trade-off the tuner has to resolve per workload
TP_ALPHA = 0.75


def tp_speedup(model_parallel: int) -> float:
    return float(model_parallel) ** TP_ALPHA


@dataclass(frozen=True)
class FleetPlan:
    """The router/replica half of a fleet serving configuration."""

    num_replicas: int = 2
    routing: str = "round_robin"
    model_parallel: int = 1

    def __post_init__(self):
        if self.num_replicas < 1 or self.model_parallel < 1:
            raise ValueError(f"malformed fleet plan {self}")
        if self.routing not in ROUTING_POLICIES:
            raise ValueError(
                f"unknown routing policy {self.routing!r}; "
                f"known: {sorted(ROUTING_POLICIES)}")

    @classmethod
    def from_config(cls, config: Dict[str, Any]) -> "FleetPlan":
        """Extract the ``fleet.*`` keys of a flat tuner configuration,
        defaulting anything unspecified."""
        kw = {}
        for f in dataclasses.fields(cls):
            key = FLEET_PREFIX + f.name
            if key in config:
                v = config[key]
                kw[f.name] = v if f.name == "routing" else int(v)
        return cls(**kw)


@dataclass(frozen=True)
class FleetSpec:
    """The deployment substrate a fleet runs on: how many devices exist and
    which of them straggle.  This is ENVIRONMENT state (what a shift
    perturbs), not a tunable — the tuner picks how to carve the devices into
    replicas, the spec says what it has to carve."""

    num_devices: int = 8
    slow_devices: Tuple[int, ...] = ()
    slowdown: float = 1.0            # slow devices run at 1/slowdown rate

    def __post_init__(self):
        if self.num_devices < 1 or self.slowdown < 1.0:
            raise ValueError(f"malformed fleet spec {self}")
        if any(d < 0 or d >= self.num_devices for d in self.slow_devices):
            raise ValueError(
                f"slow_devices {self.slow_devices} out of range for "
                f"{self.num_devices} devices")


@dataclass(frozen=True)
class FleetReport(SimReport):
    """Pooled counters of one fleet run plus the router/replica view.

    The three fleet-level counters (``routing_imbalance``,
    ``replica_queue_depth_max``, ``straggler_flagged``) are genuine
    mediators — router decisions and fleet health between configuration and
    objective — so they join :data:`FLEET_COUNTER_NAMES`; the
    latency/throughput objective clones stay excluded exactly as in
    :data:`SIM_COUNTER_NAMES`."""

    num_replicas: int = 1
    routing: str = "round_robin"
    data_parallel: int = 1
    model_parallel: int = 1
    assignments: Tuple[Tuple[int, ...], ...] = ()  # request idx per replica
    replica_ticks: Tuple[int, ...] = ()
    replica_wall_us: Tuple[float, ...] = ()
    routing_imbalance: float = 1.0   # max replica load / perfectly-even load
    replica_queue_depth_max: float = 0.0  # chosen replica backlog at routing
    straggler_flagged: int = 0
    straggler_excluded: Tuple[int, ...] = ()

    def counters(self) -> Dict[str, float]:
        c = super().counters()
        c["routing_imbalance"] = self.routing_imbalance
        c["replica_queue_depth_max"] = self.replica_queue_depth_max
        c["straggler_flagged"] = float(self.straggler_flagged)
        return c


# Fleet causal-discovery counters: the single-sim mediators plus the
# router/straggler mediators, registered as their own "fleet" group so every
# fleet-shaped surface (sim fleet, replay fleet) composes the same trio —
# and, as with SIM_COUNTER_NAMES, none of the objective-metric copies that
# :meth:`SimReport.counters` also carries.
obs_metrics.declare("routing_imbalance", group="fleet",
                    help="max replica load / perfectly-even load")
obs_metrics.declare("replica_queue_depth_max", group="fleet",
                    help="chosen-replica backlog at routing time")
obs_metrics.declare("straggler_flagged", group="fleet", kind="counter",
                    help="replicas flagged straggling during the run")

FLEET_COUNTER_NAMES: Tuple[str, ...] = obs_metrics.discovery_names(
    "serving", "fleet")


def _fleet_infeasible(reason: str, n_requests: int,
                      fleet_plan: "FleetPlan") -> FleetReport:
    base = dataclasses.asdict(_infeasible(reason, n_requests))
    return FleetReport(**base, num_replicas=fleet_plan.num_replicas,
                       routing=fleet_plan.routing,
                       model_parallel=fleet_plan.model_parallel,
                       replica_queue_depth_max=float(n_requests))


def stalled_report(n_requests: int, fleet_plan: "Optional[FleetPlan]" = None):
    """The report for a deployment that could not drain its trace within the
    tick budget (a :class:`DrainStall` escaped the event loop) — priced
    infeasible, single-sim or fleet shaped.  Public so the serving
    environments can catch the stall and keep the tuning run alive."""
    if fleet_plan is not None:
        return _fleet_infeasible("stall", n_requests, fleet_plan)
    return _infeasible("stall", n_requests)


class _FleetReplica:
    """One replica's batcher state — THE scheduler loop of the simulator.

    ``_step`` is the single implementation of the continuous-batching
    iteration (admit under the interleave policy, then one decode tick):
    :meth:`ServingSimulator.run` drives one instance and
    :class:`FleetSimulator` drives N, so the paging/chunking logic exists
    exactly once and a 1-replica fleet stays bit-identical to the single
    simulator — the regression test this stepper is held to.

    With a paging :class:`PagedPlan`, resident slots carry
    ``[request_idx, remaining, ctx_tokens, pages_held]`` against a shared
    page pool: prompt pages are allocated at admission (admission defers
    while the pool is short), one page is allocated per page-boundary
    crossing during decode, and pool exhaustion is a **page fault** resolved
    by evicting the youngest resident (the faulter itself when it is the
    youngest) back to the queue head — the oldest resident is never evicted,
    so decode always progresses.  ``prefill_chunk > 0`` additionally splits
    admission prefill into chunks, one per scheduler step, with the resident
    batch decoding underneath (no head-of-line blocking on long prompts).
    """

    def __init__(self, sim: ServingSimulator, plan: ServingPlan,
                 config: Dict[str, Any], reqs, decode_us: float, *,
                 paged: Optional[PagedPlan] = None,
                 stall_label: str = "fleet replica",
                 stall_total: Optional[int] = None,
                 trace_tid: int = 0):
        self.sim = sim
        self.plan = plan
        self.config = config
        self.reqs = reqs
        self.decode_us = decode_us
        self.paged = paged if (paged is not None and paged.paging) else None
        self.stall_label = stall_label
        self.stall_total = stall_total
        self.queue: List[int] = []
        self.resident: List[List] = []  # [idx, remaining, ctx, pages]
        self.clock = 0.0
        self.ticks = 0
        self.qd_sum = self.qd_max = self.occ_sum = 0.0
        self.prefill_total = self.decode_total = 0.0
        self.tokens = 0
        self.assigned: List[int] = []
        self.completed: List[Tuple[int, float]] = []  # (req idx, latency us)
        self.infeasible_reason = ""
        # paged pool state (inert on the dense path)
        self.free_pages = self.paged.pool_pages if self.paged else 0
        self.page_faults = 0
        self.pool_occ_sum = 0.0          # used/pool sampled per decode tick
        self.chunks_inflight_sum = 0.0   # inflight prefills per decode tick
        self.prefilling: Optional[List[int]] = None  # [idx, done_tokens, pages]
        # modeled-time tracing: the simulator track's thread id (replica
        # index in a fleet) and the per-request admit clocks — populated
        # only while a tracer is active, so the untraced run is untouched
        self.trace_tid = trace_tid
        self._admit_clock: Dict[int, float] = {}

    @property
    def backlog(self) -> int:
        """Queued + resident requests — what the router load-balances on."""
        return (len(self.queue) + len(self.resident)
                + (1 if self.prefilling is not None else 0))

    @property
    def busy(self) -> bool:
        return bool(self.queue or self.resident
                    or self.prefilling is not None)

    def enqueue(self, idx: int, arrival_us: float) -> None:
        if not self.busy:
            # idle replica: jump its clock to the arrival, mirroring the
            # single simulator's idle fast-forward
            self.clock = max(self.clock, arrival_us)
        tr = obs_trace.active()
        if tr is not None:
            tr.async_begin("sim_request", self.reqs[idx].uid,
                           cat="sim_request", track=obs_trace.TRACK_SIM,
                           ts_us=arrival_us, replica=self.trace_tid,
                           prompt_len=self.reqs[idx].prompt_len,
                           output_len=self.reqs[idx].output_len)
        self.queue.append(idx)
        self.assigned.append(idx)

    # -- paging ---------------------------------------------------------

    def _evict(self, slot: List) -> None:
        """Preempt a resident: free its pages, re-queue it at the head.  It
        restarts from scratch on re-admission — the tokens it already
        emitted are recompute, which is exactly the cost a fault carries."""
        self.free_pages += slot[3]
        self.resident.remove(slot)
        self.queue.insert(0, slot[0])

    def _grow_pages(self) -> None:
        """Allocate the +1-token page growth of every resident, faulting
        (evict the youngest) when the pool runs dry."""
        paged = self.paged
        for slot in list(self.resident):
            if slot not in self.resident:
                continue               # evicted by an earlier fault
            need = paged.pages_for(slot[2] + 1)
            while need > slot[3]:
                if self.free_pages > 0:
                    self.free_pages -= 1
                    slot[3] += 1
                    continue
                self.page_faults += 1
                victim = self.resident[-1]  # youngest; may be `slot` itself
                self._evict(victim)
                if victim is slot:
                    break

    def _finish_prefill(self, idx: int, pages: int) -> None:
        """Prompt fully prefilled: emit the first token; retire or seat."""
        reqs = self.reqs
        self.tokens += 1               # prefill emits the first token
        if reqs[idx].output_len <= 1:
            self.completed.append(
                (idx, self.clock - reqs[idx].arrival_s * 1e6))
            self.free_pages += pages   # no-op on the dense path (pages=0)
            self._trace_retire(idx)
        else:
            tr = obs_trace.active()
            if tr is not None:
                self._admit_clock[idx] = self.clock
            self.resident.append(
                [idx, reqs[idx].output_len - 1, reqs[idx].prompt_len, pages])

    def _admit(self) -> bool:
        """The admission half of one scheduler step."""
        plan, reqs, paged = self.plan, self.reqs, self.paged
        chunked = paged is not None and paged.prefill_chunk > 0
        if chunked:
            if (self.prefilling is None and self.queue
                    and (plan.interleave == "eager" or not self.resident)
                    and len(self.resident) < plan.num_slots):
                idx = self.queue[0]
                need = paged.pages_for(reqs[idx].prompt_len)
                if need <= self.free_pages:
                    self.queue.pop(0)
                    self.free_pages -= need
                    self.prefilling = [idx, 0, need]
            if self.prefilling is not None:
                # one chunk per step; residents decode underneath
                idx, done, pages = self.prefilling
                step = min(paged.prefill_chunk, reqs[idx].prompt_len - done)
                t_pref, feasible = self.sim.prefill_us(step, plan, self.config)
                if not feasible:
                    self.infeasible_reason = "vmem"
                    return False
                self.clock += t_pref
                self.prefill_total += t_pref
                tr = obs_trace.active()
                if tr is not None:
                    tr.complete("prefill_chunk", self.clock - t_pref, t_pref,
                                cat="sim_request", track=obs_trace.TRACK_SIM,
                                tid=self.trace_tid, uid=reqs[idx].uid,
                                done=done + step)
                done += step
                if done >= reqs[idx].prompt_len:
                    self.prefilling = None
                    self._finish_prefill(idx, pages)
                else:
                    self.prefilling = [idx, done, pages]
            return True
        if self.queue and (plan.interleave == "eager" or not self.resident):
            admit = min(plan.admit_chunk, plan.num_slots - len(self.resident),
                        len(self.queue))
            for _ in range(admit):
                need = 0
                if paged is not None:
                    need = paged.pages_for(reqs[self.queue[0]].prompt_len)
                    if need > self.free_pages:
                        break          # defer until residents free pages
                idx = self.queue.pop(0)
                t_pref, feasible = self.sim.prefill_us(
                    reqs[idx].prompt_len, plan, self.config)
                if not feasible:
                    self.infeasible_reason = "vmem"
                    return False
                self.clock += t_pref
                self.prefill_total += t_pref
                tr = obs_trace.active()
                if tr is not None:
                    arrival = reqs[idx].arrival_s * 1e6
                    start = self.clock - t_pref
                    tr.complete("queue", arrival, max(start - arrival, 0.0),
                                cat="sim_request", track=obs_trace.TRACK_SIM,
                                tid=self.trace_tid, uid=reqs[idx].uid)
                    tr.complete("prefill", start, t_pref, cat="sim_request",
                                track=obs_trace.TRACK_SIM, tid=self.trace_tid,
                                uid=reqs[idx].uid,
                                prompt_len=reqs[idx].prompt_len)
                self.free_pages -= need
                self._finish_prefill(idx, need)
        return True

    def _step(self) -> bool:
        """One scheduler iteration; False on a vmem-infeasible launch."""
        reqs, paged = self.reqs, self.paged
        if not self._admit():
            return False
        if self.resident:
            if self.ticks >= self.sim.max_ticks:
                total = (self.stall_total if self.stall_total is not None
                         else len(self.assigned))
                noun = ("requests" if self.stall_total is not None
                        else "assigned requests")
                raise DrainStall(
                    f"{self.stall_label} exceeded {self.sim.max_ticks} ticks "
                    f"({len(self.completed)}/{total} {noun} completed)",
                    completed=len(self.completed),
                    pending=total - len(self.completed))
            self.ticks += 1
            if paged is not None:
                self._grow_pages()
                for slot in self.resident:
                    slot[2] += 1       # the new token joins the cache
                ctx = max(slot[2] for slot in self.resident)
                d_us, feasible = self.sim.paged_decode_tick_us(
                    self.plan, paged, ctx, self.config)
                if not feasible:
                    self.infeasible_reason = "vmem"
                    return False
                self.pool_occ_sum += ((paged.pool_pages - self.free_pages)
                                      / paged.pool_pages)
                self.chunks_inflight_sum += (
                    1.0 if self.prefilling is not None else 0.0)
            else:
                d_us = self.decode_us
            self.clock += d_us
            self.decode_total += d_us
            self.occ_sum += len(self.resident)
            self.qd_sum += len(self.queue)
            self.qd_max = max(self.qd_max, float(len(self.queue)))
            self.tokens += len(self.resident)
            for slot in list(self.resident):
                slot[1] -= 1
                if slot[1] == 0:
                    idx = slot[0]
                    self.completed.append(
                        (idx, self.clock - reqs[idx].arrival_s * 1e6))
                    self.resident.remove(slot)
                    self.free_pages += slot[3]
                    self._trace_retire(idx)
        return True

    def _trace_retire(self, idx: int) -> None:
        """Close a request's modeled-time lifecycle: a decode span from
        admission to retirement, then the async end (no-op untraced)."""
        tr = obs_trace.active()
        if tr is None:
            return
        uid = self.reqs[idx].uid
        admit = self._admit_clock.pop(idx, None)
        if admit is not None:
            tr.complete("decode_resident", admit, self.clock - admit,
                        cat="sim_request", track=obs_trace.TRACK_SIM,
                        tid=self.trace_tid, uid=uid)
        tr.async_end("sim_request", uid, cat="sim_request",
                     track=obs_trace.TRACK_SIM, ts_us=self.clock,
                     latency_us=self.clock - self.reqs[idx].arrival_s * 1e6)

    def advance_until(self, t_us: float) -> bool:
        """Run scheduler iterations until the replica clock reaches ``t_us``
        or the replica drains idle — the fleet loop calls this before every
        routing decision so backlogs reflect the state at arrival time."""
        while self.busy and self.clock < t_us:
            if not self._step():
                return False
        return True

    def drain(self) -> bool:
        while self.busy:
            if not self._step():
                return False
        return True


class FleetSimulator:
    """Prices a (trace, plan, fleet plan, launch config) quadruple.

    ``fleet`` (a :class:`FleetSpec`) fixes the deployment substrate; the
    :class:`FleetPlan` carves it: ``num_devices // num_replicas`` devices per
    replica, split data-vs-model by ``runtime.elastic.viable_mesh_shape``,
    with each replica's kernels priced through its own
    :class:`ServingSimulator` whose hardware is scaled by the TP speedup and
    (for replicas whose device block contains a slow device) the straggler
    slowdown.  Arrivals are processed in global time order: every replica is
    advanced to the arrival instant, then the router places the request on
    live backlogs — so ``join_shortest_queue``/``power_of_two`` see exactly
    the state a real router would.  Deterministic: the power-of-two sampler
    is seeded from the trace realization and replica count.
    """

    def __init__(self, cell: KernelWorkload, families: Iterable[str], *,
                 hardware: Optional[HardwareSpec] = None,
                 slo_us: float = 2_000.0, max_ticks: int = 200_000,
                 fleet: Optional[FleetSpec] = None):
        self.cell = cell
        self.families = tuple(sorted(families))
        measure_mod._check_modeled(self.families)
        self.hardware = hardware or HardwareSpec()
        self.slo_us = float(slo_us)
        self.max_ticks = int(max_ticks)
        self.fleet = fleet or FleetSpec()

    # -- replica construction -------------------------------------------

    def mesh_split(self, fleet_plan: FleetPlan) -> Tuple[int, int]:
        """(data, model) split of one replica's device block."""
        from repro.runtime.elastic import viable_mesh_shape  # lazy: jax stack

        per_replica = self.fleet.num_devices // fleet_plan.num_replicas
        return viable_mesh_shape(per_replica, fleet_plan.model_parallel)

    def replica_hardware(self, fleet_plan: FleetPlan) -> List[HardwareSpec]:
        """Per-replica hardware: TP speedup, divided by the straggler
        slowdown for replicas whose contiguous device block
        ``[r*dpr, (r+1)*dpr)`` contains a slow device."""
        spec = self.fleet
        dpr = spec.num_devices // fleet_plan.num_replicas
        _, model = self.mesh_split(fleet_plan)
        slow = set(spec.slow_devices)
        out = []
        for r in range(fleet_plan.num_replicas):
            s = tp_speedup(model)
            if any(d in slow for d in range(r * dpr, (r + 1) * dpr)):
                s /= spec.slowdown
            out.append(self.hardware.scaled(s, s, s))
        return out

    # -- routing --------------------------------------------------------

    @staticmethod
    def _route(k: int, replicas: List[_FleetReplica], policy: str,
               rng: Optional[np.random.Generator]) -> int:
        n = len(replicas)
        if policy == "round_robin" or n == 1:
            return k % n
        if policy == "join_shortest_queue":
            # deterministic tie-break: lowest replica index
            return min(range(n), key=lambda r: (replicas[r].backlog, r))
        if policy == "power_of_two":
            pair = rng.choice(n, size=2, replace=False)
            lo, hi = int(min(pair)), int(max(pair))
            if replicas[hi].backlog < replicas[lo].backlog:
                return hi
            return lo                  # tie -> lower index
        raise ValueError(f"unknown routing policy {policy!r}; "
                         f"known: {sorted(ROUTING_POLICIES)}")

    # -- the fleet event loop -------------------------------------------

    def run(self, trace: Trace, plan: ServingPlan,
            fleet_plan: Optional[FleetPlan] = None,
            config: Optional[Dict[str, Any]] = None,
            paged: Optional[PagedPlan] = None) -> FleetReport:
        config = config or {}
        fleet_plan = fleet_plan or FleetPlan()
        if paged is None:
            paged = PagedPlan.from_config(config)
        n = len(trace.requests)
        if n == 0:
            raise ValueError("cannot simulate an empty trace")
        if fleet_plan.num_replicas > self.fleet.num_devices:
            return _fleet_infeasible("devices", n, fleet_plan)

        data, model = self.mesh_split(fleet_plan)
        sims = [ServingSimulator(self.cell, self.families, hardware=hw,
                                 slo_us=self.slo_us, max_ticks=self.max_ticks)
                for hw in self.replica_hardware(fleet_plan)]
        reason = sims[0].capacity_reason(trace, plan, paged)
        if reason:
            return _fleet_infeasible(reason, n, fleet_plan)
        decode_us = []
        for sim in sims:
            d_us, feasible = sim.decode_tick_us(plan, config)
            if not feasible:
                return _fleet_infeasible("vmem", n, fleet_plan)
            decode_us.append(d_us)

        reqs = trace.requests
        replicas = [_FleetReplica(sim, plan, config, reqs, d, paged=paged,
                                  trace_tid=r)
                    for r, (sim, d) in enumerate(zip(sims, decode_us))]
        # the po2 sampler is part of the environment realization: seed it
        # from the trace identity + replica count so the same (trace,
        # config) pair always draws the same probe sequence
        rng = (np.random.default_rng(
                   [trace.seed, zlib.crc32(trace.spec.encode()),
                    fleet_plan.num_replicas])
               if fleet_plan.routing == "power_of_two" else None)

        routed_backlog_max = 0.0
        for k, req in enumerate(reqs):
            a_us = req.arrival_s * 1e6
            for rep in replicas:
                if not rep.advance_until(a_us):
                    return _fleet_infeasible("vmem", n, fleet_plan)
            r = self._route(k, replicas, fleet_plan.routing, rng)
            routed_backlog_max = max(routed_backlog_max,
                                     float(replicas[r].backlog))
            replicas[r].enqueue(k, a_us)
        for rep in replicas:
            if not rep.drain():
                return _fleet_infeasible("vmem", n, fleet_plan)

        # -- pool the per-replica counters ------------------------------
        total_ticks = sum(rep.ticks for rep in replicas)
        done = sorted(pair for rep in replicas for pair in rep.completed)
        lat = np.array([l for _, l in done], np.float64)
        has_lat = lat.size > 0
        t0 = reqs[0].arrival_s * 1e6
        makespan = max(max(rep.clock for rep in replicas if rep.assigned)
                       - t0, 1e-9)
        tokens = sum(rep.tokens for rep in replicas)
        imbalance = (max(len(rep.assigned) for rep in replicas)
                     / (n / fleet_plan.num_replicas))

        # feed the straggler monitor the realized per-replica decode tick
        # times (replicas that never ticked are absent — partial reports)
        from repro.runtime.straggler import StragglerMonitor  # lazy
        monitor = StragglerMonitor(fleet_plan.num_replicas)
        step_times = {r: rep.decode_total / rep.ticks
                      for r, rep in enumerate(replicas) if rep.ticks > 0}
        if step_times:
            for _ in range(monitor.patience):
                monitor.report(step_times)

        return FleetReport(
            feasible=True, reason="", completed=n, ticks=total_ticks,
            makespan_us=makespan,
            queue_depth_mean=sum(rep.qd_sum for rep in replicas)
            / max(total_ticks, 1),
            queue_depth_max=max(rep.qd_max for rep in replicas),
            occupancy_mean=sum(rep.occ_sum for rep in replicas)
            / max(total_ticks, 1),
            prefill_us=sum(rep.prefill_total for rep in replicas),
            decode_us=sum(rep.decode_total for rep in replicas),
            p50_latency_us=float(np.percentile(lat, 50)) if has_lat else 0.0,
            p99_latency_us=float(np.percentile(lat, 99)) if has_lat else 0.0,
            mean_latency_us=float(lat.mean()) if has_lat else 0.0,
            throughput_rps=n / (makespan * 1e-6),
            tokens_per_s=tokens / (makespan * 1e-6),
            slo_violation_rate=(float((lat > self.slo_us).mean())
                                if has_lat else 0.0),
            page_pool_occupancy=sum(rep.pool_occ_sum for rep in replicas)
            / max(total_ticks, 1),
            page_faults=float(sum(rep.page_faults for rep in replicas)),
            prefill_chunks_inflight=sum(rep.chunks_inflight_sum
                                        for rep in replicas)
            / max(total_ticks, 1),
            num_replicas=fleet_plan.num_replicas, routing=fleet_plan.routing,
            data_parallel=data, model_parallel=model,
            assignments=tuple(tuple(rep.assigned) for rep in replicas),
            replica_ticks=tuple(rep.ticks for rep in replicas),
            replica_wall_us=tuple(rep.clock for rep in replicas),
            routing_imbalance=imbalance,
            replica_queue_depth_max=routed_backlog_max,
            straggler_flagged=len(monitor.flagged()),
            straggler_excluded=tuple(monitor.excluded()))
