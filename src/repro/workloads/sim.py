"""Deterministic discrete-event simulator of the continuous batcher.

The real :class:`repro.serving.scheduler.ContinuousBatcher` keeps a fixed
number of decode slots, admits queued requests into free slots, and runs one
fused decode step per tick.  This module replays that control loop against
the analytic per-kernel cost model (:class:`repro.envs.measure.
LaunchGeometry`), so the full serving stack — scheduler knobs AND kernel
launch geometry — is priceable in microseconds of modeled time on CPU CI:

- one admission costs the modeled prefill of that prompt at batch 1;
- one decode tick costs the modeled cost of the compiled decode shape
  ``(num_slots, cache_len)`` amortized per token — the compiled program runs
  at full batch whether slots are occupied or not, exactly like the real
  batcher;
- the VMEM feasibility gate of the launch space carries over, and a plan
  whose ``cache_len`` cannot hold every request of the trace is infeasible
  (you cannot deploy a cache too small for the workload).

The simulator is pure and seeded by its inputs: the same (trace, plan,
config) triple always yields the identical :class:`SimReport`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.spaces import ConfigSpace, Option
from repro.envs import measure as measure_mod
from repro.envs.measure import (HardwareSpec, KernelWorkload, LaunchGeometry,
                                family_params)
from repro.serving.scheduler import DrainStall
from repro.workloads.traces import Trace

SERVING_PREFIX = "serving."

#: The scheduler's tunable surface.  ``family.param`` launch options join it
#: in :func:`serving_space` — together they are the serving stack CAMEO tunes.
SCHEDULER_OPTIONS: Tuple[Option, ...] = (
    Option("serving.num_slots", (2, 4, 8, 16), default=8),
    Option("serving.admit_chunk", (1, 2, 4, 8), default=4),
    Option("serving.cache_len", (128, 256, 512, 1024, 2048), default=512),
    Option("serving.interleave", ("eager", "drain"), default="eager",
           kind="categorical"),
)


def serving_space(families: Optional[Iterable[str]] = None) -> ConfigSpace:
    """Scheduler options joined with the kernel-launch space — one flat
    ``ConfigSpace`` (``serving.*`` + ``family.param`` keys)."""
    from repro.kernels import dispatch

    return ConfigSpace(list(SCHEDULER_OPTIONS)
                       + list(dispatch.launch_space(families).options))


@dataclass(frozen=True)
class ServingPlan:
    """The scheduler half of a serving configuration."""

    num_slots: int = 8
    admit_chunk: int = 4
    cache_len: int = 512
    interleave: str = "eager"        # eager: admit every tick; drain: only
                                     # refill once the resident batch empties

    def __post_init__(self):
        if self.num_slots < 1 or self.admit_chunk < 1 or self.cache_len < 1:
            raise ValueError(f"malformed serving plan {self}")
        if self.interleave not in ("eager", "drain"):
            raise ValueError(
                f"unknown interleave policy {self.interleave!r}; "
                f"known: ['drain', 'eager']")

    @classmethod
    def from_config(cls, config: Dict[str, Any]) -> "ServingPlan":
        """Extract the ``serving.*`` keys of a flat tuner configuration,
        defaulting anything unspecified."""
        kw = {}
        for f in dataclasses.fields(cls):
            key = SERVING_PREFIX + f.name
            if key in config:
                v = config[key]
                kw[f.name] = v if f.name == "interleave" else int(v)
        return cls(**kw)


@dataclass(frozen=True)
class SimReport:
    """Counters from one simulated trace run (modeled time in us)."""

    feasible: bool
    reason: str                      # "" when feasible
    completed: int
    ticks: int
    makespan_us: float
    queue_depth_mean: float
    queue_depth_max: float
    occupancy_mean: float
    prefill_us: float
    decode_us: float
    p50_latency_us: float
    p99_latency_us: float
    mean_latency_us: float
    throughput_rps: float            # completed requests / modeled second
    tokens_per_s: float
    slo_violation_rate: float

    @property
    def prefill_decode_ratio(self) -> float:
        return self.prefill_us / max(self.decode_us, 1e-9)

    def counters(self) -> Dict[str, float]:
        """The measurement's metrics dict.  ``latency`` (p99) and
        ``throughput`` use the query engine's metric names so constrained
        queries ("... for which latency is less than X") bind directly —
        but they are NOT in :data:`SIM_COUNTER_NAMES`: each is (a copy of)
        an objective, and admitting an objective clone into the causal
        graph lets the CI machinery condition it away from the config
        options, collapsing the ACE ranking."""
        return {
            "queue_depth_mean": self.queue_depth_mean,
            "queue_depth_max": self.queue_depth_max,
            "occupancy_mean": self.occupancy_mean,
            "prefill_decode_ratio": self.prefill_decode_ratio,
            "latency": self.p99_latency_us,
            "throughput": self.throughput_rps,
            "slo_violation_rate": self.slo_violation_rate,
        }


#: the system events C used for causal discovery: genuine mediators between
#: configuration and objective (queueing, occupancy, prefill/decode mix) —
#: the objective-metric copies in :meth:`SimReport.counters` are excluded
SIM_COUNTER_NAMES: Tuple[str, ...] = (
    "queue_depth_mean", "queue_depth_max", "occupancy_mean",
    "prefill_decode_ratio", "slo_violation_rate")


def _infeasible(reason: str, n_requests: int) -> SimReport:
    return SimReport(feasible=False, reason=reason, completed=0, ticks=0,
                     makespan_us=0.0, queue_depth_mean=float(n_requests),
                     queue_depth_max=float(n_requests), occupancy_mean=0.0,
                     prefill_us=0.0, decode_us=0.0, p50_latency_us=0.0,
                     p99_latency_us=0.0, mean_latency_us=0.0,
                     throughput_rps=0.0, tokens_per_s=0.0,
                     slo_violation_rate=1.0)


class ServingSimulator:
    """Prices a (trace, plan, launch config) triple in modeled microseconds.

    ``cell`` fixes the model dimensions (heads, head_dim, d_model, ...); its
    batch/seq fields are overridden per event by the serving shapes the plan
    implies.  ``families`` are the kernel families the served model
    dispatches — their launch parameters (``family.param`` keys of the
    config) steer every prefill/decode price through the same
    :class:`LaunchGeometry` the kernel-launch environment uses.
    """

    def __init__(self, cell: KernelWorkload, families: Iterable[str], *,
                 hardware: Optional[HardwareSpec] = None,
                 slo_us: float = 2_000.0, max_ticks: int = 200_000):
        self.cell = cell
        self.families = tuple(sorted(families))
        measure_mod._check_modeled(self.families)
        self.hardware = hardware or HardwareSpec()
        self.slo_us = float(slo_us)
        self.max_ticks = int(max_ticks)
        self._cost_cache: Dict[Tuple, Tuple[float, bool]] = {}

    # -- pricing --------------------------------------------------------

    def _shape_cost(self, batch: int, seq_len: int,
                    config: Dict[str, Any]) -> Tuple[float, bool]:
        """(modeled us, vmem-feasible) of one launch at (batch, seq_len)."""
        key = (batch, seq_len,
               tuple(sorted((k, v) for k, v in config.items() if "." in k)))
        if key not in self._cost_cache:
            w = dataclasses.replace(self.cell, batch=batch, seq_len=seq_len)
            geo = LaunchGeometry(w, self.hardware)
            _, t, feasible = geo.totals(self.families, config)
            self._cost_cache[key] = (t, feasible)
        return self._cost_cache[key]

    def prefill_us(self, prompt_len: int, plan: ServingPlan,
                   config: Dict[str, Any]) -> Tuple[float, bool]:
        return self._shape_cost(1, max(int(prompt_len), 1), config)

    def decode_tick_us(self, plan: ServingPlan,
                       config: Dict[str, Any]) -> Tuple[float, bool]:
        """One fused decode step at the compiled shape, amortized per cache
        token: the batch runs at ``num_slots`` whatever the occupancy."""
        t, feasible = self._shape_cost(plan.num_slots, plan.cache_len, config)
        return t / plan.cache_len, feasible

    def resolved_launch(self, config: Dict[str, Any]
                        ) -> Dict[str, Dict[str, Any]]:
        """The launch parameters every price in this run derives from — the
        simulator-side audit mirroring ``dispatch.record_resolutions``."""
        return {f: family_params(f, config) for f in self.families}

    # -- the event loop -------------------------------------------------

    def run(self, trace: Trace, plan: ServingPlan,
            config: Optional[Dict[str, Any]] = None) -> SimReport:
        config = config or {}
        n = len(trace.requests)
        if n == 0:
            raise ValueError("cannot simulate an empty trace")
        if trace.max_context > plan.cache_len:
            return _infeasible("cache_len", n)
        decode_us, feasible = self.decode_tick_us(plan, config)
        if not feasible:
            return _infeasible("vmem", n)

        queue: List[int] = []          # indices into trace.requests
        resident: List[List] = []      # [request_idx, remaining_tokens]
        done_latency = np.empty(n, np.float64)
        completed = 0
        clock = 0.0
        i = 0                          # next arrival
        ticks = 0
        qd_sum = qd_max = occ_sum = 0.0
        prefill_total = decode_total = 0.0
        tokens = 0
        reqs = trace.requests

        while completed < n:
            while i < n and reqs[i].arrival_s * 1e6 <= clock:
                queue.append(i)
                i += 1
            if not resident and not queue:
                clock = reqs[i].arrival_s * 1e6   # idle: jump to next arrival
                continue
            if queue and (plan.interleave == "eager" or not resident):
                admit = min(plan.admit_chunk, plan.num_slots - len(resident),
                            len(queue))
                for _ in range(admit):
                    idx = queue.pop(0)
                    t_pref, feasible = self.prefill_us(
                        reqs[idx].prompt_len, plan, config)
                    if not feasible:
                        return _infeasible("vmem", n)
                    clock += t_pref
                    prefill_total += t_pref
                    tokens += 1        # prefill emits the first token
                    if reqs[idx].output_len <= 1:
                        done_latency[idx] = clock - reqs[idx].arrival_s * 1e6
                        completed += 1
                    else:
                        resident.append([idx, reqs[idx].output_len - 1])
            if resident:
                # >= mirrors ContinuousBatcher.run_until_drained: max_ticks
                # decode ticks may run, the (max_ticks+1)-th is the stall
                if ticks >= self.max_ticks:
                    raise DrainStall(
                        f"serving simulation exceeded {self.max_ticks} ticks "
                        f"({completed}/{n} requests completed)",
                        completed=completed, pending=n - completed)
                ticks += 1
                clock += decode_us
                decode_total += decode_us
                occ_sum += len(resident)
                qd_sum += len(queue)
                qd_max = max(qd_max, float(len(queue)))
                tokens += len(resident)
                for slot in list(resident):
                    slot[1] -= 1
                    if slot[1] == 0:
                        idx = slot[0]
                        done_latency[idx] = clock - reqs[idx].arrival_s * 1e6
                        completed += 1
                        resident.remove(slot)

        makespan = max(clock - reqs[0].arrival_s * 1e6, 1e-9)
        # guarded even though n >= 1 here: np.percentile/.mean on an empty
        # array raise/NaN, and a zero-size latency vector must never escape
        # as a poisoned report
        lat = done_latency[:completed]
        has_lat = lat.size > 0
        return SimReport(
            feasible=True, reason="", completed=n, ticks=ticks,
            makespan_us=makespan,
            queue_depth_mean=qd_sum / max(ticks, 1),
            queue_depth_max=qd_max,
            occupancy_mean=occ_sum / max(ticks, 1),
            prefill_us=prefill_total, decode_us=decode_total,
            p50_latency_us=float(np.percentile(lat, 50)) if has_lat else 0.0,
            p99_latency_us=float(np.percentile(lat, 99)) if has_lat else 0.0,
            mean_latency_us=float(lat.mean()) if has_lat else 0.0,
            throughput_rps=n / (makespan * 1e-6),
            tokens_per_s=tokens / (makespan * 1e-6),
            slo_violation_rate=(float((lat > self.slo_us).mean())
                                if has_lat else 0.0))
