"""Seeded request-trace generators: the serving workload as data.

CAMEO's headline environment change is workload fluctuation — the paper
re-optimizes when the request mix shifts.  This module makes that axis a
first-class, reproducible object: a :class:`Trace` is a finite sequence of
:class:`RequestSpec` (arrival time, prompt length, output length) and a
:class:`Workload` is a seeded generator of traces.  Everything is
deterministic — the same spec string and seed always produce the identical
trace — so source→target workload swaps are benchmarkable on CPU CI exactly
like the ``shifted:<kind>`` measurement backends.

Registry: generator kinds register with :func:`register_workload` and are
selectable by spec string through :func:`make_workload`, mirroring
``repro.envs.measure.make_backend``:

    make_workload("poisson")
    make_workload("bursty:rate=2000,burst=6,horizon=0.05")
    make_workload("replay:path=trace.jsonl")

Arrival times are in seconds from trace start; the serving simulator prices
ticks in modeled microseconds, so a trace's ``rate`` is requests per second
of modeled time.  Unknown kinds or parameters raise ``ValueError`` with the
valid names — a workload spec that cannot land on a real generator is a bug
in the caller, not noise to ignore.
"""

from __future__ import annotations

import inspect
import json
import zlib
from dataclasses import dataclass
from typing import (Any, Callable, Dict, List, Protocol, Tuple,
                    runtime_checkable)

import numpy as np

WORKLOAD_SPEC_SEP = ":"


@dataclass(frozen=True)
class RequestSpec:
    """One request of a trace: when it arrives and how big it is."""

    uid: int
    arrival_s: float
    prompt_len: int
    output_len: int

    def to_json(self) -> Dict[str, Any]:
        return {"uid": self.uid, "arrival_s": self.arrival_s,
                "prompt_len": self.prompt_len, "output_len": self.output_len}


@dataclass(frozen=True)
class Trace:
    """A finite, ordered request arrival process (one workload realization)."""

    kind: str
    spec: str
    seed: int
    requests: Tuple[RequestSpec, ...]

    def __post_init__(self):
        times = [r.arrival_s for r in self.requests]
        if any(b < a for a, b in zip(times, times[1:])):
            raise ValueError("trace arrivals must be sorted by arrival_s")
        for r in self.requests:
            if r.arrival_s < 0 or r.prompt_len < 1 or r.output_len < 1:
                raise ValueError(f"malformed request {r}")

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def span_s(self) -> float:
        """First-to-last arrival span (0 for <= 1 request)."""
        if len(self.requests) < 2:
            return 0.0
        return self.requests[-1].arrival_s - self.requests[0].arrival_s

    @property
    def total_output_tokens(self) -> int:
        return sum(r.output_len for r in self.requests)

    @property
    def max_context(self) -> int:
        """Longest prompt + output any single request needs resident."""
        return max((r.prompt_len + r.output_len for r in self.requests),
                   default=0)

    def mean_rate(self) -> float:
        """Empirical arrival rate (requests per second of span)."""
        if self.span_s <= 0:
            return 0.0
        return (len(self.requests) - 1) / self.span_s

    def save(self, path: str) -> None:
        """One JSON object per line — the format ``replay:path=`` reads."""
        with open(path, "w") as f:
            for r in self.requests:
                f.write(json.dumps(r.to_json()) + "\n")


@runtime_checkable
class Workload(Protocol):
    """A seeded trace generator: same (spec, seed) -> identical trace."""

    kind: str
    spec: str

    def generate(self, seed: int = 0) -> Trace: ...


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

#: kind -> generator function ``fn(rng, **params) -> List[RequestSpec]``
WORKLOAD_KINDS: Dict[str, Callable[..., List[RequestSpec]]] = {}


def register_workload(kind: str):
    """Decorator registering a trace generator under ``kind``.  The
    function's keyword-only parameters (with defaults) define the spec
    surface: ``make_workload("kind:param=value")`` validates against them."""
    def deco(fn: Callable[..., List[RequestSpec]]):
        if kind in WORKLOAD_KINDS:
            raise ValueError(f"workload kind {kind!r} already registered")
        WORKLOAD_KINDS[kind] = fn
        return fn
    return deco


def workload_kinds() -> Tuple[str, ...]:
    return tuple(sorted(WORKLOAD_KINDS))


def _generator_params(fn: Callable) -> Dict[str, Any]:
    return {n: p.default for n, p in inspect.signature(fn).parameters.items()
            if p.kind == inspect.Parameter.KEYWORD_ONLY}


@dataclass(frozen=True)
class TraceWorkload:
    """A registered generator bound to concrete parameters."""

    kind: str
    params: Tuple[Tuple[str, Any], ...]

    @property
    def spec(self) -> str:
        """Canonical spec string (sorted params) — round-trips through
        :func:`make_workload`."""
        if not self.params:
            return self.kind
        body = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.kind}{WORKLOAD_SPEC_SEP}{body}"

    def generate(self, seed: int = 0) -> Trace:
        # seed the stream with (seed, crc32(spec)) so distinct specs with the
        # same seed draw different arrivals, reproducibly across processes
        # (unlike hash(), crc32 is unsalted)
        rng = np.random.default_rng(
            [int(seed), zlib.crc32(self.spec.encode())])
        requests = WORKLOAD_KINDS[self.kind](rng, **dict(self.params))
        requests.sort(key=lambda r: (r.arrival_s, r.uid))
        requests = [RequestSpec(uid=i, arrival_s=r.arrival_s,
                                prompt_len=r.prompt_len,
                                output_len=r.output_len)
                    for i, r in enumerate(requests)]
        return Trace(kind=self.kind, spec=self.spec, seed=int(seed),
                     requests=tuple(requests))


def _parse_value(text: str) -> Any:
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


def make_workload(spec: str) -> TraceWorkload:
    """Spec string -> bound workload.  ``kind`` or ``kind:k=v,k=v``; unknown
    kinds/parameters raise with the valid names."""
    kind, _, body = spec.partition(WORKLOAD_SPEC_SEP)
    kind = kind.strip()
    if kind not in WORKLOAD_KINDS:
        raise ValueError(
            f"unknown workload kind {kind!r}; known: {sorted(WORKLOAD_KINDS)}")
    fn = WORKLOAD_KINDS[kind]
    valid = _generator_params(fn)
    params = dict(valid)
    for item in filter(None, (s.strip() for s in body.split(","))):
        key, sep, val = item.partition("=")
        key = key.strip()
        if not sep:
            raise ValueError(
                f"workload spec item {item!r} is not 'param=value'")
        if key not in valid:
            raise ValueError(
                f"workload kind {kind!r} has no parameter {key!r}; "
                f"valid: {sorted(valid)}")
        params[key] = _parse_value(val.strip())
    return TraceWorkload(kind=kind, params=tuple(sorted(params.items())))


# --------------------------------------------------------------------------
# length mixtures
# --------------------------------------------------------------------------

def _thin_lengths(rng: np.random.Generator, n: int, mean: float,
                  cap: int) -> np.ndarray:
    """Thin-tailed (Poisson-around-mean) lengths, >= 1, <= cap."""
    return np.clip(1 + rng.poisson(max(mean - 1.0, 0.0), n), 1, cap)


def _heavy_lengths(rng: np.random.Generator, n: int, mean: float, cap: int,
                   alpha: float) -> np.ndarray:
    """Pareto(alpha) lengths scaled to the requested mean, >= 1, <= cap."""
    draw = mean * max(alpha - 1.0, 0.1) * rng.pareto(alpha, n)
    return np.clip(draw.astype(np.int64) + 1, 1, cap)


def _requests(arrivals: np.ndarray, prompts: np.ndarray,
              outputs: np.ndarray) -> List[RequestSpec]:
    return [RequestSpec(uid=i, arrival_s=float(t), prompt_len=int(p),
                        output_len=int(o))
            for i, (t, p, o) in enumerate(zip(arrivals, prompts, outputs))]


def _poisson_arrivals(rng: np.random.Generator, rate: float,
                      horizon: float) -> np.ndarray:
    if rate <= 0 or horizon <= 0:
        raise ValueError(f"rate and horizon must be > 0, got "
                         f"rate={rate} horizon={horizon}")
    # draw in blocks until the horizon is covered: exact homogeneous process
    gaps: List[np.ndarray] = []
    total = 0.0
    while total < horizon:
        g = rng.exponential(1.0 / rate, max(int(rate * horizon) + 1, 16))
        gaps.append(g)
        total += float(g.sum())
    t = np.cumsum(np.concatenate(gaps))
    return t[t < horizon]


# --------------------------------------------------------------------------
# registered kinds
# --------------------------------------------------------------------------

@register_workload("poisson")
def poisson_trace(rng: np.random.Generator, *, rate: float = 1500.0,
                  horizon: float = 0.05, mean_prompt: float = 96.0,
                  mean_output: float = 48.0, max_len: int = 384
                  ) -> List[RequestSpec]:
    """Memoryless arrivals at ``rate`` req/s with thin-tailed lengths — the
    well-behaved staging workload (the transfer source by default)."""
    t = _poisson_arrivals(rng, rate, horizon)
    return _requests(t, _thin_lengths(rng, len(t), mean_prompt, max_len),
                     _thin_lengths(rng, len(t), mean_output, max_len))


@register_workload("bursty")
def bursty_trace(rng: np.random.Generator, *, rate: float = 1500.0,
                 burst: float = 5.0, dwell: float = 0.008,
                 burst_frac: float = 0.3, horizon: float = 0.05,
                 mean_prompt: float = 96.0, mean_output: float = 48.0,
                 max_len: int = 384) -> List[RequestSpec]:
    """Markov-modulated Poisson: a calm state at ``rate`` and a burst state
    at ``rate * burst``, with exponential dwell times (mean ``dwell`` s,
    stationary burst fraction ``burst_frac``).  Queue depth spikes the
    Poisson source never shows — the canonical serving workload shift."""
    if not 0.0 < burst_frac < 1.0:
        raise ValueError(f"burst_frac must be in (0, 1), got {burst_frac}")
    times: List[float] = []
    t, hot = 0.0, False
    while t < horizon:
        mean_dwell = dwell * (burst_frac if hot else (1.0 - burst_frac)) * 2
        seg = min(float(rng.exponential(mean_dwell)), horizon - t)
        seg_rate = rate * (burst if hot else 1.0)
        if seg > 0:
            times.extend(t + _poisson_arrivals(rng, seg_rate, seg))
        t += seg
        hot = not hot
    arr = np.sort(np.asarray(times))
    return _requests(arr, _thin_lengths(rng, len(arr), mean_prompt, max_len),
                     _thin_lengths(rng, len(arr), mean_output, max_len))


@register_workload("diurnal")
def diurnal_trace(rng: np.random.Generator, *, rate: float = 1500.0,
                  amplitude: float = 0.8, period: float = 0.02,
                  horizon: float = 0.05, mean_prompt: float = 96.0,
                  mean_output: float = 48.0, max_len: int = 384
                  ) -> List[RequestSpec]:
    """Inhomogeneous Poisson with a sinusoidal rate profile
    ``rate * (1 + amplitude * sin(2 pi t / period))`` (thinning method) —
    the day/night traffic cycle compressed to the simulator's time scale."""
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
    peak = rate * (1.0 + amplitude)
    cand = _poisson_arrivals(rng, peak, horizon)
    keep = rng.random(len(cand)) * peak <= rate * (
        1.0 + amplitude * np.sin(2.0 * np.pi * cand / period))
    t = cand[keep]
    return _requests(t, _thin_lengths(rng, len(t), mean_prompt, max_len),
                     _thin_lengths(rng, len(t), mean_output, max_len))


@register_workload("heavy_tail")
def heavy_tail_trace(rng: np.random.Generator, *, rate: float = 1500.0,
                     horizon: float = 0.05, mean_prompt: float = 96.0,
                     mean_output: float = 48.0, alpha: float = 1.6,
                     heavy_frac: float = 0.25, max_len: int = 1280
                     ) -> List[RequestSpec]:
    """Poisson arrivals with a Pareto(``alpha``) length mixture: fraction
    ``heavy_frac`` of prompts/outputs draw from the heavy tail (up to
    ``max_len``), the rest stay thin.  Long-context stragglers dominate the
    p99 and can push small-cache serving configurations infeasible."""
    if not 0.0 <= heavy_frac <= 1.0:
        raise ValueError(f"heavy_frac must be in [0, 1], got {heavy_frac}")
    t = _poisson_arrivals(rng, rate, horizon)
    n = len(t)

    def mix(mean: float) -> np.ndarray:
        thin = _thin_lengths(rng, n, mean, max_len)
        heavy = _heavy_lengths(rng, n, mean * 2.0, max_len, alpha)
        return np.where(rng.random(n) < heavy_frac, heavy, thin)

    return _requests(t, mix(mean_prompt), mix(mean_output))


@register_workload("replay")
def replay_trace(rng: np.random.Generator, *, path: str = ""
                 ) -> List[RequestSpec]:
    """Replay a recorded JSONL trace (the format :meth:`Trace.save` writes).
    Deterministic by construction — the seed is ignored."""
    if not path:
        raise ValueError("replay workload needs path=<trace.jsonl>")
    out: List[RequestSpec] = []
    with open(path) as f:
        for i, line in enumerate(filter(str.strip, f)):
            rec = json.loads(line)
            out.append(RequestSpec(
                uid=int(rec.get("uid", i)),
                arrival_s=float(rec["arrival_s"]),
                prompt_len=int(rec["prompt_len"]),
                output_len=int(rec["output_len"])))
    if not out:
        raise ValueError(f"replay trace {path!r} is empty")
    return out
