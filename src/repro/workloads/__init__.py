"""Serving-workload scenarios: seeded request-trace generators + the
deterministic continuous-batching simulator they drive.

``make_workload("bursty:rate=2000")`` mirrors ``measure.make_backend`` —
trace kinds register in ``WORKLOAD_KINDS`` and are selectable by spec
string anywhere a workload is accepted (``ServingEnv``, the serving
benchmark, ``repro.launch.serve --workload``).
"""

from repro.workloads.sim import (  # noqa: F401
    FLEET_COUNTER_NAMES, FLEET_OPTIONS, FLEET_PREFIX, ROUTING_POLICIES,
    SCHEDULER_OPTIONS, SERVING_PREFIX, SIM_COUNTER_NAMES, DrainStall,
    FleetPlan, FleetReport, FleetSimulator, FleetSpec, ServingPlan,
    ServingSimulator, SimReport, serving_space, tp_speedup)
from repro.workloads.traces import (  # noqa: F401
    WORKLOAD_KINDS, RequestSpec, Trace, TraceWorkload, Workload,
    make_workload, register_workload, workload_kinds)
