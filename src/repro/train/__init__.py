from repro.train.optimizer import make_optimizer, Optimizer  # noqa: F401
from repro.train.train_step import make_train_step, TrainState  # noqa: F401
from repro.train.serve_step import make_prefill_step, make_decode_step  # noqa: F401
