"""In-house optimizers (no external deps): AdamW, Adafactor, SGD-momentum,
with warmup/cosine schedules and global-norm clipping.

Adafactor is the default for the trillion-byte archs (deepseek-v3, llama4,
command-r): its factored second moment keeps optimizer state at O(rows+cols)
instead of O(rows*cols), which is what makes those models fit 16 GB/chip HBM
at 512 chips (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.utils.config import TrainConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

def make_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    warm, total, base = cfg.warmup_steps, cfg.total_steps, cfg.lr

    def sched(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm_lr = base * (step + 1) / max(warm, 1)
        if cfg.schedule == "constant":
            post = jnp.asarray(base, jnp.float32)
        elif cfg.schedule == "linear":
            frac = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
            post = base * (1.0 - frac)
        else:  # cosine
            frac = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
            post = 0.5 * base * (1.0 + jnp.cos(math.pi * frac))
        return jnp.where(step < warm, warm_lr, post)

    return sched


def clip_by_global_norm(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)]
    gnorm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def _adamw(cfg: TrainConfig) -> Optimizer:
    sched = make_schedule(cfg)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr = sched(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - cfg.b1 ** t
        c2 = 1.0 - cfg.b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = cfg.b1 * m + (1 - cfg.b1) * gf
            v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
            mh, vh = m_new / c1, v_new / c2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # no decay on norms/biases
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# --------------------------------------------------------------------------

def _adafactor(cfg: TrainConfig) -> Optimizer:
    sched = make_schedule(cfg)
    d_clip = 1.0  # update clipping threshold (Shazeer & Stern)

    def init(params):
        def slot(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),     # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(slot, params,
                                      is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, step):
        lr = sched(step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** -0.8  # standard adafactor decay schedule

        def upd(g, slot, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + 1e-30
            if p.ndim >= 2:
                vr = beta2 * slot["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * slot["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                vhat = (vr[..., None] * vc[..., None, :]
                        / (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + 1e-30))
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = beta2 * slot["v"] + (1 - beta2) * g2
                vhat = v
                new_slot = {"v": v}
            u = gf / (jnp.sqrt(vhat) + 1e-30)
            # update clipping: rms(u) <= d_clip
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / d_clip)
            delta = u
            if p.ndim >= 2:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_slot

        out = jax.tree.map(upd, grads, state["slots"], params,
                           is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_slots = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"slots": new_slots}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# SGD + momentum
# --------------------------------------------------------------------------

def _sgdm(cfg: TrainConfig) -> Optimizer:
    sched = make_schedule(cfg)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = sched(step)

        def upd(g, m, p):
            m_new = cfg.b1 * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m_new).astype(p.dtype), m_new

        out = jax.tree.map(upd, grads, state["m"], params)
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m}

    return Optimizer(init, update)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer == "adamw":
        return _adamw(cfg)
    if cfg.optimizer == "adafactor":
        return _adafactor(cfg)
    if cfg.optimizer == "sgdm":
        return _sgdm(cfg)
    raise ValueError(f"unknown optimizer {cfg.optimizer}")
