"""Serve-step factories: prefill / decode / generate.

``make_prefill_step`` runs the full prompt through the model, filling the KV
caches (attention) or computing the final recurrent state (SSM), and returns
the last-position logits.  ``make_decode_step`` advances one token per batch
element against the cached state — this is the function the ``decode_*`` and
``long_*`` dry-run shapes lower.

State layout follows the training-side scan: caches are stacked over
super-blocks so decode lowers to a single ``lax.scan`` over layers.

Both factories take an optional ``launch_config`` (flat ``family.param`` or
nested dict, e.g. ``TuneResult.launch_config`` from a kernel-launch tuning
run): the step body runs under an *exclusive* ``dispatch.use_launch_config``
so exactly the tuned block sizes / chunk lengths are baked into the trace —
an ambient installed config cannot leak in, which is what lets
:func:`jitted_steps` cache compiled (prefill, decode) pairs per
(model, run, cache_len, launch_config) soundly (jax traces lazily, whenever
the first call happens).  To deploy a tuned optimum to a step, pass it here;
``use_launch_config`` alone cannot reach an already-compiled step.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models import encdec
from repro.models.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.utils.config import RunConfig


def freeze_launch_config(launch_config: Optional[Dict[str, Any]]
                         ) -> Tuple[Tuple[str, Tuple[Tuple[str, Any], ...]], ...]:
    """Hashable canonical form of a launch config (flat or nested) — the jit
    cache key component, so equivalent spellings share one compilation."""
    if not launch_config:
        return ()
    nested = dispatch.split_launch_config(launch_config)
    return tuple((f, tuple(sorted(p.items()))) for f, p in sorted(nested.items()))


class ServeState(NamedTuple):
    caches: Any           # stacked per-super-block decode caches
    lengths: jax.Array    # (B,) int32 tokens consumed so far
    extras: Dict[str, jax.Array]  # enc_out / vision_embeds, static per request


def make_prefill_step(model: Model, run: RunConfig,
                      cache_len: Optional[int] = None,
                      launch_config: Optional[Dict[str, Any]] = None
                      ) -> Callable[..., Tuple[ServeState, jax.Array]]:
    """Returns prefill(params, batch) -> (ServeState, last_logits (B, V))."""
    cfg = model.cfg
    max_len = cache_len or run.shape.seq_len
    dispatch.split_launch_config(launch_config or {})  # eager validation

    def prefill_step(params, batch: Dict) -> Tuple[ServeState, jax.Array]:
      # exclusive: the trace depends only on launch_config, never on an
      # ambient use_launch_config active when jax happens to trace — that
      # determinism is what makes the jitted_steps cache sound
      with dispatch.use_launch_config(launch_config, exclusive=True):
        tokens = batch["tokens"]
        b, s = tokens.shape
        caches = model.init_decode_state(b, max_len)
        extras: Dict[str, jax.Array] = {}
        if cfg.family == "audio":
            par = run.parallel
            enc_out = encdec.encode(params, cfg, par, batch["frames"])
            extras["enc_out"] = enc_out
            logits, new_caches = encdec.decode_forward(
                params, cfg, par, tokens, enc_out, decode_state=caches,
                decode=False)
        else:
            fkw = {}
            if cfg.family == "vlm":
                extras["vision_embeds"] = batch["vision_embeds"]
                fkw["vision_embeds"] = batch["vision_embeds"]
            logits, new_caches, _ = model.forward(
                params, tokens, decode_state=caches, decode=False, **fkw)
        lengths = jnp.full((b,), s, jnp.int32)
        return ServeState(new_caches, lengths, extras), logits[:, -1]

    return prefill_step


def make_decode_step(model: Model, run: RunConfig,
                     launch_config: Optional[Dict[str, Any]] = None
                     ) -> Callable[..., Tuple[ServeState, jax.Array]]:
    """Returns decode(params, state, tokens (B,1)) -> (state', logits (B, V))."""
    cfg = model.cfg
    dispatch.split_launch_config(launch_config or {})  # eager validation

    def decode_step(params, state: ServeState, tokens: jax.Array
                    ) -> Tuple[ServeState, jax.Array]:
      with dispatch.use_launch_config(launch_config, exclusive=True):
        positions = state.lengths[:, None]  # (B, 1) per-request positions
        if cfg.family == "audio":
            logits, new_caches = encdec.decode_forward(
                params, cfg, run.parallel, tokens, state.extras["enc_out"],
                positions=positions, decode_state=state.caches, decode=True)
        else:
            fkw = {}
            if cfg.family == "vlm":
                fkw["vision_embeds"] = state.extras["vision_embeds"]
            logits, new_caches, _ = model.forward(
                params, tokens, positions=positions, decode_state=state.caches,
                decode=True, **fkw)
        new_state = ServeState(new_caches, state.lengths + 1, state.extras)
        return new_state, logits[:, -1]

    return decode_step


# --------------------------------------------------------------------------
# compiled-step cache
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _jitted_steps_cached(model: Model, run: RunConfig,
                         cache_len: Optional[int],
                         frozen_launch: Tuple) -> Tuple[Callable, Callable]:
    launch_config = {f: dict(p) for f, p in frozen_launch}
    return (jax.jit(make_prefill_step(model, run, cache_len=cache_len,
                                      launch_config=launch_config)),
            jax.jit(make_decode_step(model, run,
                                     launch_config=launch_config)))


def jitted_steps(model: Model, run: RunConfig,
                 cache_len: Optional[int] = None,
                 launch_config: Optional[Dict[str, Any]] = None
                 ) -> Tuple[Callable, Callable]:
    """Cached jit-compiled ``(prefill, decode)`` for this serving setup.

    Keyed on (model, run, cache_len, canonical launch config) — ``Model`` is
    a NamedTuple of config + closures, hashable by identity of those
    closures — so repeated :func:`generate` calls and serving loops reuse
    compilations instead of retracing, while a *different* tuned launch
    config correctly gets a fresh trace (launch params are baked at trace
    time).  LRU-bounded so long-lived processes cycling through many models
    do not pin every compilation.
    """
    if not obs_trace.enabled():
        return _jitted_steps_cached(model, run, cache_len,
                                    freeze_launch_config(launch_config))
    before = _jitted_steps_cached.cache_info()
    steps = _jitted_steps_cached(model, run, cache_len,
                                 freeze_launch_config(launch_config))
    after = _jitted_steps_cached.cache_info()
    hit = after.hits > before.hits
    obs_metrics.REGISTRY.inc(
        "jit_cache_hits" if hit else "jit_cache_misses")
    obs_trace.instant("jit_cache_hit" if hit else "jit_cache_miss",
                      cat="jit_cache", track=obs_trace.TRACK_KERNEL,
                      cache_len=cache_len if cache_len is not None else -1,
                      currsize=after.currsize)
    return steps


# --------------------------------------------------------------------------
# generation loop (examples / integration tests)
# --------------------------------------------------------------------------

def sample_token(logits: jax.Array, key: jax.Array,
                 temperature: Any = 0.0) -> jax.Array:
    """logits (B, V) -> (B,) int32. temperature 0 = greedy.

    A scalar temperature applies to every row; an array of shape (B,) samples
    each row at its own temperature (0 rows decode greedily) — the mixed
    temperature case a continuous batcher hits when requests with different
    sampling settings share one decode step."""
    if jnp.ndim(temperature) == 0:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature,
                                      axis=-1).astype(jnp.int32)
    temps = jnp.asarray(temperature, logits.dtype)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe = jnp.where(temps > 0.0, temps, 1.0)
    sampled = jax.random.categorical(key, logits / safe[:, None],
                                     axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)


def generate(model: Model, run: RunConfig, params, batch: Dict, *,
             num_steps: int, temperature: float = 0.0, seed: int = 0,
             cache_len: Optional[int] = None,
             launch_config: Optional[Dict[str, Any]] = None) -> jax.Array:
    """Prefill + autoregressive decode. Returns generated tokens (B, steps).

    Steps come from :func:`jitted_steps`, so repeated generation with the
    same shapes/config reuses the compiled prefill/decode instead of
    retracing on every call."""
    prompt = batch["tokens"]
    b = prompt.shape[0]
    cache_len = cache_len or (prompt.shape[1] + num_steps)
    prefill, decode = jitted_steps(model, run, cache_len=cache_len,
                                   launch_config=launch_config)

    state, logits = prefill(params, batch)
    key = jax.random.PRNGKey(seed)

    toks = []
    tok = sample_token(logits, key, temperature)
    toks.append(tok)
    for i in range(num_steps - 1):
        key, sub = jax.random.split(key)
        state, logits = decode(params, state, tok[:, None])
        tok = sample_token(logits, sub, temperature)
        toks.append(tok)
    return jnp.stack(toks, axis=1)
