"""Gradient machinery: microbatch accumulation, compression, loss helpers."""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def cross_entropy_loss(logits: jax.Array, targets: jax.Array,
                       z_loss: float = 0.0) -> Tuple[jax.Array, Dict]:
    """Token-level CE with optional z-loss. logits (B,S,V), targets (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (targets >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    metrics = {"ce_loss": loss}
    if z_loss > 0.0:
        zl = z_loss * jnp.sum(jnp.square(lse) * mask) / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * mask) / denom
    metrics["accuracy"] = acc
    return loss, metrics


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

def compress_int8_ef(grads, error_buf):
    """Int8 quantization with error feedback.

    Returns (dequantized grads to apply, new error buffer).  On a real TPU
    deployment the int8 representation is what crosses the ICI links (paired
    with an int8 all-reduce); here the quantization error dynamics — the part
    that affects convergence — are exact.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq

    out = jax.tree.map(one, grads, error_buf)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
