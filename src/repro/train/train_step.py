"""Train-step factory.

Produces a jit-able ``train_step(state, batch) -> (state, metrics)`` with:

- microbatch gradient accumulation via ``lax.scan`` (per-microbatch gradient
  reduction lets XLA overlap the data-parallel reduce with the next
  microbatch's compute);
- mixed precision: fp32 (or bf16) master params, bf16 compute copies.  With
  ``grad_compression="bf16"`` gradients are taken w.r.t. the bf16 copies so
  the cross-data-axis all-reduce happens in bf16 (half the collective bytes —
  visible in the dry-run HLO); ``int8_ef`` adds error-feedback int8
  quantization on top;
- global-norm clipping, z-loss, MoE aux loss, DeepSeek MTP loss.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models.model import Model
from repro.models.transformer import mtp_logits
from repro.train.grad import (
    compress_int8_ef, cross_entropy_loss, init_error_buffer)
from repro.train.optimizer import Optimizer, clip_by_global_norm, make_schedule
from repro.utils.config import RunConfig
from repro.utils.trees import tree_cast


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array
    error_buf: Optional[Any] = None  # int8-EF compression residual


def init_train_state(model: Model, run: RunConfig, optimizer: Optimizer,
                     key: jax.Array) -> TrainState:
    params = model.init(key)
    params = tree_cast(params, jnp.dtype(run.train.param_dtype))
    opt_state = optimizer.init(params)
    err = (init_error_buffer(params)
           if run.parallel.grad_compression == "int8_ef" else None)
    return TrainState(params, opt_state, jnp.zeros((), jnp.int32), err)


def make_train_step(model: Model, run: RunConfig, optimizer: Optimizer,
                    launch_config: Optional[Dict] = None
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """``launch_config`` (e.g. ``TuneResult.launch_config`` from a
    kernel-launch tuning run) is installed on the dispatch registry around
    the step body, so the tuned block sizes / chunk lengths are baked into
    the trace when the returned step is jitted.  A different config needs a
    fresh ``make_train_step`` + jit."""
    cfg = model.cfg
    tc = run.train
    par = run.parallel
    compute_dtype = jnp.dtype(tc.compute_dtype)
    n_micro = par.microbatch
    dispatch.split_launch_config(launch_config or {})  # eager validation

    def loss_fn(params_c, batch):
        inputs, targets = batch["inputs"], batch["targets"]
        fkw = {}
        if cfg.family == "vlm":
            fkw["vision_embeds"] = batch["vision_embeds"]
        if cfg.family == "audio":
            fkw["frames"] = batch["frames"]
        if cfg.mtp_depth > 0:
            from repro.models.layers import lm_logits
            h, _, aux = model.forward(params_c, inputs, return_hidden=True, **fkw)
            logits = lm_logits(params_c["embed"], h)
        else:
            logits, _, aux = model.forward(params_c, inputs, **fkw)
        loss, metrics = cross_entropy_loss(logits, targets, z_loss=tc.z_loss)
        if cfg.is_moe:
            loss = loss + tc.moe_aux_loss * aux
            metrics["moe_aux"] = aux
        if cfg.mtp_depth > 0:
            positions = jnp.arange(inputs.shape[1])
            lg2 = mtp_logits(params_c, cfg, par, h, targets, positions)
            mtp_tgt = jnp.concatenate(
                [targets[:, 1:], jnp.full_like(targets[:, :1], -1)], axis=1)
            mtp_loss, _ = cross_entropy_loss(lg2, mtp_tgt)
            loss = loss + 0.3 * mtp_loss
            metrics["mtp_loss"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def grads_of(params, batch):
        if par.grad_compression in ("bf16", "int8_ef"):
            # differentiate w.r.t. the bf16 copies: the DP all-reduce of the
            # cotangents is then bf16 (half the bytes on the wire)
            params_c = tree_cast(params, compute_dtype)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params_c, batch)
            grads = tree_cast(grads, jnp.float32)
        else:
            def f32_loss(p, b):
                return loss_fn(tree_cast(p, compute_dtype), b)
            (loss, metrics), grads = jax.value_and_grad(f32_loss, has_aux=True)(
                params, batch)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
      # exclusive: the trace is a pure function of launch_config (see
      # serve_step; an ambient install at trace time must not leak in)
      with dispatch.use_launch_config(launch_config, exclusive=True):
        if n_micro > 1:
            def micro(acc, mb):
                g, m = grads_of(state.params, mb)
                return jax.tree.map(jnp.add, acc, g), m

            mb_batch = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, metrics = jax.lax.scan(micro, zero, mb_batch)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            grads, metrics = grads_of(state.params, batch)

        new_err = state.error_buf
        if par.grad_compression == "int8_ef":
            grads, new_err = compress_int8_ef(grads, state.error_buf)

        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, state.step)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = make_schedule(tc)(state.step)
        return TrainState(new_params, new_opt, state.step + 1, new_err), metrics

    return train_step
