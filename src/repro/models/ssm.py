"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

Both expose a sequence form (train/prefill, dispatching to the chunked scan /
SSD kernels) and a recurrent single-step form (decode) with explicit carried
state, so 500k-context decode is O(1) in sequence length.

Projections are kept as *separate* weight matrices (x, z, B, C, dt) rather
than one fused in_proj: the fused layout would slice a tensor-parallel-sharded
dimension at non-shard-aligned offsets (e.g. zamba2's 2*5120+128+80 fused
width over 16 TP shards), forcing XLA to reshard.  Separate matrices give
clean TP: d_inner/heads shard over the model axis, B/C (tiny, per-group) stay
replicated — matching production Mamba TP implementations.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import dense_init
from repro.utils.config import ModelConfig


class MambaState(NamedTuple):
    """Decode state for one mamba block."""
    conv: jax.Array  # (B, K-1, conv_channels) last inputs for causal conv
    ssm: jax.Array   # mamba1: (B, C, N); mamba2: (B, H, N, P)


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def _causal_conv_seq(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (B, L, C); w: (K, C); b: (C,)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _causal_conv_step(state: jax.Array, x_t: jax.Array, w: jax.Array, b: jax.Array):
    """state: (B, K-1, C); x_t: (B, C). Returns (new_state, y_t)."""
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(x_t.dtype)
    return window[:, 1:], y


def _dt_softplus_init(key, n: int):
    dt_init = jnp.exp(jax.random.uniform(key, (n,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return dt_init + jnp.log1p(-jnp.exp(-dt_init))  # inverse softplus


# --------------------------------------------------------------------------
# Mamba-1
# --------------------------------------------------------------------------

def init_mamba1(key, cfg: ModelConfig, dtype) -> Dict:
    d_inner = cfg.ssm_expand * cfg.d_model
    n, rank, k = cfg.ssm_state, _dt_rank(cfg), cfg.ssm_conv
    ks = jax.random.split(key, 7)
    A = -jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
    return {
        "w_x": dense_init(ks[0], cfg.d_model, d_inner, dtype),
        "w_z": dense_init(ks[1], cfg.d_model, d_inner, dtype),
        "conv_w": (jax.random.normal(ks[2], (k, d_inner), jnp.float32)
                   / math.sqrt(k)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_bcdt": dense_init(ks[3], d_inner, rank + 2 * n, dtype),
        "w_dt": dense_init(ks[4], rank, d_inner, dtype, scale=rank ** -0.5),
        "dt_bias": _dt_softplus_init(ks[5], d_inner),
        "A_log": jnp.log(-A),  # stored as log(-A), fp32
        "D": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(ks[6], d_inner, cfg.d_model, dtype),
    }


def apply_mamba1(p: Dict, cfg: ModelConfig, x: jax.Array,
                 state: Optional[MambaState] = None, decode: bool = False,
                 return_state: bool = False
                 ) -> Tuple[jax.Array, Optional[MambaState]]:
    n, rank = cfg.ssm_state, _dt_rank(cfg)
    b, s, _ = x.shape
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"])
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    A = -jnp.exp(p["A_log"])

    if decode:
        assert state is not None and s == 1
        conv_state, y_t = _causal_conv_step(state.conv, xi[:, 0], p["conv_w"], p["conv_b"])
        u = jax.nn.silu(y_t)  # (B, C)
        xdbc = jnp.einsum("bc,ce->be", u, p["w_bcdt"])
        dt_low, Bc, Cc = xdbc[..., :rank], xdbc[..., rank:rank + n], xdbc[..., rank + n:]
        dt = jax.nn.softplus(jnp.einsum("br,rc->bc", dt_low, p["w_dt"])
                             + p["dt_bias"][None, :])
        ssm_state, y = ops.selective_scan_step(state.ssm, u, dt, A, Bc, Cc, p["D"])
        y = y * jax.nn.silu(z[:, 0])
        out = jnp.einsum("bc,cd->bd", y, p["w_out"])[:, None, :]
        return out, MambaState(conv_state, ssm_state)

    u = jax.nn.silu(_causal_conv_seq(xi, p["conv_w"], p["conv_b"]))
    xdbc = jnp.einsum("bsc,ce->bse", u, p["w_bcdt"])
    dt_low, Bc, Cc = xdbc[..., :rank], xdbc[..., rank:rank + n], xdbc[..., rank + n:]
    dt = jax.nn.softplus(jnp.einsum("bsr,rc->bsc", dt_low, p["w_dt"])
                         + p["dt_bias"][None, None, :])
    new_state = None
    if return_state:
        y, h_final = ops.selective_scan(u, dt, A, Bc, Cc, p["D"],
                                        chunk=cfg.ssm_chunk, return_state=True)
        new_state = MambaState(_conv_tail(xi, cfg.ssm_conv), h_final)
    else:
        y = ops.selective_scan(u, dt, A, Bc, Cc, p["D"], chunk=cfg.ssm_chunk)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsc,cd->bsd", y, p["w_out"])
    return out, new_state


def _conv_tail(x: jax.Array, k: int) -> jax.Array:
    """Last k-1 inputs of the sequence, zero-padded on the left — the decode
    conv state after prefilling with `x` (B, S, C)."""
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return xp[:, xp.shape[1] - (k - 1):, :]


def init_mamba1_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    d_inner = cfg.ssm_expand * cfg.d_model
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, cfg.ssm_state), jnp.float32),
    )


# --------------------------------------------------------------------------
# Mamba-2
# --------------------------------------------------------------------------

def _m2_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_num_heads
    head_dim = d_inner // heads
    groups = 1
    return d_inner, heads, head_dim, groups


def init_mamba2(key, cfg: ModelConfig, dtype) -> Dict:
    d_inner, heads, head_dim, g = _m2_dims(cfg)
    n, k = cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 9)
    return {
        "w_z": dense_init(ks[0], cfg.d_model, d_inner, dtype),
        "w_x": dense_init(ks[1], cfg.d_model, d_inner, dtype),
        "w_B": dense_init(ks[2], cfg.d_model, g * n, dtype),
        "w_C": dense_init(ks[3], cfg.d_model, g * n, dtype),
        "w_dtp": dense_init(ks[4], cfg.d_model, heads, dtype),
        "conv_x_w": (jax.random.normal(ks[5], (k, d_inner), jnp.float32)
                     / math.sqrt(k)).astype(dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_bc_w": (jax.random.normal(ks[6], (k, 2 * g * n), jnp.float32)
                      / math.sqrt(k)).astype(dtype),
        "conv_bc_b": jnp.zeros((2 * g * n,), dtype),
        "dt_bias": _dt_softplus_init(ks[7], heads),
        "A_log": jnp.log(jnp.arange(1, heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[8], d_inner, cfg.d_model, dtype),
    }


def apply_mamba2(p: Dict, cfg: ModelConfig, x: jax.Array,
                 state: Optional[MambaState] = None, decode: bool = False,
                 return_state: bool = False
                 ) -> Tuple[jax.Array, Optional[MambaState]]:
    d_inner, heads, head_dim, g = _m2_dims(cfg)
    n = cfg.ssm_state
    b, s, _ = x.shape
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bc = jnp.concatenate([jnp.einsum("bsd,de->bse", x, p["w_B"]),
                          jnp.einsum("bsd,de->bse", x, p["w_C"])], axis=-1)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dtp"])
    A = -jnp.exp(p["A_log"])

    if decode:
        assert state is not None and s == 1
        cs_x, cs_bc = state.conv[..., :d_inner], state.conv[..., d_inner:]
        cs_x, x_t = _causal_conv_step(cs_x, xi[:, 0], p["conv_x_w"], p["conv_x_b"])
        cs_bc, bc_t = _causal_conv_step(cs_bc, bc[:, 0], p["conv_bc_w"], p["conv_bc_b"])
        x_t = jax.nn.silu(x_t).reshape(b, heads, head_dim)
        bc_t = jax.nn.silu(bc_t)
        Bt = bc_t[..., :g * n].reshape(b, g, n)
        Ct = bc_t[..., g * n:].reshape(b, g, n)
        dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"][None, :])  # (B, H)
        ssm_state, y = ops.ssd_step(state.ssm, x_t, dt, A, Bt, Ct, p["D"])
        y = y.reshape(b, d_inner)
        y = _gated_rmsnorm(y, z[:, 0], p["norm_scale"], cfg.norm_eps)
        out = jnp.einsum("bc,cd->bd", y, p["w_out"])[:, None, :]
        return out, MambaState(jnp.concatenate([cs_x, cs_bc], -1), ssm_state)

    xs_ = jax.nn.silu(_causal_conv_seq(xi, p["conv_x_w"], p["conv_x_b"]))
    bcs = jax.nn.silu(_causal_conv_seq(bc, p["conv_bc_w"], p["conv_bc_b"]))
    xs_ = xs_.reshape(b, s, heads, head_dim)
    Bs = bcs[..., :g * n].reshape(b, s, g, n)
    Cs = bcs[..., g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, :])  # (B, S, H)
    new_state = None
    if return_state:
        y, ssm_final = ops.ssd(xs_, dt, A, Bs, Cs, p["D"], chunk=cfg.ssm_chunk,
                               return_state=True)
        conv_tail = _conv_tail(jnp.concatenate([xi, bc], -1), cfg.ssm_conv)
        new_state = MambaState(conv_tail, ssm_final)
    else:
        y = ops.ssd(xs_, dt, A, Bs, Cs, p["D"], chunk=cfg.ssm_chunk)
    y = y.reshape(b, s, d_inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsc,cd->bsd", y, p["w_out"])
    return out, new_state


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    d_inner, heads, head_dim, g = _m2_dims(cfg)
    conv_ch = d_inner + 2 * g * cfg.ssm_state
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, heads, cfg.ssm_state, head_dim), jnp.float32),
    )
