"""Model factory: build/init/apply dispatch over the 10 assigned families,
plus exact analytic parameter counting (via ``jax.eval_shape`` — no
allocation).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer
from repro.utils.config import ModelConfig, ParallelConfig


class Model(NamedTuple):
    """Bound model functions for one architecture."""
    cfg: ModelConfig
    init: Callable[..., Dict]
    forward: Callable[..., Any]           # training/prefill forward
    init_decode_state: Callable[..., Dict]
    # paged-KV variant: (batch, pool_pages, page_size, pages_per_slot_max)
    # -> stacked decode state; None for families without a paged serving path
    init_paged_decode_state: Optional[Callable[..., Dict]] = None


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def build_model(cfg: ModelConfig, par: Optional[ParallelConfig] = None) -> Model:
    par = par or ParallelConfig()
    dtype = _dtype(cfg)
    if cfg.family == "audio":
        def init(key):
            return encdec.init_encdec_params(cfg, key, dtype)

        def forward(params, tokens, *, frames=None, decode_state=None,
                    decode=False, positions=None, **kw):
            enc_out = encdec.encode(params, cfg, par, frames)
            logits, state = encdec.decode_forward(
                params, cfg, par, tokens, enc_out, positions=positions,
                decode_state=decode_state, decode=decode)
            return logits, state, jnp.zeros((), jnp.float32)

        def init_state(batch, max_len):
            return encdec.init_encdec_decode_state(cfg, batch, max_len, dtype)

        return Model(cfg, init, forward, init_state)

    def init(key):
        return transformer.init_lm_params(cfg, key, dtype)

    def forward(params, tokens, *, vision_embeds=None, decode_state=None,
                decode=False, positions=None, return_hidden=False, **kw):
        return transformer.forward(
            params, cfg, par, tokens, positions=positions,
            vision_embeds=vision_embeds, decode_state=decode_state,
            decode=decode, return_hidden=return_hidden)

    def init_state(batch, max_len):
        return transformer.init_decode_state(cfg, batch, max_len, dtype)

    def init_paged_state(batch, pool_pages, page_size, pages_per_slot_max):
        return transformer.init_paged_decode_state(
            cfg, batch, pool_pages, page_size, pages_per_slot_max, dtype)

    return Model(cfg, init, forward, init_state, init_paged_state)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    return build_model(cfg).init(key)


@functools.lru_cache(maxsize=256)
def _param_shapes_cached(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape (no allocation).

    With ``active_only`` (MoE), routed-expert params are scaled by
    top_k / num_experts — the standard "active parameters" convention.
    """
    shapes = _param_shapes_cached(cfg)
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", "")))) for p in path)
        if "moe" in keys and any(w in keys for w in ("w_gate", "w_up", "w_down")) \
                and "shared" not in keys:
            expert += n
    if active_only and cfg.is_moe and expert:
        total = total - expert + int(expert * cfg.moe_top_k / cfg.moe_num_experts)
    return total
