"""Shared layers: norms, rotary embeddings, MLP variants, embedding/head.

Everything is functional: ``init_*`` returns a param dict, the apply function
takes (params, activations).  Initializers follow standard truncated-normal
fan-in scaling.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim), jnp.float32)
            ).astype(dtype)


# -- RMSNorm ---------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((dim,), dtype)}


def apply_rmsnorm(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    return ops.rmsnorm(x, p["scale"], eps=eps)


# -- Rotary ----------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with even D; positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    if positions.ndim == 1:
        angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, D/2)
        angles = angles[None, :, None, :]  # (1, S, 1, D/2)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
        angles = angles[:, :, None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP variants ----------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, mlp_type: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    # relu2 (nemotron squared-ReLU) and gelu share a 2-matrix shape
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def apply_mlp(p: Dict, x: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jax.nn.silu(g) * u
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", x, p["w_up"])))
    else:  # gelu
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# -- Embedding + LM head ---------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype, tie: bool) -> Dict:
    k1, k2 = jax.random.split(key)
    p = {"embedding": embed_init(k1, vocab, d_model, dtype)}
    if not tie:
        p["lm_head"] = dense_init(k2, d_model, vocab, dtype)
    return p


def embed_tokens(p: Dict, tokens: jax.Array, d_model: int) -> jax.Array:
    return p["embedding"][tokens] * jnp.asarray(math.sqrt(d_model), p["embedding"].dtype)


def lm_logits(p: Dict, h: jax.Array) -> jax.Array:
    if "lm_head" in p:
        return jnp.einsum("bsd,dv->bsv", h, p["lm_head"])
    return jnp.einsum("bsd,vd->bsv", h, p["embedding"])
