"""Mixture-of-Experts layer (DeepSeek-V3 / Llama-4 style).

GSPMD-friendly grouped dense dispatch (GShard-style): tokens are split into
routing groups of ``group_size`` tokens; within each group a one-hot
dispatch/combine einsum routes at most ``capacity`` tokens to each expert.
The group dimension shards over the data axis and the expert dimension over a
configurable axis (``model`` -> TP-style all-reduce combine, ``data`` ->
classic EP all-to-all), both of which XLA partitions automatically.  Group
size, capacity factor, and the expert axis are first-class CAMEO knobs.

Supports top-k softmax routing (llama4: top-1) and DeepSeek-style sigmoid
scoring with renormalization over the selected experts, shared (always-on)
experts, and the switch-style load-balance auxiliary loss.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, apply_mlp
from repro.utils.config import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    e, dff = cfg.moe_num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], cfg.d_model, e, jnp.float32),
        # expert weights stacked on a leading expert dim (sharded for EP)
        "w_gate": _stack_init(ks[1], e, cfg.d_model, dff, dtype),
        "w_up": _stack_init(ks[2], e, cfg.d_model, dff, dtype),
        "w_down": _stack_init(ks[3], e, dff, cfg.d_model, dtype),
    }
    if cfg.moe_num_shared > 0:
        p["shared"] = init_mlp(ks[4], cfg.d_model, dff * cfg.moe_num_shared, "swiglu", dtype)
    return p


def _stack_init(key, e, din, dout, dtype):
    scale = din ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (e, din, dout), jnp.float32)
            * scale).astype(dtype)


def apply_moe(p: Dict, cfg: ModelConfig, x: jax.Array,
              router_mode: str = "softmax", group_size: int = 512,
              dropless: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (out, aux_loss).

    ``dropless`` forces capacity = group size (no token ever dropped) — used
    on the decode path where dropping a token corrupts a live request.
    """
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    t = b * s
    tg = min(group_size, t)
    while t % tg != 0:  # group size must divide the token count
        tg //= 2
    g = t // tg
    tokens = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32), p["router"])
    if router_mode == "sigmoid":  # deepseek-v3 scoring
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)

    top_vals, top_idx = jax.lax.top_k(scores, k)  # (G, Tg, k)
    if router_mode == "sigmoid":
        top_vals = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-20)

    # per-group capacity per expert
    if dropless:
        capacity = tg
    else:
        capacity = max(1, int(tg * k * cfg.moe_capacity_factor / e))
        capacity = min(capacity, tg)

    # queue position of each (token, slot) within its expert, per group
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)  # (G, Tg, k, E)
    flat = onehot.reshape(g, tg * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tg, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)  # (G, Tg, k)
    keep = pos < capacity

    gate = top_vals * keep.astype(top_vals.dtype)  # dropped slots contribute 0
    slot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # (G, Tg, k, C)
    mask = onehot.astype(jnp.float32) * keep[..., None].astype(jnp.float32)
    # (G, Tg, E, C) dispatch / combine tensors
    dispatch = jnp.einsum("gtke,gtkc->gtec", mask, slot).astype(x.dtype)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", mask, slot, gate.astype(jnp.float32))

    # route -> expert compute -> unroute
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, tokens)  # (G, E, C, D)
    h_gate = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
    h_up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])  # (G, E, C, D)
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), expert_out)

    if cfg.moe_num_shared > 0:
        out = out + apply_mlp(p["shared"], tokens, "swiglu")

    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))  # mean router prob
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=2),
                  axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    return out.reshape(b, s, d), aux
