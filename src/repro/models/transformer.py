"""Decoder-only LM covering dense / moe / ssm / hybrid / vlm families.

Layers are organized as *super-blocks*: the smallest repeating pattern of
sub-layers (e.g. llama4 = [dense, moe], zamba2 = 5x[mamba2] + [mamba2+shared
attention], vlm = 4x[dense] + [cross]).  Super-block weights are stacked on a
leading axis and iterated with ``jax.lax.scan`` so that 61-layer 671B configs
lower to compact HLO.  Decode threads stacked per-super-block caches through
the same scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (
    apply_mlp, apply_rmsnorm, embed_tokens, init_embed, init_mlp,
    init_rmsnorm, lm_logits,
)
from repro.models.moe import apply_moe, init_moe
from repro.utils.config import ModelConfig, ParallelConfig


# --------------------------------------------------------------------------
# super-block patterns
# --------------------------------------------------------------------------

def block_pattern(cfg: ModelConfig) -> List[str]:
    """Sub-layer kinds within one super-block."""
    if cfg.family == "ssm":
        return ["mamba1"]
    if cfg.family == "hybrid":
        period = cfg.hybrid_attn_period or 6
        return ["mamba2"] * (period - 1) + ["mamba2_shared_attn"]
    if cfg.family == "vlm":
        period = cfg.cross_attn_period or 5
        return ["dense"] * (period - 1) + ["cross"]
    if cfg.is_moe:
        if cfg.moe_layer_period > 1:
            return ["dense"] * (cfg.moe_layer_period - 1) + ["moe"]
        return ["moe"]
    return ["dense"]


def num_superblocks(cfg: ModelConfig) -> int:
    pat = len(block_pattern(cfg))
    assert cfg.num_layers % pat == 0, (cfg.num_layers, pat)
    return cfg.num_layers // pat


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype) -> Dict:
    if cfg.attn_type == "mla":
        return attn.init_mla(key, cfg, dtype)
    return attn.init_gqa(key, cfg, dtype)


def _init_sublayer(key, cfg: ModelConfig, kind: str, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    if kind == "mamba1":
        return {"norm": init_rmsnorm(cfg.d_model, dtype),
                "mixer": ssm.init_mamba1(ks[0], cfg, dtype)}
    if kind in ("mamba2", "mamba2_shared_attn"):
        return {"norm": init_rmsnorm(cfg.d_model, dtype),
                "mixer": ssm.init_mamba2(ks[0], cfg, dtype)}
    p = {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": _init_attn(ks[0], cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if kind == "moe":
        p["moe"] = init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    if kind == "cross":
        p["cross_norm"] = init_rmsnorm(cfg.d_model, dtype)
        p["cross"] = attn.init_cross_attn(ks[2], cfg, cfg.vision_dim or cfg.d_model, dtype)
        p["cross_gate"] = jnp.zeros((), dtype)  # gated cross-attn (llama3.2-v)
    return p


def init_lm_params(cfg: ModelConfig, key: jax.Array, dtype) -> Dict[str, Any]:
    pat = block_pattern(cfg)
    nsb = num_superblocks(cfg)
    k_embed, k_blocks, k_shared, k_mtp = jax.random.split(key, 4)

    def init_superblock(k):
        sub_keys = jax.random.split(k, len(pat))
        return {f"sub{i}": _init_sublayer(sub_keys[i], cfg, kind, dtype)
                for i, kind in enumerate(pat)}

    params: Dict[str, Any] = {
        "embed": init_embed(k_embed, cfg.vocab_size, cfg.d_model, dtype, cfg.tie_embeddings),
        "blocks": jax.vmap(init_superblock)(jax.random.split(k_blocks, nsb)),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.family == "hybrid":
        ks = jax.random.split(k_shared, 2)
        params["shared_attn"] = {
            "norm": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_gqa(ks[0], cfg, dtype),
            "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
        }
    if cfg.mtp_depth > 0:
        ks = jax.random.split(k_mtp, 2)
        params["mtp"] = {
            "proj": jax.random.normal(ks[0], (2 * cfg.d_model, cfg.d_model), jnp.float32
                                      ).astype(dtype) * (2 * cfg.d_model) ** -0.5,
            "block": _init_sublayer(ks[1], cfg, "dense" if not cfg.is_moe else "moe", dtype),
            "norm": init_rmsnorm(cfg.d_model, dtype),
        }
    return params


# --------------------------------------------------------------------------
# caches / decode state
# --------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    """Stacked per-super-block decode state matching the scan layout."""
    pat = block_pattern(cfg)
    nsb = num_superblocks(cfg)

    def one_sub(kind):
        if kind == "mamba1":
            return ssm.init_mamba1_state(cfg, batch, dtype)
        if kind in ("mamba2", "mamba2_shared_attn"):
            st = {"mixer": ssm.init_mamba2_state(cfg, batch, dtype)}
            if kind == "mamba2_shared_attn":
                st["shared_kv"] = attn.init_kv_cache(cfg, batch, max_len, dtype)
            return st
        if cfg.attn_type == "mla":
            return attn.init_mla_cache(cfg, batch, max_len, dtype)
        return attn.init_kv_cache(cfg, batch, max_len, dtype)

    def one_block(_):
        return {f"sub{i}": one_sub(kind) for i, kind in enumerate(pat)}

    # stack over super-blocks via tree_map on a template
    template = one_block(None)
    return jax.tree.map(lambda x: jnp.zeros((nsb,) + x.shape, x.dtype), template)


def init_paged_decode_state(cfg: ModelConfig, batch: int, pool_pages: int,
                            page_size: int, pages_per_slot_max: int,
                            dtype) -> Dict:
    """Paged variant of :func:`init_decode_state`: every attention KV cache
    becomes a :class:`~repro.models.attention.PagedKVCache` over a per-layer
    ``pool_pages``-page pool; recurrent SSM states (O(1) per slot) are
    unchanged.  The compiled decode shape is ``(pool_pages, page_size)`` —
    independent of any per-request context length, which is the point of the
    paged refactor."""
    if cfg.attn_type == "mla":
        raise NotImplementedError(
            "paged serving does not support the MLA compressed cache yet; "
            "serve MLA models dense")
    pat = block_pattern(cfg)
    nsb = num_superblocks(cfg)

    def paged_kv():
        return attn.init_paged_kv_cache(cfg, batch, pool_pages, page_size,
                                        pages_per_slot_max, dtype)

    def one_sub(kind):
        if kind == "mamba1":
            return ssm.init_mamba1_state(cfg, batch, dtype)
        if kind in ("mamba2", "mamba2_shared_attn"):
            st = {"mixer": ssm.init_mamba2_state(cfg, batch, dtype)}
            if kind == "mamba2_shared_attn":
                st["shared_kv"] = paged_kv()
            return st
        return paged_kv()

    template = {f"sub{i}": one_sub(kind) for i, kind in enumerate(pat)}
    # broadcast (not zeros): page tables start on the scratch page, a
    # non-zero index the template already carries
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (nsb,) + x.shape).astype(x.dtype),
        template)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _apply_sublayer(sub_p, cfg, par, kind, h, positions, shared_p, vision_kv,
                    cache, decode):
    """Returns (h, new_cache, aux).

    ``cache`` may be present in two modes: decode (single-token recurrent
    step) and prefill (full sequence forward that also fills the cache /
    computes the final recurrent state).
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    use_cache = cache is not None
    if kind == "mamba1":
        y, st = ssm.apply_mamba1(sub_p["mixer"], cfg,
                                 apply_rmsnorm(sub_p["norm"], h, cfg.norm_eps),
                                 state=cache if decode else None, decode=decode,
                                 return_state=use_cache and not decode)
        return h + y, (st if use_cache else cache), aux
    if kind in ("mamba2", "mamba2_shared_attn"):
        mixer_cache = cache["mixer"] if (decode and isinstance(cache, dict)) else None
        y, st = ssm.apply_mamba2(sub_p["mixer"], cfg,
                                 apply_rmsnorm(sub_p["norm"], h, cfg.norm_eps),
                                 state=mixer_cache, decode=decode,
                                 return_state=use_cache and not decode)
        h = h + y
        if kind == "mamba2_shared_attn":
            kv = cache["shared_kv"] if isinstance(cache, dict) else None
            y2, kv2 = attn.apply_gqa(shared_p["attn"], cfg, par,
                                     apply_rmsnorm(shared_p["norm"], h, cfg.norm_eps),
                                     positions, cache=kv, decode=decode)
            h = h + y2
            h = h + apply_mlp(shared_p["mlp"],
                              apply_rmsnorm(shared_p["mlp_norm"], h, cfg.norm_eps),
                              cfg.mlp_type)
            if use_cache:
                new_cache = {"mixer": st, "shared_kv": kv2}
        elif use_cache:
            new_cache = {"mixer": st}
        return h, new_cache, aux

    # attention + (mlp | moe) [+ cross]
    hn = apply_rmsnorm(sub_p["attn_norm"], h, cfg.norm_eps)
    if cfg.attn_type == "mla":
        y, kv = attn.apply_mla(sub_p["attn"], cfg, par, hn, positions,
                               cache=cache, decode=decode)
    else:
        y, kv = attn.apply_gqa(sub_p["attn"], cfg, par, hn, positions,
                               cache=cache, decode=decode)
    h = h + y
    if use_cache:
        new_cache = kv
    if kind == "cross":
        hc = apply_rmsnorm(sub_p["cross_norm"], h, cfg.norm_eps)
        yc = attn.apply_cross_attn(sub_p["cross"], cfg, par, hc, vision_kv)
        h = h + jnp.tanh(sub_p["cross_gate"]) * yc
    hm = apply_rmsnorm(sub_p["mlp_norm"], h, cfg.norm_eps)
    if kind == "moe":
        y, aux = apply_moe(sub_p["moe"], cfg, hm, router_mode=cfg.moe_router,
                           group_size=par.moe_group_size, dropless=decode)
        h = h + y
    else:
        h = h + apply_mlp(sub_p["mlp"], hm, cfg.mlp_type)
    return h, new_cache, aux


def forward(
    params: Dict,
    cfg: ModelConfig,
    par: ParallelConfig,
    tokens: jax.Array,           # (B, S) int32
    *,
    positions: Optional[jax.Array] = None,
    vision_embeds: Optional[jax.Array] = None,  # (B, T, D_v) for vlm
    decode_state: Optional[Dict] = None,
    decode: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (logits, new_decode_state, aux_loss)."""
    pat = block_pattern(cfg)
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s)
    h = embed_tokens(params["embed"], tokens, cfg.d_model)
    h = _shard_act(h, par)
    shared_p = params.get("shared_attn")
    use_cache = decode_state is not None

    def body(carry, xs):
        h, aux = carry
        if use_cache:
            block_p, block_cache = xs
        else:
            block_p, block_cache = xs, None
        new_caches = {}
        for i, kind in enumerate(pat):
            cache_i = block_cache[f"sub{i}"] if block_cache is not None else None
            h, nc, a = _apply_sublayer(block_p[f"sub{i}"], cfg, par, kind, h,
                                       positions, shared_p, vision_embeds,
                                       cache_i, decode)
            h = _shard_act(h, par)
            new_caches[f"sub{i}"] = nc
            aux = aux + a
        return (h, aux), (new_caches if use_cache else None)

    body_fn = body
    if par.remat != "none" and not decode:
        policy = None
        if par.remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        body_fn = jax.checkpoint(body, policy=policy, prevent_cse=False)

    aux0 = jnp.zeros((), jnp.float32)
    if par.scan_layers:
        xs = (params["blocks"], decode_state) if use_cache else params["blocks"]
        (h, aux), new_state = jax.lax.scan(body_fn, (h, aux0), xs)
    else:
        nsb = num_superblocks(cfg)
        new_list = []
        carry = (h, aux0)
        for i in range(nsb):
            block_p = jax.tree.map(lambda x: x[i], params["blocks"])
            if use_cache:
                cache_i = jax.tree.map(lambda x: x[i], decode_state)
                carry, nc = body_fn(carry, (block_p, cache_i))
                new_list.append(nc)
            else:
                carry, _ = body_fn(carry, block_p)
        h, aux = carry
        new_state = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
                     if use_cache else None)

    h = apply_rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, new_state, aux
    logits = lm_logits(params["embed"], h)
    return logits, new_state, aux


def _shard_act(h: jax.Array, par: ParallelConfig) -> jax.Array:
    """Activation sharding constraint (batch over data, optional SP over seq)."""
    from repro.sharding.specs import activation_sharding
    return activation_sharding(h, par)


# --------------------------------------------------------------------------
# MTP head (deepseek multi-token prediction)
# --------------------------------------------------------------------------

def mtp_logits(params: Dict, cfg: ModelConfig, par: ParallelConfig,
               h: jax.Array, tokens: jax.Array, positions: jax.Array) -> jax.Array:
    """Predict token t+2 from hidden t combined with embedding of token t+1."""
    mtp = params["mtp"]
    emb_next = embed_tokens(params["embed"], tokens, cfg.d_model)  # embeds of t+1
    hh = jnp.concatenate([h, emb_next], axis=-1)
    hh = jnp.einsum("bsd,de->bse", hh, mtp["proj"])
    kind = "moe" if "moe" in mtp["block"] else "dense"
    hh, _, _ = _apply_sublayer(mtp["block"], cfg, par, kind, hh, positions,
                               None, None, None, False)
    hh = apply_rmsnorm(mtp["norm"], hh, cfg.norm_eps)
    return lm_logits(params["embed"], hh)
