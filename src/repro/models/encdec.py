"""Whisper-style encoder-decoder backbone.

Per the task spec the conv/mel frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, T_enc, D) directly to the encoder.  The
encoder is bidirectional self-attention; the decoder has causal self-attention
plus cross-attention over encoder output, with standard KV caching for decode.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import attention as attn
from repro.models.layers import (
    apply_mlp, apply_rmsnorm, dense_init, embed_tokens, init_embed, init_mlp,
    init_rmsnorm, lm_logits,
)
from repro.utils.config import ModelConfig, ParallelConfig


def _init_enc_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_gqa(k1, cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_gqa(k1, cfg, dtype),
        "cross_norm": init_rmsnorm(cfg.d_model, dtype),
        "cross": attn.init_cross_attn(k2, cfg, cfg.d_model, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def init_encdec_params(cfg: ModelConfig, key: jax.Array, dtype) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc_layers = cfg.encoder_layers or cfg.num_layers
    return {
        "embed": init_embed(k1, cfg.vocab_size, cfg.d_model, dtype, cfg.tie_embeddings),
        "frame_proj": dense_init(k4, cfg.d_model, cfg.d_model, dtype),  # stub frontend adapter
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(k2, enc_layers)),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            jax.random.split(k3, cfg.num_layers)),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }


def encode(params: Dict, cfg: ModelConfig, par: ParallelConfig,
           frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, D) precomputed frame embeddings (stub frontend)."""
    h = jnp.einsum("btd,de->bte", frames, params["frame_proj"])
    b, t, _ = h.shape
    positions = jnp.arange(t)

    def body(h, block_p):
        hn = apply_rmsnorm(block_p["attn_norm"], h, cfg.norm_eps)
        hd = cfg.head_dim
        q = jnp.einsum("bsd,de->bse", hn, block_p["attn"]["wq"]).reshape(b, t, cfg.num_heads, hd)
        k = jnp.einsum("bsd,de->bse", hn, block_p["attn"]["wk"]).reshape(b, t, cfg.num_kv_heads, hd)
        v = jnp.einsum("bsd,de->bse", hn, block_p["attn"]["wv"]).reshape(b, t, cfg.num_kv_heads, hd)
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = ops.flash_attention(q, k, v, causal=False,
                                q_block=par.attn_q_block, kv_block=par.attn_kv_block)
        h = h + jnp.einsum("bse,ed->bsd", o.reshape(b, t, -1), block_p["attn"]["wo"])
        hm = apply_rmsnorm(block_p["mlp_norm"], h, cfg.norm_eps)
        h = h + apply_mlp(block_p["mlp"], hm, cfg.mlp_type)
        return h, None

    body = _maybe_remat(body, par)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return apply_rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _maybe_remat(body, par: ParallelConfig):
    if par.remat == "none":
        return body
    policy = None
    if par.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(body, policy=policy, prevent_cse=False)


def decode_forward(
    params: Dict,
    cfg: ModelConfig,
    par: ParallelConfig,
    tokens: jax.Array,        # (B, S)
    enc_out: jax.Array,       # (B, T_enc, D)
    *,
    positions: Optional[jax.Array] = None,
    decode_state: Optional[Dict] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s)
    h = embed_tokens(params["embed"], tokens, cfg.d_model)
    use_cache = decode_state is not None

    def body(h, xs):
        if use_cache:
            block_p, cache = xs
        else:
            block_p, cache = xs, None
        hn = apply_rmsnorm(block_p["attn_norm"], h, cfg.norm_eps)
        y, kv = attn.apply_gqa(block_p["attn"], cfg, par, hn, positions,
                               cache=cache, decode=decode)
        h = h + y
        hc = apply_rmsnorm(block_p["cross_norm"], h, cfg.norm_eps)
        h = h + attn.apply_cross_attn(block_p["cross"], cfg, par, hc, enc_out)
        hm = apply_rmsnorm(block_p["mlp_norm"], h, cfg.norm_eps)
        h = h + apply_mlp(block_p["mlp"], hm, cfg.mlp_type)
        return h, (kv if use_cache else None)

    if use_cache:
        xs = (params["dec_blocks"], decode_state)
    else:
        xs = params["dec_blocks"]
    body_fn = body if decode else _maybe_remat(body, par)
    h, new_state = jax.lax.scan(body_fn, h, xs)
    h = apply_rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return lm_logits(params["embed"], h), new_state


def init_encdec_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    template = attn.init_kv_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), template)
