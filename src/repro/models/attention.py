"""Attention variants: GQA (with optional sliding window / softcap), MLA
(DeepSeek latent attention with compressed KV cache), and cross-attention.

All functions are functional (params dict in, activations out) and carry an
optional KV cache for decode.  The inner attention contraction dispatches to
``kernels.ops`` (Pallas on TPU, jnp oracle elsewhere).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init
from repro.utils.config import ModelConfig, ParallelConfig


class KVCache(NamedTuple):
    """Ring-free append cache. k/v: (B, S_max, H_kv, D); length: (B,) int32."""
    k: jax.Array
    v: jax.Array
    length: jax.Array


class PagedKVCache(NamedTuple):
    """Block-paged KV cache over a shared page pool.

    ``k_pages``/``v_pages``: ``(P, page_size, H_kv, D)`` — the pool, shared by
    every slot of the batch.  ``page_table``: ``(B, pages_per_slot_max)``
    int32 — token ``t`` of slot ``b`` lives at pool page
    ``page_table[b, t // page_size]``, row ``t % page_size``.  Unused table
    entries must still hold *valid* pool indices (the attention mask from
    ``length`` makes their contents irrelevant).  ``length``: ``(B,)`` int32.

    With a single pool page per slot and ``page_size == cache_len`` the
    gathered layout IS the dense :class:`KVCache` — the dense-equivalence
    anchor the paged serving stack is tested against.
    """
    k_pages: jax.Array
    v_pages: jax.Array
    page_table: jax.Array
    length: jax.Array


class MLACache(NamedTuple):
    """DeepSeek MLA compressed cache: latent c_kv + rope key."""
    c_kv: jax.Array  # (B, S_max, kv_lora_rank)
    k_pe: jax.Array  # (B, S_max, qk_rope_head_dim)
    length: jax.Array


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, dtype) -> Dict:
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def apply_gqa(
    p: Dict,
    cfg: ModelConfig,
    par: ParallelConfig,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (S,)
    cache: Optional[KVCache] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[KVCache]]:
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if decode and isinstance(cache, PagedKVCache):
        assert s == 1
        if cfg.sliding_window > 0:
            raise NotImplementedError(
                "paged KV cache does not support sliding-window attention "
                "(the ring layout and the page layout disagree about where "
                "token t lives); serve sliding-window models dense")
        ps = cache.k_pages.shape[1]
        rows = jnp.arange(b)
        page_ids = cache.page_table[rows, cache.length // ps]  # (B,)
        row_ids = cache.length % ps                            # (B,)
        k_pages = cache.k_pages.at[page_ids, row_ids].set(k[:, 0])
        v_pages = cache.v_pages.at[page_ids, row_ids].set(v[:, 0])
        new_len = cache.length + 1
        o = ops.paged_decode_attention(
            q, k_pages, v_pages, cache.page_table, new_len,
            logit_softcap=cfg.attn_logit_softcap)
        new_cache = PagedKVCache(k_pages, v_pages, cache.page_table, new_len)
    elif decode:
        assert cache is not None and s == 1
        size = cache.k.shape[1]
        ring = cfg.sliding_window > 0 and size <= cfg.sliding_window
        idx = cache.length % size if ring else cache.length  # (B,)
        k_cache = _scatter_time(cache.k, k, idx)
        v_cache = _scatter_time(cache.v, v, idx)
        new_len = cache.length + 1
        # Ring cache holds exactly the window -> validity mask suffices; the
        # window mask is only needed when the cache is longer than the window.
        attn_len = jnp.minimum(new_len, size) if ring else new_len
        window = 0 if ring else cfg.sliding_window
        o = ops.decode_attention(
            q, k_cache, v_cache, attn_len,
            sliding_window=window, logit_softcap=cfg.attn_logit_softcap,
            kv_block=par.attn_kv_block)
        new_cache = KVCache(k_cache, v_cache, new_len)
    else:
        if isinstance(cache, PagedKVCache):
            # prefill runs dense (batch-1, one compiled program) and the
            # batcher scatters the filled rows into the slot's pages — see
            # repro.serving.scheduler._scatter_paged_rows
            raise NotImplementedError(
                "prefill directly into a paged cache is not supported; "
                "prefill dense and scatter the rows into pages")
        o = ops.flash_attention(
            q, k, v, causal=True, sliding_window=cfg.sliding_window,
            logit_softcap=cfg.attn_logit_softcap,
            q_block=par.attn_q_block, kv_block=par.attn_kv_block)
        new_cache = None
        if cache is not None:  # prefill into cache
            size = cache.k.shape[1]
            if s <= size:
                k_cache = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0))
            else:
                # ring cache smaller than the prompt (sliding window): pack
                # the last `size` keys at their ring slots (pos % size)
                j = jnp.arange(size)
                tok = s - size + ((j - s) % size)
                k_cache, v_cache = k[:, tok], v[:, tok]
            new_cache = KVCache(k_cache, v_cache, cache.length + s)
    out = jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.num_heads * hd), p["wo"])
    return out, new_cache


def _scatter_time(cache: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """Write `new` (B, 1, H, D) at per-batch time index `idx` (B,)."""
    b = cache.shape[0]
    onehot = jax.nn.one_hot(idx, cache.shape[1], dtype=cache.dtype)  # (B, S)
    return cache * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * new


def init_paged_kv_cache(cfg: ModelConfig, batch: int, pool_pages: int,
                        page_size: int, pages_per_slot_max: int,
                        dtype) -> PagedKVCache:
    """Paged cache with ``pool_pages`` allocatable pages plus one *scratch*
    page (index ``pool_pages``).  Every table entry starts on the scratch
    page, and the scheduler points freed slots back at it: an empty slot's
    decode step still scatters its pad-token K/V (exactly like the dense
    batcher writes into its own unused rows), so empty slots must land on a
    page no live request owns — otherwise they corrupt it."""
    hd = cfg.head_dim
    if cfg.sliding_window > 0:
        raise NotImplementedError(
            "paged KV cache does not support sliding-window attention")
    return PagedKVCache(
        k_pages=jnp.zeros((pool_pages + 1, page_size, cfg.num_kv_heads, hd),
                          dtype),
        v_pages=jnp.zeros((pool_pages + 1, page_size, cfg.num_kv_heads, hd),
                          dtype),
        page_table=jnp.full((batch, pages_per_slot_max), pool_pages,
                            jnp.int32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    hd = cfg.head_dim
    if cfg.sliding_window > 0:
        # ring buffer: the cache never needs to exceed the attention window
        max_len = min(max_len, cfg.sliding_window)
    return KVCache(
        k=jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        v=jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# --------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank, cfg.num_heads * qk_dim, dtype),
        "w_dkv": dense_init(ks[2], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "w_uk": dense_init(ks[3], cfg.kv_lora_rank,
                           cfg.num_heads * cfg.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[4], cfg.kv_lora_rank,
                           cfg.num_heads * cfg.v_head_dim, dtype),
        "wo": dense_init(ks[5], cfg.num_heads * cfg.v_head_dim, cfg.d_model, dtype),
    }


def apply_mla(
    p: Dict,
    cfg: ModelConfig,
    par: ParallelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[MLACache] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[MLACache]]:
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
    q = jnp.einsum("bsr,re->bse", cq, p["w_uq"]).reshape(b, s, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv, k_pe_flat = ckv_full[..., :cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    k_pe = apply_rope(k_pe_flat[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if decode:
        assert cache is not None and s == 1
        idx = cache.length
        onehot = jax.nn.one_hot(idx, cache.c_kv.shape[1], dtype=c_kv.dtype)
        c_cache = cache.c_kv * (1 - onehot)[..., None] + onehot[..., None] * c_kv
        pe_cache = cache.k_pe * (1 - onehot)[..., None] + onehot[..., None] * k_pe
        new_len = cache.length + 1
        # absorbed attention: score = q_nope^T W_uk c + q_pe^T k_pe
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.reshape(b, s, h, dn),
                           p["w_uk"].reshape(cfg.kv_lora_rank, h, dn))
        scale = (dn + dr) ** -0.5
        logits = (jnp.einsum("bshr,btr->bhst", q_lat, c_cache)
                  + jnp.einsum("bshr,btr->bhst", q_pe, pe_cache)) * scale
        t_pos = jnp.arange(c_cache.shape[1])[None, :]
        valid = t_pos < new_len[:, None]
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, c_cache)  # (B,1,H,rank)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, p["w_uv"].reshape(cfg.kv_lora_rank, h, dv))
        new_cache = MLACache(c_cache, pe_cache, new_len)
    else:
        k_nope = jnp.einsum("bsr,re->bse", c_kv, p["w_uk"]).reshape(b, s, h, dn)
        vfull = jnp.einsum("bsr,re->bse", c_kv, p["w_uv"]).reshape(b, s, h, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, dr))], -1)
        q_cat = jnp.concatenate([q_nope, q_pe], -1)
        o = ops.flash_attention(q_cat, k, vfull, causal=True,
                                q_block=par.attn_q_block, kv_block=par.attn_kv_block)
        new_cache = None
        if cache is not None:
            c_cache = jax.lax.dynamic_update_slice(cache.c_kv, c_kv, (0, 0, 0))
            pe_cache = jax.lax.dynamic_update_slice(cache.k_pe, k_pe, (0, 0, 0))
            new_cache = MLACache(c_cache, pe_cache, cache.length + s)
    out = jnp.einsum("bse,ed->bsd", o.reshape(b, s, h * dv), p["wo"])
    return out, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        k_pe=jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


# --------------------------------------------------------------------------
# Cross-attention (VLM image layers / enc-dec)
# --------------------------------------------------------------------------

def init_cross_attn(key, cfg: ModelConfig, kv_dim: int, dtype) -> Dict:
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": dense_init(k2, kv_dim, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(k3, kv_dim, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def apply_cross_attn(p: Dict, cfg: ModelConfig, par: ParallelConfig,
                     x: jax.Array, kv_src: jax.Array) -> jax.Array:
    """x: (B, S, D); kv_src: (B, T, D_kv) — no causal mask, no rope on kv."""
    b, s, _ = x.shape
    t = kv_src.shape[1]
    hd = cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = jnp.einsum("btd,de->bte", kv_src, p["wk"]).reshape(b, t, cfg.num_kv_heads, hd)
    v = jnp.einsum("btd,de->bte", kv_src, p["wv"]).reshape(b, t, cfg.num_kv_heads, hd)
    o = ops.flash_attention(q, k, v, causal=False,
                            q_block=par.attn_q_block, kv_block=par.attn_kv_block)
    return jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.num_heads * hd), p["wo"])
