from repro.models.model import (  # noqa: F401
    build_model,
    init_params,
    count_params_analytic,
)
