"""Continuous-batching serving scheduler.

Production serving does not run prefill/decode on fixed request batches: it
keeps a fixed number of SLOTS (the compiled decode batch size), admits new
requests into free slots as running ones finish, and runs one fused decode
step per tick for whatever is resident.  That keeps the compiled decode
shape static (one XLA program) while the request mix churns — the same
design as production LLM servers, adapted to this framework's
``ServeState``.

Mechanics:

- One decode program of batch = ``num_slots`` is compiled once.  Empty
  slots carry a pad token and their outputs are ignored.
- Prefill runs per admitted request (batch 1) and its cache is scattered
  into the slot's rows of the shared stacked cache.
- Per-request stopping: max_new_tokens or an EOS token id.
- Fairness/occupancy stats for capacity planning.

The scatter uses ``jax.tree.map`` over the cache pytree with a dynamic
batch-row update — O(cache_row) per admission, no recompile.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.train.serve_step import ServeState, jitted_steps, sample_token
from repro.utils.config import RunConfig


class DrainStall(RuntimeError):
    """A drain loop (real scheduler or the workload simulator) hit its tick
    budget with requests still queued or resident — a stall, not a completed
    run.  Carries the progress made so callers can report it."""

    def __init__(self, msg: str, *, completed: int, pending: int):
        super().__init__(msg)
        self.completed = completed
        self.pending = pending


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    extras: Dict[str, np.ndarray] = field(default_factory=dict)


@dataclass
class RequestState:
    request: Request
    slot: int
    generated: List[int] = field(default_factory=list)
    admitted_at: float = 0.0
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None


def _scatter_rows(dst_tree, src_tree, slot: int):
    """Write src (batch-1 state rows) into dst at batch row `slot`.

    Cache leaves are stacked (layers, batch, ...); lengths are (batch,).
    The batch dim is located as the first axis whose size equals the slot
    count — for stacked leaves that is axis 1, for flat leaves axis 0.
    """
    def one(dst, src):
        if dst.ndim == src.ndim and dst.shape == src.shape:
            return dst  # shared/static (e.g. vision_kv broadcast) — keep
        if dst.ndim >= 2 and src.ndim == dst.ndim and \
                src.shape[0] == dst.shape[0] and src.shape[1] == 1:
            # stacked (layers, 1, ...) -> row `slot` of (layers, B, ...)
            return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, axis=1)
        if src.ndim == dst.ndim and src.shape[0] == 1:
            return jax.lax.dynamic_update_slice_in_dim(dst, src, slot, axis=0)
        raise ValueError(f"unscatterable leaf {src.shape} -> {dst.shape}")

    return jax.tree.map(one, dst_tree, src_tree)


class ContinuousBatcher:
    def __init__(self, model: Model, run: RunConfig, params, *,
                 num_slots: int = 8, cache_len: int = 512,
                 eos_token: Optional[int] = None, seed: int = 0,
                 launch_config: Optional[Dict[str, Any]] = None,
                 interleave: str = "eager"):
        if interleave not in ("eager", "drain"):
            raise ValueError(
                f"unknown interleave policy {interleave!r}; "
                f"known: ['drain', 'eager']")
        self.model = model
        self.run = run
        self.params = params
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.eos_token = eos_token
        self.interleave = interleave
        self._key = jax.random.PRNGKey(seed)

        # a tuned kernel-launch optimum (e.g. TuneResult.launch_config) is
        # baked into the traces; the shared cache means several batchers on
        # one model reuse the compilation
        self._prefill, self._decode = jitted_steps(
            model, run, cache_len=cache_len, launch_config=launch_config)

        caches = model.init_decode_state(num_slots, cache_len)
        self.state = ServeState(
            caches=caches,
            lengths=jnp.zeros((num_slots,), jnp.int32),
            extras={})
        self._tokens = jnp.zeros((num_slots,), jnp.int32)
        self._slots: List[Optional[RequestState]] = [None] * num_slots
        self.queue: List[Request] = []
        self.completed: List[RequestState] = []
        self.ticks = 0
        self.stalled = False
        self._occupancy_sum = 0
        # lifetime wall time inside prefill vs decode launches — replay
        # reports diff these to get a per-replay prefill/decode split
        self.prefill_s = 0.0
        self.decode_s = 0.0

    # -- admission ----------------------------------------------------------

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _admit(self) -> None:
        if self.interleave == "drain" and \
                any(s is not None for s in self._slots):
            # drain policy: only refill once the resident batch empties —
            # the same admission gate the workload simulator prices
            return
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            batch = {"tokens": prompt}
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v)[None]
            t0 = time.perf_counter()
            one_state, logits = self._prefill(self.params, batch)
            jax.block_until_ready(logits)
            self.prefill_s += time.perf_counter() - t0
            self.state = ServeState(
                caches=_scatter_rows(self.state.caches, one_state.caches,
                                     slot),
                lengths=self.state.lengths.at[slot].set(
                    one_state.lengths[0]),
                extras=self.state.extras)
            self._key, sub = jax.random.split(self._key)
            tok = int(sample_token(logits, sub, req.temperature)[0])
            rs = RequestState(req, slot, admitted_at=time.perf_counter())
            rs.generated.append(tok)
            self._tokens = self._tokens.at[slot].set(tok)
            self._slots[slot] = rs
            self._maybe_finish(rs, tok)

    # -- stepping -----------------------------------------------------------

    def _maybe_finish(self, rs: RequestState, tok: int) -> None:
        if rs.done:
            return
        if (self.eos_token is not None and tok == self.eos_token) or \
                len(rs.generated) >= rs.request.max_new_tokens:
            rs.finished_at = time.perf_counter()
            self.completed.append(rs)
            self._slots[rs.slot] = None

    def tick(self) -> int:
        """Admit + one decode step for all resident requests.
        Returns the number of live requests stepped."""
        self._admit()
        live = [s for s in self._slots if s is not None]
        if not live:
            return 0
        self.ticks += 1
        self._occupancy_sum += len(live)
        t0 = time.perf_counter()
        new_state, logits = self._decode(self.params, self.state,
                                         self._tokens[:, None])
        jax.block_until_ready(logits)
        self.decode_s += time.perf_counter() - t0
        self.state = new_state
        self._key, sub = jax.random.split(self._key)
        # per-slot temperatures: requests with different sampling settings
        # share one decode step, so each resident row decodes at its own
        # temperature (empty slots sample greedily into ignored outputs);
        # the all-greedy batch — the common replay case — keeps the scalar
        # argmax-only fast path
        if any(rs.request.temperature > 0.0 for rs in live):
            temps = np.zeros((self.num_slots,), np.float32)
            for rs in live:
                temps[rs.slot] = rs.request.temperature
            toks = sample_token(logits, sub, jnp.asarray(temps))
        else:
            toks = sample_token(logits, sub, 0.0)
        for rs in list(live):
            tok = int(toks[rs.slot])
            rs.generated.append(tok)
            self._tokens = self._tokens.at[rs.slot].set(tok)
            self._maybe_finish(rs, tok)
        return len(live)

    def run_until_drained(self, max_ticks: int = 10_000,
                          on_limit: str = "raise") -> List[RequestState]:
        """Tick until every submitted request finishes or ``max_ticks`` ticks
        (counted from this call) elapse.  Hitting the limit with work still
        pending is a stall, never silently partial results: ``on_limit`` is
        ``"raise"`` (:class:`DrainStall`, the default) or ``"warn"`` (emit a
        ``RuntimeWarning``, set :attr:`stalled`, return what completed)."""
        if on_limit not in ("raise", "warn"):
            raise ValueError(f"on_limit must be 'raise' or 'warn', "
                             f"got {on_limit!r}")
        self.stalled = False
        start = self.ticks
        while self.queue or any(s is not None for s in self._slots):
            if self.ticks - start >= max_ticks:
                pending = len(self.queue) + sum(
                    s is not None for s in self._slots)
                msg = (f"batcher not drained after {max_ticks} ticks: "
                       f"{len(self.completed)} completed, {pending} pending")
                if on_limit == "raise":
                    raise DrainStall(msg, completed=len(self.completed),
                                     pending=pending)
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
                self.stalled = True
                break
            if self.tick() == 0 and not self.queue:
                break
        return self.completed

    # -- stats ----------------------------------------------------------------

    @property
    def mean_occupancy(self) -> float:
        return self._occupancy_sum / max(self.ticks, 1)
